//! Deterministic fault injection for chaos-testing the execution stack.
//!
//! A [`FaultPlan`] is a parsed list of fault specs, each addressed at a
//! campaign job (`panic` / `nan` / `stall`) or at a persisted-artifact
//! save (`truncate-save`), with a fire budget. Faults are keyed by
//! *identity* (optimizer name + job index + how many times the spec has
//! fired), not by wall-clock or randomness, so a replay under the same
//! plan faults at exactly the same points — which is what lets the tests
//! kill a metasweep mid-flight, resume it, and pin the merged envelope
//! bitwise against an uninterrupted run.
//!
//! ## Spec grammar
//!
//! A plan is `;`- or `,`-separated entries of the form `KIND@TARGET`:
//!
//! | entry                     | effect                                             |
//! |---------------------------|----------------------------------------------------|
//! | `panic@pso.j0`            | job 0 of the next `pso` campaign panics (once)     |
//! | `panic@pso.j0x*`          | …on every attempt (retries exhaust → quarantine)   |
//! | `nan@greedy_ils.j2x3`     | evals of that job score NaN, first 3 attempts      |
//! | `stall@*.j1`              | job 1 of any campaign stalls (simulated clock jam) |
//! | `truncate-save@s0`        | the first artifact save is truncated mid-write     |
//! | `truncate-save@*x2`       | the next two saves are truncated                   |
//!
//! Job-fault targets are `ALGO[.jN][xCOUNT]` — `ALGO` is an optimizer
//! registry name or `*`, `.jN` pins one job index (omit to match any
//! job), and `xCOUNT` caps how many times the spec fires (default 1,
//! `x*` = unlimited). Save targets are `sN` (the Nth save this process
//! performs, 0-based) or `*`.
//!
//! The CLI installs a process-global plan from `--inject-faults SPEC` or
//! the `TUNETUNER_FAULTS` environment variable; that global is consulted
//! by [`crate::util::fsio::atomic_write`] and handed by `main` to the
//! sweep drivers, which scope it to their own campaigns (reference
//! sweeps stay fault-free). Library code and tests pass explicit plans
//! (`Campaign::faults`, the `*_checkpointed` drivers) so parallel tests
//! never leak faults into each other.

use crate::error::{Result, TuneError};
use crate::runner::{EvalResult, Runner};
use crate::searchspace::SearchSpace;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Simulated seconds an injected stall jams onto every evaluation: far
/// past any campaign cutoff, so the first faulted eval exhausts the
/// budget deterministically (a *simulated* hang — the worker thread
/// itself never blocks).
pub const STALL_SECONDS: f64 = 1.0e9;

/// What an injected job fault does to the victim.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The job panics (exercises `catch_unwind` isolation + retry).
    Panic,
    /// Every evaluation the job performs scores NaN.
    NanScore,
    /// Every evaluation costs [`STALL_SECONDS`] extra simulated seconds.
    Stall,
}

impl FaultKind {
    fn name(self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::NanScore => "nan",
            FaultKind::Stall => "stall",
        }
    }
}

enum Target {
    /// A campaign job: optimizer name (`"*"` = any) and job index
    /// (`None` = any job of a matching campaign).
    Job { algo: String, job: Option<usize> },
    /// A persisted-artifact save, by process-wide ordinal (`None` = any).
    Save { ordinal: Option<u64> },
}

struct Spec {
    kind: Option<FaultKind>, // None = truncate-save
    target: Target,
    /// How many times this spec may fire (u32::MAX = unlimited).
    count: u32,
    fired: AtomicU32,
}

impl Spec {
    /// Atomically consume one firing if the budget allows.
    fn consume(&self) -> bool {
        self.fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |f| {
                (f < self.count).then(|| f.saturating_add(1))
            })
            .is_ok()
    }
}

/// A parsed, thread-safe fault plan. Cheap to consult (a short spec scan
/// per job start / save); drivers that receive `None` skip even that.
pub struct FaultPlan {
    specs: Vec<Spec>,
    /// Process-wide save ordinal (only advanced while a plan is active).
    saves: AtomicU64,
}

impl FaultPlan {
    /// Parse a plan from the spec grammar (see the module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut specs = Vec::new();
        for entry in spec.split([';', ',']) {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (kind_str, target_str) = entry.split_once('@').ok_or_else(|| {
                TuneError::InvalidInput(format!(
                    "fault spec {entry:?}: expected KIND@TARGET (e.g. panic@pso.j0x*)"
                ))
            })?;
            let (target_str, count) = split_count(target_str)?;
            match kind_str {
                "truncate-save" => {
                    let ordinal = match target_str {
                        "*" => None,
                        s => Some(parse_prefixed(s, 's').ok_or_else(|| {
                            TuneError::InvalidInput(format!(
                                "fault spec {entry:?}: truncate-save target must be sN or *"
                            ))
                        })?),
                    };
                    specs.push(Spec {
                        kind: None,
                        target: Target::Save { ordinal },
                        count,
                        fired: AtomicU32::new(0),
                    });
                }
                "panic" | "nan" | "stall" => {
                    let kind = match kind_str {
                        "panic" => FaultKind::Panic,
                        "nan" => FaultKind::NanScore,
                        _ => FaultKind::Stall,
                    };
                    let (algo, job) = match target_str.rsplit_once(".j") {
                        Some((algo, digits)) => {
                            let job = digits.parse::<usize>().map_err(|_| {
                                TuneError::InvalidInput(format!(
                                    "fault spec {entry:?}: bad job index {digits:?}"
                                ))
                            })?;
                            (algo, Some(job))
                        }
                        None => (target_str, None),
                    };
                    if algo.is_empty() {
                        return Err(TuneError::InvalidInput(format!(
                            "fault spec {entry:?}: empty optimizer target"
                        )));
                    }
                    specs.push(Spec {
                        kind: Some(kind),
                        target: Target::Job {
                            algo: algo.to_string(),
                            job,
                        },
                        count,
                        fired: AtomicU32::new(0),
                    });
                }
                other => {
                    return Err(TuneError::InvalidInput(format!(
                        "fault spec {entry:?}: unknown kind {other:?} \
                         (panic | nan | stall | truncate-save)"
                    )));
                }
            }
        }
        if specs.is_empty() {
            return Err(TuneError::InvalidInput(
                "empty fault plan: no KIND@TARGET entries".into(),
            ));
        }
        Ok(FaultPlan {
            specs,
            saves: AtomicU64::new(0),
        })
    }

    /// Fault to inject into job `job` of a campaign running `algo`, if
    /// any spec matches and still has fire budget. Called exactly once
    /// per job attempt, so `xCOUNT` budgets count *attempts*.
    pub fn job_fault(&self, algo: &str, job: usize) -> Option<FaultKind> {
        for spec in &self.specs {
            let Some(kind) = spec.kind else { continue };
            let Target::Job {
                algo: ref a,
                job: j,
            } = spec.target
            else {
                continue;
            };
            if (a == "*" || a == algo) && (j.is_none() || j == Some(job)) && spec.consume() {
                return Some(kind);
            }
        }
        None
    }

    /// Whether the save now being performed should be truncated.
    /// Advances the process-wide save ordinal.
    pub fn save_fault(&self) -> bool {
        let ordinal = self.saves.fetch_add(1, Ordering::SeqCst);
        for spec in &self.specs {
            let Target::Save { ordinal: o } = spec.target else {
                continue;
            };
            if (o.is_none() || o == Some(ordinal)) && spec.consume() {
                return true;
            }
        }
        false
    }
}

/// Split a trailing `xCOUNT` / `x*` fire budget off a target string.
fn split_count(target: &str) -> Result<(&str, u32)> {
    if let Some((head, suffix)) = target.rsplit_once('x') {
        if !head.is_empty() {
            if suffix == "*" {
                return Ok((head, u32::MAX));
            }
            if !suffix.is_empty() && suffix.bytes().all(|b| b.is_ascii_digit()) {
                let n: u32 = suffix.parse().map_err(|_| {
                    TuneError::InvalidInput(format!("fault count x{suffix} out of range"))
                })?;
                if n == 0 {
                    return Err(TuneError::InvalidInput(
                        "fault count x0 would never fire".into(),
                    ));
                }
                return Ok((head, n));
            }
        }
    }
    Ok((target, 1))
}

fn parse_prefixed(s: &str, prefix: char) -> Option<u64> {
    s.strip_prefix(prefix)?.parse().ok()
}

static GLOBAL: OnceLock<Arc<FaultPlan>> = OnceLock::new();

/// Install the process-global fault plan (the CLI entry point, from
/// `--inject-faults` / `TUNETUNER_FAULTS`). First install wins; library
/// code and tests should prefer explicit plans over this global.
pub fn install(plan: FaultPlan) -> Arc<FaultPlan> {
    Arc::clone(GLOBAL.get_or_init(|| Arc::new(plan)))
}

/// The process-global fault plan, if one was installed.
pub fn global() -> Option<Arc<FaultPlan>> {
    GLOBAL.get().cloned()
}

/// A [`Runner`] wrapper that corrupts evaluations according to an
/// injected [`FaultKind`]: `nan` poisons every value, `stall` jams
/// [`STALL_SECONDS`] onto every cost (the simulated clock exhausts the
/// budget after one eval; the worker thread never actually blocks, so
/// the batch always drains). `Campaign::run` wraps the sim runner in
/// this when the job's fault plan says so.
pub struct FaultyRunner<R: Runner> {
    inner: R,
    kind: FaultKind,
}

impl<R: Runner> FaultyRunner<R> {
    pub fn new(inner: R, kind: FaultKind) -> FaultyRunner<R> {
        FaultyRunner { inner, kind }
    }

    #[inline]
    fn corrupt(&self, value: f64, cost: f64) -> (f64, f64) {
        match self.kind {
            FaultKind::NanScore => (f64::NAN, cost),
            FaultKind::Stall => (value, cost + STALL_SECONDS),
            FaultKind::Panic => (value, cost),
        }
    }
}

impl<R: Runner> Runner for FaultyRunner<R> {
    fn space(&self) -> &SearchSpace {
        self.inner.space()
    }

    fn evaluate(&mut self, config_idx: usize) -> EvalResult {
        let mut r = self.inner.evaluate(config_idx);
        match self.kind {
            FaultKind::NanScore => r.value = f64::NAN,
            FaultKind::Stall => r.overhead += STALL_SECONDS,
            FaultKind::Panic => {}
        }
        r
    }

    fn label(&self) -> String {
        format!("{} [fault:{}]", self.inner.label(), self.kind.name())
    }

    fn evaluate_lite(&mut self, config_idx: usize) -> (f64, f64) {
        let (v, c) = self.inner.evaluate_lite(config_idx);
        self.corrupt(v, c)
    }

    fn evaluate_batch_lite(&mut self, idxs: &[usize], out: &mut Vec<(f64, f64)>) {
        self.inner.evaluate_batch_lite(idxs, out);
        for pair in out.iter_mut() {
            *pair = self.corrupt(pair.0, pair.1);
        }
    }

    fn batch_committed(&mut self, pairs: &[(f64, f64)]) {
        self.inner.batch_committed(pairs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_job_specs_with_counts() {
        let plan = FaultPlan::parse("panic@pso.j3x2; nan@greedy_ils; stall@*.j0x*").unwrap();
        // panic@pso.j3 fires twice, on job 3 only.
        assert_eq!(plan.job_fault("pso", 2), None);
        assert_eq!(plan.job_fault("pso", 3), Some(FaultKind::Panic));
        assert_eq!(plan.job_fault("pso", 3), Some(FaultKind::Panic));
        assert_eq!(plan.job_fault("pso", 3), None, "x2 budget exhausted");
        // nan@greedy_ils matches any job, once.
        assert_eq!(plan.job_fault("greedy_ils", 7), Some(FaultKind::NanScore));
        assert_eq!(plan.job_fault("greedy_ils", 7), None);
        // stall@*.j0 is unlimited and algo-wildcarded.
        for algo in ["a", "b", "a"] {
            assert_eq!(plan.job_fault(algo, 0), Some(FaultKind::Stall));
            assert_eq!(plan.job_fault(algo, 1), None);
        }
    }

    #[test]
    fn parses_save_specs_by_ordinal() {
        let plan = FaultPlan::parse("truncate-save@s1").unwrap();
        assert!(!plan.save_fault(), "save 0 passes");
        assert!(plan.save_fault(), "save 1 is truncated");
        assert!(!plan.save_fault(), "save 2 passes");

        let any = FaultPlan::parse("truncate-save@*x2").unwrap();
        assert!(any.save_fault());
        assert!(any.save_fault());
        assert!(!any.save_fault(), "x2 budget exhausted");
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "panic",
            "panic@",
            "explode@pso",
            "panic@pso.jx",
            "panic@pso.jNaN",
            "truncate-save@pso",
            "nan@pso.j1x0",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn underscore_algo_names_survive_count_splitting() {
        // `x` only splits a count when the suffix is digits or `*`:
        // names like `random_search` parse intact.
        let plan = FaultPlan::parse("panic@random_search.j1").unwrap();
        assert_eq!(plan.job_fault("random_search", 1), Some(FaultKind::Panic));
    }
}
