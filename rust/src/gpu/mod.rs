//! Simulated target devices.

pub mod specs;

pub use specs::{DeviceModel, all_devices, device_by_name, TEST_DEVICES, TRAIN_DEVICES};
