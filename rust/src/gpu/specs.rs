//! The six simulated GPUs of the paper's benchmark hub.
//!
//! The paper brute-forces four kernels on an NVIDIA A100, A4000, A6000 and
//! an AMD MI250X, W6600, W7800. We have none of these, so each is replaced
//! by a device *model* parameterized with the published architecture
//! numbers (SM/CU count, peak fp32 throughput, DRAM bandwidth, per-SM
//! occupancy limits, warp/wavefront width). The cross-device diversity —
//! compute- vs bandwidth-rich designs, 32- vs 64-wide scheduling, different
//! occupancy ceilings — is what exercises generalization in the
//! hyperparameter-tuning evaluation, and is preserved by these models.
//!
//! Following the paper's split: train = {A100, A4000, MI250X},
//! test = {A6000, W6600, W7800}.

use crate::perfmodel::contract::{self, NUM_DEVICE};

/// A simulated GPU.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceModel {
    pub name: &'static str,
    pub vendor: &'static str,
    /// Streaming multiprocessors (NVIDIA) / compute units (AMD).
    pub num_sm: u32,
    /// Peak fp32 GFLOP/s.
    pub peak_gflops: f32,
    /// Peak DRAM bandwidth in GB/s.
    pub bandwidth_gbs: f32,
    /// Max resident threads per SM.
    pub max_threads_per_sm: u32,
    /// Shared memory / LDS per SM in bytes.
    pub smem_per_sm: u32,
    /// Register file entries per SM.
    pub regs_per_sm: u32,
    /// Max resident blocks per SM.
    pub max_blocks_per_sm: u32,
    /// Warp (NVIDIA) or wavefront (AMD CDNA) width.
    pub warp_size: u32,
    /// Device-specific landscape seed in [0, 1): blends the two config
    /// hashes so every device reorders the ruggedness differently.
    pub rug_seed: f32,
    /// Ruggedness amplitude (relative spread of the landscape term).
    pub rug_amp: f32,
}

impl DeviceModel {
    /// Pack into the f32 device vector of the L1/L2 contract.
    pub fn to_vector(&self) -> [f32; NUM_DEVICE] {
        let mut d = [0f32; NUM_DEVICE];
        d[contract::D_NUM_SM] = self.num_sm as f32;
        d[contract::D_PEAK_GFLOPS] = self.peak_gflops;
        d[contract::D_BW_GBS] = self.bandwidth_gbs;
        d[contract::D_MAX_THREADS] = self.max_threads_per_sm as f32;
        d[contract::D_SMEM_SM] = self.smem_per_sm as f32;
        d[contract::D_REGS_SM] = self.regs_per_sm as f32;
        d[contract::D_MAX_BLOCKS] = self.max_blocks_per_sm as f32;
        d[contract::D_WARP] = self.warp_size as f32;
        d[contract::D_RUG_SEED] = self.rug_seed;
        d[contract::D_RUG_AMP] = self.rug_amp;
        d
    }

    /// Ratio of compute to bandwidth (FLOP per byte at peak): the machine
    /// balance used in docs and sanity tests.
    pub fn machine_balance(&self) -> f32 {
        self.peak_gflops / self.bandwidth_gbs
    }
}

/// NVIDIA A100 40GB (DAS-6): Ampere GA100.
pub const A100: DeviceModel = DeviceModel {
    name: "A100",
    vendor: "NVIDIA",
    num_sm: 108,
    peak_gflops: 19_500.0,
    bandwidth_gbs: 1_555.0,
    max_threads_per_sm: 2048,
    smem_per_sm: 167_936,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 32,
    warp_size: 32,
    rug_seed: 0.137,
    rug_amp: 0.22,
};

/// NVIDIA RTX A4000 (DAS-6): Ampere GA104, workstation.
pub const A4000: DeviceModel = DeviceModel {
    name: "A4000",
    vendor: "NVIDIA",
    num_sm: 48,
    peak_gflops: 19_170.0,
    bandwidth_gbs: 448.0,
    max_threads_per_sm: 1536,
    smem_per_sm: 102_400,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 16,
    warp_size: 32,
    rug_seed: 0.389,
    rug_amp: 0.24,
};

/// NVIDIA RTX A6000 (DAS-6): Ampere GA102, workstation.
pub const A6000: DeviceModel = DeviceModel {
    name: "A6000",
    vendor: "NVIDIA",
    num_sm: 84,
    peak_gflops: 38_710.0,
    bandwidth_gbs: 768.0,
    max_threads_per_sm: 1536,
    smem_per_sm: 102_400,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 16,
    warp_size: 32,
    rug_seed: 0.611,
    rug_amp: 0.23,
};

/// AMD MI250X (LUMI), single GCD: CDNA2, wavefront 64.
pub const MI250X: DeviceModel = DeviceModel {
    name: "MI250X",
    vendor: "AMD",
    num_sm: 110,
    peak_gflops: 23_950.0,
    bandwidth_gbs: 1_638.0,
    max_threads_per_sm: 2048,
    smem_per_sm: 65_536,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 16,
    warp_size: 64,
    rug_seed: 0.743,
    rug_amp: 0.28,
};

/// AMD Radeon PRO W6600 (DAS-6): RDNA2, wave32.
pub const W6600: DeviceModel = DeviceModel {
    name: "W6600",
    vendor: "AMD",
    num_sm: 28,
    peak_gflops: 10_400.0,
    bandwidth_gbs: 224.0,
    max_threads_per_sm: 1024,
    smem_per_sm: 65_536,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 16,
    warp_size: 32,
    rug_seed: 0.877,
    rug_amp: 0.27,
};

/// AMD Radeon PRO W7800 (DAS-6): RDNA3, wave32, dual-issue fp32.
pub const W7800: DeviceModel = DeviceModel {
    name: "W7800",
    vendor: "AMD",
    num_sm: 70,
    peak_gflops: 45_300.0,
    bandwidth_gbs: 576.0,
    max_threads_per_sm: 1024,
    smem_per_sm: 65_536,
    regs_per_sm: 65_536,
    max_blocks_per_sm: 16,
    warp_size: 32,
    rug_seed: 0.271,
    rug_amp: 0.26,
};

/// All six devices in benchmark-hub order.
pub fn all_devices() -> Vec<DeviceModel> {
    vec![A100, A4000, A6000, MI250X, W6600, W7800]
}

/// Training devices of the paper's split.
pub const TRAIN_DEVICES: [&str; 3] = ["MI250X", "A100", "A4000"];
/// Held-out test devices of the paper's split.
pub const TEST_DEVICES: [&str; 3] = ["W6600", "W7800", "A6000"];

/// Look up a device by (case-insensitive) name.
pub fn device_by_name(name: &str) -> Option<DeviceModel> {
    all_devices()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_distinct_devices() {
        let ds = all_devices();
        assert_eq!(ds.len(), 6);
        let names: std::collections::HashSet<_> = ds.iter().map(|d| d.name).collect();
        assert_eq!(names.len(), 6);
        let seeds: std::collections::HashSet<_> =
            ds.iter().map(|d| d.rug_seed.to_bits()).collect();
        assert_eq!(seeds.len(), 6, "rug seeds must differ per device");
    }

    #[test]
    fn train_test_split_partitions() {
        let mut all: Vec<&str> = TRAIN_DEVICES.iter().chain(TEST_DEVICES.iter()).copied().collect();
        all.sort();
        let mut names: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
        names.sort();
        assert_eq!(all, names);
    }

    #[test]
    fn vector_layout_matches_contract() {
        let v = A100.to_vector();
        assert_eq!(v[contract::D_NUM_SM], 108.0);
        assert_eq!(v[contract::D_WARP], 32.0);
        assert_eq!(v[contract::D_BW_GBS], 1555.0);
        assert!((v[contract::D_RUG_AMP] - 0.22).abs() < 1e-6);
    }

    #[test]
    fn balance_diversity() {
        // The set must span bandwidth-rich (A100, MI250X) and compute-rich
        // (A6000, W7800) designs for the landscapes to diverge.
        let balances: Vec<f32> = all_devices().iter().map(|d| d.machine_balance()).collect();
        let min = balances.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = balances.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(max / min > 3.0, "balances {balances:?}");
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(device_by_name("a100").unwrap().name, "A100");
        assert_eq!(device_by_name("MI250X").unwrap().warp_size, 64);
        assert!(device_by_name("H100").is_none());
    }
}
