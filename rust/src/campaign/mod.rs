//! The orchestration API: one typed, observable entry point for all
//! tuning runs.
//!
//! The paper's core loop — repeated simulated tuning runs aggregated into
//! Eq. 3 scores — used to be re-plumbed by hand in every driver (CLI
//! `tune`/`hypertune`, the exhaustive sweep, the meta-strategies, the
//! experiment regenerators), each with its own seed derivation, budgets,
//! and thread scopes. A [`Campaign`] owns that loop once:
//!
//! ```no_run
//! use tunetuner::campaign::Campaign;
//! use tunetuner::dataset::hub::Hub;
//! use tunetuner::optimizers::HyperParams;
//! use tunetuner::runtime::Engine;
//! use std::sync::Arc;
//!
//! # fn main() -> tunetuner::Result<()> {
//! let engine = Arc::new(Engine::auto(&Engine::default_artifacts_dir()));
//! let result = Campaign::new("genetic_algorithm")
//!     .hyperparams(HyperParams::new().set("popsize", 20i64))
//!     .matrix(&Hub::new(Hub::default_root()), engine, &["gemm"], &["A100"])?
//!     .repeats(5)
//!     .seed(42)
//!     .run()?;
//! println!("score {:.3}", result.score());
//! # Ok(())
//! # }
//! ```
//!
//! * Spaces come either from a kernel×device **matrix** (brute-force
//!   caches are built on demand through the engine) or from prepared
//!   [`SpaceEval`]s.
//! * Execution happens on a persistent [`Executor`] worker pool — one
//!   pool per process instead of one `thread::scope` per evaluation
//!   (the meta-tuning path runs ~150 campaigns back to back).
//! * Progress surfaces through an [`Observer`]; results come back as a
//!   serde-stable, versioned [`CampaignResult`] carrying each space's
//!   structural fingerprint as provenance.
//! * Seeds are deterministic per (campaign seed, space index, repeat):
//!   results are bit-reproducible regardless of pool size or scheduling.
//! * Jobs are fault-isolated: a panicking run never takes down the batch
//!   (see [`Executor::scatter_result`]), is retried under the
//!   [`RetryPolicy`] — replaying its exact RNG stream — and surfaces as
//!   a typed [`TuneError::WorkerPanic`] when retries exhaust, so the
//!   sweep drivers quarantine one leg instead of losing a whole sweep.
//!
//! `methodology::evaluate_algorithm`, `hypertuning::exhaustive_tuning`
//! and `hypertuning::MetaRunner` are thin wrappers over this module.

pub mod executor;
pub mod observer;
pub mod result;

pub use executor::{Executor, JobFailure};
pub use observer::{LogObserver, NullObserver, Observer};
pub use result::{CampaignResult, SpaceOutcome, SCHEMA, SCHEMA_VERSION};

use crate::dataset::hub::{Hub, HUB_SEED};
use crate::error::{Result, TuneError};
use crate::faults::{FaultKind, FaultPlan, FaultyRunner};
use crate::gpu::specs::device_by_name;
use crate::kernels;
use crate::methodology::{AggregateResult, SpaceEval};
use crate::optimizers::{self, HyperParams};
use crate::perfmodel::NoiseModel;
use crate::runner::{Budget, LiveRunner, SimulationRunner, Trace, Tuning, TuningScratch};
use crate::runtime::Engine;
use crate::util::rng::{mix64, Rng};
use std::sync::Arc;

/// How each tuning run's budget is derived.
#[derive(Clone, Debug)]
pub enum BudgetPolicy {
    /// The methodology default: each space's calibrated baseline budget
    /// (`SpaceEval::budget_seconds`) with the standard proposal cap
    /// (`4 × space + 10_000`) bounding schedule-heavy revisit spins.
    Methodology,
    /// Fixed simulated seconds per run (same proposal cap).
    Seconds(f64),
    /// Fixed unique-evaluation count per run.
    Evals(usize),
}

impl BudgetPolicy {
    fn for_space(&self, se: &SpaceEval) -> Budget {
        match self {
            BudgetPolicy::Methodology => Budget::seconds(se.budget_seconds)
                .with_proposal_cap(4 * se.space.len() + 10_000),
            BudgetPolicy::Seconds(s) => {
                Budget::seconds(*s).with_proposal_cap(4 * se.space.len() + 10_000)
            }
            BudgetPolicy::Evals(n) => Budget::evals(*n),
        }
    }

    fn render(&self) -> String {
        match self {
            BudgetPolicy::Methodology => "methodology".to_string(),
            BudgetPolicy::Seconds(s) => format!("{s}s"),
            BudgetPolicy::Evals(n) => format!("{n} evals"),
        }
    }
}

/// Where evaluations come from.
#[derive(Clone)]
pub enum Backend {
    /// The paper's simulation mode: replay from the brute-force caches
    /// (the default — what makes hypertuning feasible).
    Sim,
    /// Live evaluation through the device-model engine: every proposal is
    /// measured fresh (noise included). `seed` is the hub-style raw seed
    /// the per-(kernel, device) noise streams are derived from.
    Live { engine: Arc<Engine>, seed: u64 },
}

impl Backend {
    fn name(&self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Live { .. } => "live",
        }
    }
}

/// How many times a panicked tuning job is attempted in total before the
/// campaign gives up with [`TuneError::WorkerPanic`]. Retries are
/// deterministic: a job's RNG stream derives from its (space, repeat)
/// identity — not from the attempt number — so a retried job that
/// survives reproduces bitwise the trace a faultless run would have
/// produced.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts per job (initial run + retries). Minimum 1.
    pub max_attempts: usize,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 2 }
    }
}

/// A configured tuning campaign: one algorithm + hyperparameter
/// assignment, run `repeats` times on every prepared space, scored with
/// the methodology's Eq. 2/Eq. 3. Build with [`Campaign::new`] and the
/// chained setters, execute with [`Campaign::run`].
#[derive(Clone)]
pub struct Campaign {
    algo: String,
    hp: HyperParams,
    spaces: Arc<Vec<SpaceEval>>,
    repeats: usize,
    seed: u64,
    cutoff: f64,
    points: usize,
    budget: BudgetPolicy,
    backend: Backend,
    observer: Arc<dyn Observer>,
    executor: Arc<Executor>,
    retry: RetryPolicy,
    faults: Option<Arc<FaultPlan>>,
}

impl Campaign {
    /// Start a campaign for a registered optimizer (validated at
    /// [`run`](Campaign::run) time against the optimizer's schema).
    pub fn new(algo: &str) -> Campaign {
        Campaign {
            algo: algo.to_string(),
            hp: HyperParams::new(),
            spaces: Arc::new(Vec::new()),
            repeats: 1,
            seed: 42,
            cutoff: crate::methodology::DEFAULT_CUTOFF,
            points: crate::methodology::DEFAULT_POINTS,
            budget: BudgetPolicy::Methodology,
            backend: Backend::Sim,
            observer: Arc::new(NullObserver),
            executor: Executor::global(),
            retry: RetryPolicy::default(),
            faults: None,
        }
    }

    /// Hyperparameter assignment (schema-validated at run time).
    pub fn hyperparams(mut self, hp: HyperParams) -> Campaign {
        self.hp = hp;
        self
    }

    /// Same campaign, different hyperparameters — the cheap per-config
    /// clone the hypertuning drivers use (spaces stay shared).
    pub fn with_hyperparams(&self, hp: &HyperParams) -> Campaign {
        let mut c = self.clone();
        c.hp = hp.clone();
        c
    }

    /// Explicit prepared spaces.
    pub fn space_evals(mut self, spaces: Vec<SpaceEval>) -> Campaign {
        self.spaces = Arc::new(spaces);
        self
    }

    /// Prepared spaces shared with other campaigns (no clone).
    pub fn spaces_arc(mut self, spaces: Arc<Vec<SpaceEval>>) -> Campaign {
        self.spaces = spaces;
        self
    }

    /// Budget-cutoff percentile for [`matrix`](Campaign::matrix)-prepared
    /// spaces (default [`crate::methodology::DEFAULT_CUTOFF`]). Must be
    /// set **before** `matrix()`, which consumes it to build the spaces.
    pub fn cutoff(mut self, cutoff: f64) -> Campaign {
        self.cutoff = cutoff;
        self
    }

    /// Sampling points per curve for [`matrix`](Campaign::matrix)-prepared
    /// spaces (default [`crate::methodology::DEFAULT_POINTS`]). Must be
    /// set **before** `matrix()`, which consumes it to build the spaces.
    pub fn points(mut self, points: usize) -> Campaign {
        self.points = points;
        self
    }

    /// Prepare the kernel×device matrix: ensure every brute-force cache
    /// exists in the hub (building missing ones through `engine`), then
    /// derive each space's methodology budget and baseline — using the
    /// [`cutoff`](Campaign::cutoff) / [`points`](Campaign::points) set so
    /// far, so call those first. Spaces are ordered kernel-major
    /// (`k0×d0, k0×d1, …`), matching the paper's train/test layouts.
    pub fn matrix(
        mut self,
        hub: &Hub,
        engine: Arc<Engine>,
        kernel_names: &[&str],
        device_names: &[&str],
    ) -> Result<Campaign> {
        for d in device_names {
            if device_by_name(d).is_none() {
                return Err(TuneError::UnknownDevice((*d).to_string()));
            }
        }
        hub.ensure(kernel_names, device_names, engine, HUB_SEED)?;
        let mut spaces = Vec::with_capacity(kernel_names.len() * device_names.len());
        for k in kernel_names {
            let kernel = kernels::kernel_by_name(k)?;
            for d in device_names {
                let cache = hub.load(kernel.name, d)?;
                spaces.push(SpaceEval::new(
                    kernel.space_arc(),
                    cache,
                    self.cutoff,
                    self.points,
                ));
            }
        }
        self.spaces = Arc::new(spaces);
        Ok(self)
    }

    /// Tuning runs per space (the paper: 25 while hypertuning, 100 for
    /// re-evaluation).
    pub fn repeats(mut self, repeats: usize) -> Campaign {
        self.repeats = repeats;
        self
    }

    /// Campaign seed. Each (space `s`, repeat `r`) run draws its RNG from
    /// `mix64(seed, mix64(s, r))`, so results are reproducible regardless
    /// of pool size or scheduling.
    pub fn seed(mut self, seed: u64) -> Campaign {
        self.seed = seed;
        self
    }

    /// Budget policy (default [`BudgetPolicy::Methodology`]).
    pub fn budget(mut self, budget: BudgetPolicy) -> Campaign {
        self.budget = budget;
        self
    }

    /// Evaluation backend (default [`Backend::Sim`]).
    pub fn backend(mut self, backend: Backend) -> Campaign {
        self.backend = backend;
        self
    }

    /// Progress observer (default [`NullObserver`]).
    pub fn observer(mut self, observer: Arc<dyn Observer>) -> Campaign {
        self.observer = observer;
        self
    }

    /// Executor to run on (default the process-wide [`Executor::global`]).
    pub fn executor(mut self, executor: Arc<Executor>) -> Campaign {
        self.executor = executor;
        self
    }

    /// Retry policy for panicked jobs (default: one retry).
    pub fn retry(mut self, retry: RetryPolicy) -> Campaign {
        self.retry = retry;
        self
    }

    /// Fault-injection plan scoped to this campaign's jobs (chaos
    /// testing; default none). The sweep drivers thread their plan
    /// through here, so campaigns they *don't* hand it to — reference
    /// sweeps, unrelated tests — stay fault-free.
    pub fn faults(mut self, faults: Option<Arc<FaultPlan>>) -> Campaign {
        self.faults = faults;
        self
    }

    /// The prepared spaces.
    pub fn spaces(&self) -> &[SpaceEval] {
        &self.spaces
    }

    /// Validate, scatter all (space, repeat) runs onto the executor,
    /// gather and score the traces, and assemble the result envelope.
    pub fn run(&self) -> Result<CampaignResult> {
        // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
        let t0 = std::time::Instant::now();
        // Validate up front: algorithm + hyperparameters against the
        // registry schema (typed errors), spaces and repeats non-empty,
        // and — for the live backend — resolvable kernel/device names.
        let resolved = optimizers::descriptor(&self.algo)?.resolve(&self.hp)?;
        // Full construction once up front: a descriptor whose `build` can
        // fail beyond schema checks must surface a typed error here, not
        // a panic inside a pool worker.
        optimizers::create(&self.algo, &self.hp)?;
        if self.spaces.is_empty() {
            return Err(TuneError::InvalidInput(
                "campaign has no spaces (use .matrix() or .space_evals())".into(),
            ));
        }
        if self.repeats == 0 {
            return Err(TuneError::InvalidInput("campaign repeats must be >= 1".into()));
        }
        match &self.backend {
            Backend::Sim => {
                // Fail fast on stale caches (TuneError::StaleCache) before
                // burning a whole campaign replaying misaligned indices —
                // the guard the old per-run `SimulationRunner::new` gave
                // the CLI path. Spot-checks 4 keys per space, so the jobs
                // themselves can keep using the unchecked constructor.
                for se in self.spaces.iter() {
                    se.cache.verify_against(&se.space)?;
                }
            }
            Backend::Live { .. } => {
                for se in self.spaces.iter() {
                    kernels::kernel_by_name(&se.cache.kernel)?;
                    if device_by_name(&se.cache.device).is_none() {
                        return Err(TuneError::UnknownDevice(se.cache.device.clone()));
                    }
                }
            }
        }

        let hp_key = resolved.key();
        self.observer
            .campaign_started(&self.algo, &hp_key, self.spaces.len(), self.repeats);
        for (s, se) in self.spaces.iter().enumerate() {
            self.observer.space_started(s, &se.label, se.budget_seconds);
        }

        // Scatter: one job per (space, repeat); every job derives its RNG
        // from the job index, so gather order == job order and results
        // are scheduling-independent. The closure is shared with the
        // retry path below: a retried job re-derives the identical RNG
        // stream from its identity, so a job that panicked transiently
        // replays its original trace bitwise on the next attempt.
        let n_jobs = self.spaces.len() * self.repeats;
        let job_spaces = Arc::clone(&self.spaces);
        let job_observer = Arc::clone(&self.observer);
        let algo = self.algo.clone();
        let hp = self.hp.clone();
        let repeats = self.repeats;
        let seed = self.seed;
        let budget = self.budget.clone();
        let backend = self.backend.clone();
        let faults = self.faults.clone();
        let run_job: Arc<dyn Fn(usize) -> Trace + Send + Sync> = Arc::new(move |job| {
            let (s, r) = (job / repeats, job % repeats);
            let se = &job_spaces[s];
            job_observer.run_started(s, r);
            let fault = faults.as_ref().and_then(|p| p.job_fault(&algo, job));
            if fault == Some(FaultKind::Panic) {
                // lint: allow(W03, reason = "deliberate injected fault (chaos tests)")
                panic!("injected fault: panic ({algo} job {job})");
            }
            // Per-job optimizer instance (Optimizer is stateless across
            // runs, and create() is cheap).
            // lint: allow(W03, reason = "algorithm validated before scatter")
            let opt = optimizers::create(&algo, &hp).expect("validated before scatter");
            let budget = budget.for_space(se);
            let mut rng = Rng::new(mix64(seed, mix64(s as u64, r as u64)));
            // Pooled per-worker scratch: executor workers are persistent
            // threads, so the spaces×repeats jobs of a campaign (and of
            // every following campaign) reuse one set of space-sized
            // buffers per worker slot instead of allocating and zeroing
            // them per run.
            let trace = TuningScratch::with_pooled(|scratch| match &backend {
                Backend::Sim => {
                    let sim = SimulationRunner::new_unchecked(
                        Arc::clone(&se.space),
                        Arc::clone(&se.cache),
                    );
                    // Injected nan/stall faults corrupt evaluations
                    // through a wrapper; the job itself still completes,
                    // exercising the scoring path under poisoned data.
                    match fault {
                        Some(kind) => {
                            let mut faulty = FaultyRunner::new(sim, kind);
                            let mut tuning = Tuning::with_scratch(&mut faulty, budget, scratch);
                            opt.run(&mut tuning, &mut rng);
                            tuning.finish()
                        }
                        None => {
                            let mut sim = sim;
                            let mut tuning = Tuning::with_scratch(&mut sim, budget, scratch);
                            opt.run(&mut tuning, &mut rng);
                            tuning.finish()
                        }
                    }
                }
                Backend::Live { engine, seed } => {
                    let kernel = kernels::kernel_by_name(&se.cache.kernel)
                        // lint: allow(W03, reason = "kernel name validated before scatter")
                        .expect("validated before scatter");
                    let device = device_by_name(&se.cache.device)
                        // lint: allow(W03, reason = "device name validated before scatter")
                        .expect("validated before scatter");
                    let mut live = LiveRunner::new(
                        kernel,
                        &device,
                        Arc::clone(engine),
                        NoiseModel::default(),
                        *seed,
                    );
                    let mut tuning = Tuning::with_scratch(&mut live, budget, scratch);
                    opt.run(&mut tuning, &mut rng);
                    tuning.finish()
                }
            });
            job_observer.trace_completed(
                s,
                r,
                trace.best().unwrap_or(f64::INFINITY),
                trace.unique_evals,
                trace.elapsed,
            );
            trace
        });

        let scatter_job = Arc::clone(&run_job);
        let mut results = self
            .executor
            .scatter_result(n_jobs, move |job| scatter_job(job));
        // Deterministic retry: only the failed jobs are re-scattered, up
        // to the policy's attempt cap. On exhaustion the first failure
        // surfaces as a typed [`TuneError::WorkerPanic`] so the sweep
        // drivers can quarantine this leg instead of aborting the sweep.
        let max_attempts = self.retry.max_attempts.max(1);
        let mut attempts = 1;
        while attempts < max_attempts && results.iter().any(|res| res.is_err()) {
            attempts += 1;
            let failed: Vec<usize> = results
                .iter()
                .enumerate()
                .filter_map(|(i, res)| res.is_err().then_some(i))
                .collect();
            for &i in &failed {
                if let Err(f) = &results[i] {
                    let (s, r) = (i / self.repeats, i % self.repeats);
                    self.observer.leg_retried(
                        &format!("{}[s{s}r{r}]", self.algo),
                        attempts,
                        max_attempts,
                        &f.message,
                    );
                }
            }
            let retry_map = failed.clone();
            let retry_job = Arc::clone(&run_job);
            let retried = self
                .executor
                .scatter_result(failed.len(), move |k| retry_job(retry_map[k]));
            for (k, res) in retried.into_iter().enumerate() {
                results[failed[k]] = res.map_err(|mut f| {
                    // A retry batch's failure indices are positions in the
                    // compacted batch; restore the original job id.
                    f.job = failed[k];
                    f
                });
            }
        }
        if let Some((job, f)) = results
            .iter()
            .enumerate()
            .find_map(|(i, res)| res.as_ref().err().map(|f| (i, f)))
        {
            return Err(TuneError::WorkerPanic {
                job,
                attempts,
                message: f.message.clone(),
            });
        }
        let traces: Vec<Trace> = results
            .into_iter()
            // lint: allow(W03, reason = "failures re-raised above; all results are Some")
            .map(|res| res.expect("failures handled above"))
            .collect();

        // Gather: score the whole campaign's traces with one batched
        // call (traces are in job order, grouped by space).
        let per_space_scores =
            crate::methodology::score_campaign(&self.spaces, &traces, self.repeats);
        let mut spaces_out = Vec::with_capacity(self.spaces.len());
        let mut simulated = 0.0;
        for (s, se) in self.spaces.iter().enumerate() {
            let runs = &traces[s * self.repeats..(s + 1) * self.repeats];
            let scores = &per_space_scores[s];
            let mean_score = crate::util::stats::mean(scores);
            self.observer.space_scored(s, &se.label, mean_score);
            simulated += runs.iter().map(|t| t.elapsed).sum::<f64>();
            spaces_out.push(SpaceOutcome {
                label: se.label.clone(),
                kernel: se.cache.kernel.clone(),
                device: se.cache.device.clone(),
                space_fingerprint: se.space.fingerprint(),
                budget_seconds: se.budget_seconds,
                optimum: se.optimum,
                best_value: runs
                    .iter()
                    .filter_map(|t| t.best())
                    .fold(f64::INFINITY, f64::min),
                mean_unique_evals: runs.iter().map(|t| t.unique_evals as f64).sum::<f64>()
                    / runs.len() as f64,
                mean_score,
                scores: scores.clone(),
            });
        }
        let aggregate = AggregateResult::from_per_space_scores(per_space_scores);
        let wallclock = t0.elapsed().as_secs_f64();
        self.observer.campaign_finished(aggregate.score, wallclock);
        Ok(CampaignResult {
            algo: self.algo.clone(),
            hp_key,
            hp: resolved.0.into_iter().collect(),
            repeats: self.repeats,
            seed: self.seed,
            backend: self.backend.name().to_string(),
            budget: self.budget.render(),
            spaces: spaces_out,
            aggregate,
            wallclock_seconds: wallclock,
            simulated_seconds: simulated,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::{A100, W7800};
    use crate::perfmodel::NoiseModel;
    use std::sync::Mutex;
    use std::sync::OnceLock;

    fn spaces() -> &'static Vec<SpaceEval> {
        static SPACES: OnceLock<Vec<SpaceEval>> = OnceLock::new();
        SPACES.get_or_init(|| {
            let engine = Arc::new(Engine::native());
            [&A100, &W7800]
                .iter()
                .map(|dev| {
                    let kernel = kernels::kernel_by_name("synthetic").unwrap();
                    let mut live = LiveRunner::new(
                        kernels::kernel_by_name("synthetic").unwrap(),
                        dev,
                        Arc::clone(&engine),
                        NoiseModel::default(),
                        42,
                    );
                    let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                    SpaceEval::new(kernel.space_arc(), cache, 0.95, 20)
                })
                .collect()
        })
    }

    // The golden comparison against a verbatim copy of the pre-refactor
    // thread::scope evaluator lives in rust/tests/campaign.rs (comparing
    // against `evaluate_algorithm` here would be tautological — it is a
    // thin wrapper over this module now).

    #[test]
    fn stale_cache_is_typed_error() {
        let se = &spaces()[0];
        let gemm = kernels::kernel_by_name("gemm").unwrap();
        // A cache for the synthetic space presented with the gemm space:
        // the campaign must refuse before running anything.
        let stale = SpaceEval::new(gemm.space_arc(), Arc::clone(&se.cache), 0.95, 10);
        let err = Campaign::new("random_search")
            .space_evals(vec![stale])
            .run()
            .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err}");
    }

    #[test]
    fn campaign_is_deterministic_across_pool_sizes() {
        let base = Campaign::new("genetic_algorithm")
            .space_evals(spaces().clone())
            .repeats(6)
            .seed(11);
        let wide = base.clone().run().unwrap();
        let narrow = base
            .executor(Arc::new(Executor::new(0)))
            .run()
            .unwrap();
        assert_eq!(wide.score().to_bits(), narrow.score().to_bits());
        assert_eq!(wide.aggregate.aggregate_curve, narrow.aggregate.aggregate_curve);
    }

    #[test]
    fn validation_is_typed() {
        let err = Campaign::new("nope")
            .space_evals(spaces().clone())
            .run()
            .unwrap_err();
        assert!(matches!(err, TuneError::UnknownAlgorithm { .. }), "{err}");
        let err = Campaign::new("pso")
            .hyperparams(HyperParams::new().set("c3", 1.0))
            .space_evals(spaces().clone())
            .run()
            .unwrap_err();
        assert!(matches!(err, TuneError::SchemaViolation(_)), "{err}");
        let err = Campaign::new("pso").run().unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");
        let err = Campaign::new("pso")
            .space_evals(spaces().clone())
            .repeats(0)
            .run()
            .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");
    }

    #[test]
    fn envelope_carries_provenance_and_outcomes() {
        let c = Campaign::new("mls")
            .space_evals(spaces().clone())
            .repeats(4)
            .seed(5)
            .run()
            .unwrap();
        assert_eq!(c.spaces.len(), 2);
        for (so, se) in c.spaces.iter().zip(spaces()) {
            assert_eq!(so.space_fingerprint, se.space.fingerprint());
            assert_eq!(so.kernel, "synthetic");
            assert_eq!(so.scores.len(), 20);
            assert!(so.best_value.is_finite());
            assert!(so.mean_unique_evals > 0.0);
        }
        assert_eq!(c.backend, "sim");
        assert_eq!(c.budget, "methodology");
        // The resolved hyperparameters (schema defaults) are recorded.
        assert!(c.hp_key.contains("neighborhood="), "{}", c.hp_key);
        // Round-trips through the JSON envelope.
        let back = CampaignResult::from_json(&c.to_json()).unwrap();
        assert_eq!(back.score(), c.score());
        assert_eq!(back.spaces[0].space_fingerprint, c.spaces[0].space_fingerprint);
    }

    #[test]
    fn eval_budget_policy_bounds_runs() {
        let c = Campaign::new("random_search")
            .space_evals(spaces().clone())
            .repeats(3)
            .budget(BudgetPolicy::Evals(7))
            .run()
            .unwrap();
        for so in &c.spaces {
            assert!(so.mean_unique_evals <= 7.0 + 1e-9);
        }
        assert_eq!(c.budget, "7 evals");
    }

    #[test]
    fn live_backend_runs_and_scores() {
        let c = Campaign::new("random_search")
            .space_evals(spaces().clone())
            .repeats(3)
            .seed(9)
            .backend(Backend::Live {
                engine: Arc::new(Engine::native()),
                seed: 42,
            })
            .run()
            .unwrap();
        assert_eq!(c.backend, "live");
        // Live evaluations replay the same device model the caches were
        // built from, so scores stay in the plausible band.
        assert!(c.score() > -1.5 && c.score() < 1.0, "score {}", c.score());
    }

    /// Events from the submitting thread are totally ordered; worker
    /// events respect the documented partial order.
    #[derive(Default)]
    struct Collector(Mutex<Vec<String>>);

    impl Observer for Collector {
        fn campaign_started(&self, algo: &str, _hp: &str, spaces: usize, repeats: usize) {
            self.0
                .lock()
                .unwrap()
                .push(format!("campaign_started {algo} {spaces} {repeats}"));
        }
        fn space_started(&self, s: usize, _label: &str, _b: f64) {
            self.0.lock().unwrap().push(format!("space_started {s}"));
        }
        fn run_started(&self, s: usize, r: usize) {
            self.0.lock().unwrap().push(format!("run_started {s} {r}"));
        }
        fn trace_completed(&self, s: usize, r: usize, _b: f64, _u: usize, _e: f64) {
            self.0.lock().unwrap().push(format!("trace_completed {s} {r}"));
        }
        fn space_scored(&self, s: usize, _label: &str, _m: f64) {
            self.0.lock().unwrap().push(format!("space_scored {s}"));
        }
        fn campaign_finished(&self, _score: f64, _w: f64) {
            self.0.lock().unwrap().push("campaign_finished".to_string());
        }
    }

    #[test]
    fn observer_event_ordering() {
        let collector = Arc::new(Collector::default());
        Campaign::new("pso")
            .space_evals(spaces().clone())
            .repeats(3)
            .observer(Arc::clone(&collector) as Arc<dyn Observer>)
            .run()
            .unwrap();
        let events = collector.0.lock().unwrap().clone();
        let pos = |name: &str| events.iter().position(|e| e == name).unwrap();

        assert!(events[0].starts_with("campaign_started pso 2 3"));
        assert_eq!(events.last().unwrap(), "campaign_finished");
        // All space_started events precede all run/trace events.
        let last_started = events
            .iter()
            .rposition(|e| e.starts_with("space_started"))
            .unwrap();
        let first_run = events
            .iter()
            .position(|e| e.starts_with("run_started"))
            .unwrap();
        assert!(last_started < first_run);
        // Every (space, repeat) ran exactly once, start before completion.
        for s in 0..2 {
            for r in 0..3 {
                let started = pos(&format!("run_started {s} {r}"));
                let done = pos(&format!("trace_completed {s} {r}"));
                assert!(started < done);
                assert_eq!(
                    events.iter().filter(|e| **e == format!("trace_completed {s} {r}")).count(),
                    1
                );
            }
        }
        // Scoring happens after every trace, in space order.
        let last_trace = events
            .iter()
            .rposition(|e| e.starts_with("trace_completed"))
            .unwrap();
        assert!(pos("space_scored 0") > last_trace);
        assert!(pos("space_scored 0") < pos("space_scored 1"));
    }

    /// Collects only the fault-tolerance events.
    #[derive(Default)]
    struct RetryCollector(Mutex<Vec<String>>);

    impl Observer for RetryCollector {
        fn leg_retried(&self, leg: &str, attempt: usize, max_attempts: usize, error: &str) {
            self.0
                .lock()
                .unwrap()
                .push(format!("{leg} {attempt}/{max_attempts} {error}"));
        }
    }

    /// A transiently panicking job is retried on its identity-derived RNG
    /// stream, so the final envelope is bitwise identical to a fault-free
    /// run.
    #[test]
    fn injected_panic_is_retried_and_reproduces_clean_result() {
        let clean = Campaign::new("pso")
            .space_evals(spaces().clone())
            .repeats(3)
            .seed(17)
            .run()
            .unwrap();
        let collector = Arc::new(RetryCollector::default());
        let plan = Arc::new(crate::faults::FaultPlan::parse("panic@pso.j3").unwrap());
        let retried = Campaign::new("pso")
            .space_evals(spaces().clone())
            .repeats(3)
            .seed(17)
            .faults(Some(plan))
            .observer(Arc::clone(&collector) as Arc<dyn Observer>)
            .run()
            .unwrap();
        assert_eq!(clean.score().to_bits(), retried.score().to_bits());
        assert_eq!(
            clean.aggregate.aggregate_curve,
            retried.aggregate.aggregate_curve
        );
        let events = collector.0.lock().unwrap().clone();
        // Job 3 with 3 repeats is (space 1, repeat 0); one retry at the
        // default two-attempt policy, carrying the captured panic payload.
        assert_eq!(events.len(), 1, "{events:?}");
        assert!(events[0].starts_with("pso[s1r0] 2/2"), "{}", events[0]);
        assert!(events[0].contains("injected fault"), "{}", events[0]);
    }

    /// A job that panics on every attempt exhausts the retry budget and
    /// surfaces as a typed `WorkerPanic` — and the executor pool survives
    /// to run the next campaign.
    #[test]
    fn exhausted_retries_are_typed_worker_panic() {
        let plan = Arc::new(crate::faults::FaultPlan::parse("panic@pso.j1x*").unwrap());
        let base = Campaign::new("pso")
            .space_evals(spaces().clone())
            .repeats(3)
            .seed(23);
        let err = base.clone().faults(Some(plan)).run().unwrap_err();
        match &err {
            TuneError::WorkerPanic {
                job,
                attempts,
                message,
            } => {
                assert_eq!(*job, 1);
                assert_eq!(*attempts, 2);
                assert!(message.contains("injected fault"), "{message}");
            }
            other => panic!("expected WorkerPanic, got {other}"),
        }
        // The shared pool is unharmed: the same campaign minus the plan
        // completes normally.
        base.run().unwrap();
    }

    /// nan/stall faults corrupt evaluations without killing the job: the
    /// campaign completes (possibly with degraded scores) and never errors.
    #[test]
    fn nan_and_stall_faults_complete_without_error() {
        let plan = Arc::new(
            crate::faults::FaultPlan::parse("nan@random_search.j0; stall@random_search.j1")
                .unwrap(),
        );
        let c = Campaign::new("random_search")
            .space_evals(spaces().clone())
            .repeats(3)
            .seed(31)
            .faults(Some(plan))
            .run()
            .unwrap();
        assert_eq!(c.spaces.len(), 2);
        // The stalled job burned its whole budget on one evaluation.
        assert!(c.spaces[0].mean_unique_evals >= 1.0);
    }
}
