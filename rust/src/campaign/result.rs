//! Serde-stable campaign result envelope.
//!
//! [`CampaignResult`] is the machine-consumable record of one campaign:
//! a versioned schema (`tunetuner-campaign` / [`SCHEMA_VERSION`]), the
//! campaign inputs (algorithm, hyperparameter key/values, repeats, seed,
//! backend, budget policy), one [`SpaceOutcome`] per search space —
//! carrying the space's [`fingerprint`](crate::searchspace::SearchSpace::fingerprint)
//! as provenance — and the Eq. 3 aggregate. `tunetuner tune --json`
//! prints exactly this envelope, and the JSON round-trips through
//! [`CampaignResult::from_json`].

use crate::error::{Context, Result};
use crate::methodology::AggregateResult;
use crate::searchspace::Value;
use crate::util::json::Json;

/// Version of the serialized envelope; bump on breaking field changes.
pub const SCHEMA_VERSION: u64 = 1;

/// Schema tag of the serialized envelope.
pub const SCHEMA: &str = "tunetuner-campaign";

/// Per-space outcome of a campaign.
#[derive(Clone, Debug)]
pub struct SpaceOutcome {
    /// Display label (`kernel@device`).
    pub label: String,
    pub kernel: String,
    pub device: String,
    /// Structural fingerprint of the kernel search space the runs walked.
    pub space_fingerprint: String,
    /// Methodology budget of this space in simulated seconds.
    pub budget_seconds: f64,
    /// Known optimum of the space (from its brute-force cache).
    pub optimum: f64,
    /// Best objective value found across the repeats.
    pub best_value: f64,
    /// Mean unique evaluations per repeat.
    pub mean_unique_evals: f64,
    /// Eq. 2 score at each sampling point (mean over repeats).
    pub scores: Vec<f64>,
    /// Mean of `scores`.
    pub mean_score: f64,
}

/// The complete, serializable outcome of one campaign.
#[derive(Clone, Debug)]
pub struct CampaignResult {
    pub algo: String,
    /// Stable `k=v,k=v` rendering of the (schema-resolved) hyperparameters.
    pub hp_key: String,
    /// The hyperparameter assignment itself.
    pub hp: Vec<(String, Value)>,
    pub repeats: usize,
    pub seed: u64,
    /// `"sim"` or `"live"`.
    pub backend: String,
    /// Budget policy rendering (`"methodology"`, `"12.5s"`, `"200 evals"`).
    pub budget: String,
    pub spaces: Vec<SpaceOutcome>,
    /// The Eq. 3 aggregation the hypertuner maximizes.
    pub aggregate: AggregateResult,
    /// Real seconds the campaign took.
    pub wallclock_seconds: f64,
    /// Simulated device-seconds consumed by all runs.
    pub simulated_seconds: f64,
}

impl CampaignResult {
    /// The scalar Eq. 3 score.
    pub fn score(&self) -> f64 {
        self.aggregate.score
    }

    pub fn to_json(&self) -> Json {
        let spaces: Vec<Json> = self
            .spaces
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("label", s.label.as_str().into())
                    .set("kernel", s.kernel.as_str().into())
                    .set("device", s.device.as_str().into())
                    .set("space_fingerprint", s.space_fingerprint.as_str().into())
                    .set("budget_seconds", s.budget_seconds.into())
                    .set("optimum", s.optimum.into())
                    .set("best_value", s.best_value.into())
                    .set("mean_unique_evals", s.mean_unique_evals.into())
                    .set(
                        "scores",
                        Json::Arr(s.scores.iter().map(|&v| v.into()).collect()),
                    )
                    .set("mean_score", s.mean_score.into());
                o
            })
            .collect();
        let mut hp = Json::obj();
        for (k, v) in &self.hp {
            hp.set(k, value_to_json(v));
        }
        let mut j = Json::obj();
        j.set("schema", SCHEMA.into())
            .set("schema_version", (SCHEMA_VERSION as f64).into())
            .set("algo", self.algo.as_str().into())
            .set("hp_key", self.hp_key.as_str().into())
            .set("hp", hp)
            .set("repeats", self.repeats.into())
            // String, not number: JSON numbers are f64 and would corrupt
            // seeds >= 2^53 on the round-trip.
            .set("seed", self.seed.to_string().as_str().into())
            .set("backend", self.backend.as_str().into())
            .set("budget", self.budget.as_str().into())
            .set("spaces", Json::Arr(spaces))
            .set(
                "aggregate_curve",
                Json::Arr(self.aggregate.aggregate_curve.iter().map(|&v| v.into()).collect()),
            )
            .set("score", self.aggregate.score.into())
            .set("wallclock_seconds", self.wallclock_seconds.into())
            .set("simulated_seconds", self.simulated_seconds.into());
        j
    }

    /// Parse an envelope previously produced by [`to_json`](Self::to_json).
    ///
    /// Numeric hyperparameter *kinds* normalize on the round-trip: JSON
    /// numbers are untyped, so a whole-valued `Value::Float` comes back
    /// as `Value::Int` (same rendered key, and schema validation widens
    /// integers to floats, so feeding the parsed assignment back into a
    /// campaign is lossless in behavior).
    pub fn from_json(j: &Json) -> Result<CampaignResult> {
        if j.get("schema").and_then(|v| v.as_str()) != Some(SCHEMA) {
            crate::bail!("not a {SCHEMA} envelope");
        }
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        if version > SCHEMA_VERSION {
            crate::bail!(
                "campaign envelope version {version} is newer than this \
                 binary's {SCHEMA_VERSION}"
            );
        }
        let f64s = |v: &Json| -> Vec<f64> {
            v.as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .collect()
        };
        let mut spaces = Vec::new();
        for s in j.get("spaces").and_then(|v| v.as_arr()).context("missing spaces")? {
            let str_field = |k: &str| -> String {
                s.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string()
            };
            let num_field =
                |k: &str| -> f64 { s.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN) };
            spaces.push(SpaceOutcome {
                label: str_field("label"),
                kernel: str_field("kernel"),
                device: str_field("device"),
                space_fingerprint: str_field("space_fingerprint"),
                budget_seconds: num_field("budget_seconds"),
                optimum: num_field("optimum"),
                best_value: num_field("best_value"),
                mean_unique_evals: num_field("mean_unique_evals"),
                scores: s.get("scores").map(&f64s).unwrap_or_default(),
                mean_score: num_field("mean_score"),
            });
        }
        let aggregate_curve = j.get("aggregate_curve").map(&f64s).unwrap_or_default();
        let score = j.get("score").and_then(|v| v.as_f64()).context("missing score")?;
        let hp: Vec<(String, Value)> = j
            .get("hp")
            .and_then(|v| v.as_obj())
            .map(|m| m.iter().map(|(k, v)| (k.clone(), json_to_value(v))).collect())
            .unwrap_or_default();
        Ok(CampaignResult {
            algo: j
                .get("algo")
                .and_then(|v| v.as_str())
                .context("missing algo")?
                .to_string(),
            hp_key: j
                .get("hp_key")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            hp,
            repeats: j.get("repeats").and_then(|v| v.as_usize()).unwrap_or(0),
            seed: match j.get("seed") {
                Some(Json::Str(s)) => s.parse().unwrap_or(0),
                Some(v) => v.as_f64().unwrap_or(0.0) as u64,
                None => 0,
            },
            backend: j
                .get("backend")
                .and_then(|v| v.as_str())
                .unwrap_or("sim")
                .to_string(),
            budget: j
                .get("budget")
                .and_then(|v| v.as_str())
                .unwrap_or("methodology")
                .to_string(),
            aggregate: AggregateResult {
                per_space_scores: spaces.iter().map(|s| s.scores.clone()).collect(),
                aggregate_curve,
                score,
            },
            spaces,
            wallclock_seconds: j
                .get("wallclock_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            simulated_seconds: j
                .get("simulated_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        })
    }
}

fn value_to_json(v: &Value) -> Json {
    match v {
        Value::Int(i) => Json::Num(*i as f64),
        Value::Float(f) => Json::Num(*f),
        Value::Str(s) => Json::Str(s.clone()),
        Value::Bool(b) => Json::Bool(*b),
    }
}

fn json_to_value(j: &Json) -> Value {
    match j {
        Json::Num(x) if x.fract() == 0.0 && x.abs() < 9e15 => Value::Int(*x as i64),
        Json::Num(x) => Value::Float(*x),
        Json::Bool(b) => Value::Bool(*b),
        other => Value::Str(other.as_str().unwrap_or_default().to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CampaignResult {
        CampaignResult {
            algo: "pso".into(),
            hp_key: "c1=2,popsize=20".into(),
            hp: vec![
                ("c1".to_string(), Value::Float(2.0)),
                ("popsize".to_string(), Value::Int(20)),
            ],
            repeats: 5,
            seed: 42,
            backend: "sim".into(),
            budget: "methodology".into(),
            spaces: vec![SpaceOutcome {
                label: "gemm@A100".into(),
                kernel: "gemm".into(),
                device: "A100".into(),
                space_fingerprint: "abc-123".into(),
                budget_seconds: 12.5,
                optimum: 0.001,
                best_value: 0.0012,
                mean_unique_evals: 40.0,
                scores: vec![0.1, 0.2, 0.3],
                mean_score: 0.2,
            }],
            aggregate: AggregateResult {
                per_space_scores: vec![vec![0.1, 0.2, 0.3]],
                aggregate_curve: vec![0.1, 0.2, 0.3],
                score: 0.2,
            },
            wallclock_seconds: 1.5,
            simulated_seconds: 60.0,
        }
    }

    #[test]
    fn envelope_roundtrip() {
        let r = sample();
        let j = r.to_json();
        assert_eq!(j.get("schema").and_then(|v| v.as_str()), Some(SCHEMA));
        let back = CampaignResult::from_json(&j).unwrap();
        assert_eq!(back.algo, "pso");
        assert_eq!(back.hp_key, r.hp_key);
        // Kinds normalize (whole Float -> Int) but names and rendered
        // values survive exactly.
        assert_eq!(back.hp.len(), r.hp.len());
        for ((bk, bv), (rk, rv)) in back.hp.iter().zip(&r.hp) {
            assert_eq!(bk, rk);
            assert_eq!(bv.key(), rv.key());
        }
        assert_eq!(back.spaces.len(), 1);
        assert_eq!(back.spaces[0].space_fingerprint, "abc-123");
        assert_eq!(back.spaces[0].scores, vec![0.1, 0.2, 0.3]);
        assert_eq!(back.aggregate.score, 0.2);
        assert_eq!(back.aggregate.per_space_scores, r.aggregate.per_space_scores);
        assert_eq!(back.seed, 42);
        assert_eq!(back.backend, "sim");
    }

    #[test]
    fn roundtrip_through_text() {
        let r = sample();
        let text = r.to_json().to_pretty();
        let parsed = crate::util::json::parse(&text).unwrap();
        let back = CampaignResult::from_json(&parsed).unwrap();
        assert_eq!(back.hp_key, r.hp_key);
        assert_eq!(back.score(), r.score());
    }

    #[test]
    fn seed_survives_beyond_f64_precision() {
        let mut r = sample();
        r.seed = 0xDEAD_BEEF_DEAD_BEEF; // > 2^53: a JSON number would corrupt it
        let text = r.to_json().to_string();
        let back =
            CampaignResult::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.seed, 0xDEAD_BEEF_DEAD_BEEF);
    }

    #[test]
    fn rejects_foreign_and_future_envelopes() {
        let mut j = Json::obj();
        j.set("schema", "something-else".into());
        assert!(CampaignResult::from_json(&j).is_err());
        let mut j = sample().to_json();
        j.set("schema_version", 999.0.into());
        assert!(CampaignResult::from_json(&j).is_err());
    }
}
