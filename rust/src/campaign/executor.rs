//! Persistent scatter/gather worker pool.
//!
//! Before the campaign API, every call to `evaluate_algorithm` spawned a
//! fresh `std::thread::scope` — the meta-tuning path re-created the whole
//! pool for each of its ~150 hyperparameter evaluations. The [`Executor`]
//! keeps one set of workers alive for the process (or a scoped pool for
//! tests/benches) and hands them batches of independent jobs:
//!
//! * **scatter** — jobs are claimed from a shared atomic counter, so work
//!   distribution is dynamic (a slow space doesn't idle the other
//!   workers) exactly as with the old per-call scope;
//! * **gather** — every job writes its own slot; results come back in job
//!   order, so downstream scoring is independent of thread scheduling.
//!
//! Determinism is unaffected by pooling: job payloads derive their RNG
//! streams from the job index, never from the executing thread.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// True while this thread is executing an executor job. `scatter` is
    /// not reentrant (the submit lock is held for the whole batch); a
    /// nested call from inside a job would deadlock, so it panics with a
    /// diagnosis instead.
    static IN_EXECUTOR_JOB: Cell<bool> = const { Cell::new(false) };
}

/// One published batch of jobs.
struct Batch {
    n_jobs: usize,
    /// Next job index to claim.
    next: AtomicUsize,
    /// Jobs finished (success or panic).
    completed: AtomicUsize,
    panicked: AtomicBool,
    /// Runs job `i`; the closure writes its result into slot `i`.
    job: Box<dyn Fn(usize) + Send + Sync>,
}

struct State {
    batch: Option<Arc<Batch>>,
    /// Bumped on every publish so sleeping workers can tell a new batch
    /// from a spurious wakeup.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    batch_done: Condvar,
    jobs_completed: AtomicU64,
    batches: AtomicU64,
}

/// A persistent worker pool executing scatter/gather batches.
pub struct Executor {
    shared: Arc<Shared>,
    /// Serializes batches: one in flight at a time (batches from
    /// concurrent tests/threads queue up here).
    submit: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl Executor {
    /// Pool with an explicit worker count (0 = jobs run on the submitting
    /// thread only, still correct — useful for tests).
    pub fn new(workers: usize) -> Executor {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batch: None,
                epoch: 0,
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            batch_done: Condvar::new(),
            jobs_completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tt-exec-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor {
            shared,
            submit: Mutex::new(()),
            workers,
            handles,
        }
    }

    /// The process-wide shared pool (sized to the available parallelism),
    /// created on first use and kept alive for the process lifetime.
    pub fn global() -> Arc<Executor> {
        static GLOBAL: OnceLock<Arc<Executor>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| {
            let workers = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4);
            Arc::new(Executor::new(workers))
        }))
    }

    /// Number of pool workers (the submitting thread also participates in
    /// every batch, so effective parallelism is `workers + 1`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total jobs completed over the executor's lifetime.
    pub fn jobs_completed(&self) -> u64 {
        self.shared.jobs_completed.load(Ordering::Relaxed)
    }

    /// Total batches executed over the executor's lifetime.
    pub fn batches(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    /// Run `n_jobs` independent jobs and gather their results in job
    /// order. Blocks until every job finished; panics (after the batch
    /// drains) if any job panicked, mirroring `thread::scope` semantics.
    ///
    /// Not reentrant: a job (or an observer it calls) must not scatter on
    /// any executor from inside the job — the calling batch would wait on
    /// the nested one while holding its slot. Detected and panicked with
    /// a diagnosis rather than deadlocking.
    pub fn scatter<T, F>(&self, n_jobs: usize, job: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if IN_EXECUTOR_JOB.with(|f| f.get()) {
            panic!(
                "Executor::scatter called from inside an executor job; nested \
                 scatter/Campaign::run would deadlock the pool — restructure so \
                 campaigns are submitted from the driving thread"
            );
        }
        if n_jobs == 0 {
            return Vec::new();
        }
        let slots: Arc<Vec<Mutex<Option<T>>>> =
            Arc::new((0..n_jobs).map(|_| Mutex::new(None)).collect());
        let write_slots = Arc::clone(&slots);
        let batch = Arc::new(Batch {
            n_jobs,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            job: Box::new(move |i| {
                let v = job(i);
                *write_slots[i].lock().unwrap() = Some(v);
            }),
        });

        let submit = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.batch = Some(Arc::clone(&batch));
            self.shared.work_ready.notify_all();
        }
        self.shared.batches.fetch_add(1, Ordering::Relaxed);
        // The submitting thread drains the same counter: a zero-worker
        // executor still completes, and small batches don't wait on pool
        // wakeup latency.
        run_jobs(&self.shared, &batch);
        let mut st = self.shared.state.lock().unwrap();
        while batch.completed.load(Ordering::Acquire) < n_jobs {
            st = self.shared.batch_done.wait(st).unwrap();
        }
        st.batch = None;
        drop(st);
        drop(submit);

        if batch.panicked.load(Ordering::Relaxed) {
            panic!("executor job panicked");
        }
        slots
            .iter()
            .map(|m| m.lock().unwrap().take().expect("job slot unfilled"))
            .collect()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    last_epoch = st.epoch;
                    if let Some(b) = st.batch.clone() {
                        break b;
                    }
                    // Epoch advanced but the batch already drained and was
                    // cleared — keep waiting for the next one.
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        run_jobs(shared, &batch);
    }
}

/// Claim and run jobs from `batch` until its counter is exhausted.
fn run_jobs(shared: &Shared, batch: &Batch) {
    loop {
        let i = batch.next.fetch_add(1, Ordering::Relaxed);
        if i >= batch.n_jobs {
            return;
        }
        IN_EXECUTOR_JOB.with(|f| f.set(true));
        let ok = catch_unwind(AssertUnwindSafe(|| (batch.job)(i)));
        IN_EXECUTOR_JOB.with(|f| f.set(false));
        if ok.is_err() {
            batch.panicked.store(true, Ordering::Relaxed);
        }
        shared.jobs_completed.fetch_add(1, Ordering::Relaxed);
        let done = batch.completed.fetch_add(1, Ordering::AcqRel) + 1;
        if done == batch.n_jobs {
            // Take the state lock before notifying so the submitter can't
            // miss the wakeup between its check and its wait.
            let _guard = shared.state.lock().unwrap();
            shared.batch_done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_returns_in_job_order() {
        let ex = Executor::new(4);
        let out = ex.scatter(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(ex.jobs_completed(), 100);
        assert_eq!(ex.batches(), 1);
    }

    #[test]
    fn zero_worker_pool_runs_on_submitter() {
        let ex = Executor::new(0);
        let out = ex.scatter(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_is_noop() {
        let ex = Executor::new(2);
        let out: Vec<usize> = ex.scatter(0, |i| i);
        assert!(out.is_empty());
        assert_eq!(ex.batches(), 0);
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let ex = Executor::new(3);
        for round in 0..50u64 {
            let out = ex.scatter(8, move |i| round * 100 + i as u64);
            assert_eq!(out, (0..8).map(|i| round * 100 + i).collect::<Vec<_>>());
        }
        assert_eq!(ex.batches(), 50);
        assert_eq!(ex.jobs_completed(), 400);
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let ex = Arc::new(Executor::new(2));
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let ex = Arc::clone(&ex);
                scope.spawn(move || {
                    let out = ex.scatter(20, move |i| t * 1000 + i as u64);
                    assert_eq!(out, (0..20).map(|i| t * 1000 + i).collect::<Vec<_>>());
                });
            }
        });
        assert_eq!(ex.jobs_completed(), 80);
    }

    #[test]
    fn nested_scatter_fails_loudly_instead_of_deadlocking() {
        let ex = Arc::new(Executor::new(1));
        let inner = Arc::clone(&ex);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.scatter(2, move |_| inner.scatter(1, |i| i))
        }));
        assert!(r.is_err(), "nested scatter must panic, not hang");
        // The pool survives.
        assert_eq!(ex.scatter(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn job_panic_propagates_to_submitter() {
        let ex = Executor::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.scatter(10, |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err());
        // The pool survives a panicked batch.
        assert_eq!(ex.scatter(3, |i| i), vec![0, 1, 2]);
    }
}
