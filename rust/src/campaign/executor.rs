//! Persistent scatter/gather worker pool.
//!
//! Before the campaign API, every call to `evaluate_algorithm` spawned a
//! fresh `std::thread::scope` — the meta-tuning path re-created the whole
//! pool for each of its ~150 hyperparameter evaluations. The [`Executor`]
//! keeps one set of workers alive for the process (or a scoped pool for
//! tests/benches) and hands them batches of independent jobs:
//!
//! * **scatter** — jobs are claimed from a shared atomic counter, so work
//!   distribution is dynamic (a slow space doesn't idle the other
//!   workers) exactly as with the old per-call scope;
//! * **gather** — every job writes its own slot; results come back in job
//!   order, so downstream scoring is independent of thread scheduling.
//!
//! ## Fault isolation
//!
//! Every job runs under `catch_unwind`: a panicking job never takes the
//! batch (or the pool) down with it. [`Executor::scatter_result`] is the
//! fault-isolating gather — the batch always drains, and each slot comes
//! back as `Ok(T)` or a typed [`JobFailure`] carrying the job index and
//! the captured panic payload. The legacy [`Executor::scatter`] is a
//! thin wrapper that re-raises the first (lowest-index) failure after
//! the drain, preserving `thread::scope` semantics for callers that
//! treat a panic as fatal — now with the original payload message
//! instead of a bare "executor job panicked".
//!
//! Determinism is unaffected by pooling: job payloads derive their RNG
//! streams from the job index, never from the executing thread.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

thread_local! {
    /// True while this thread is executing an executor job. `scatter` is
    /// not reentrant (the submit lock is held for the whole batch); a
    /// nested call from inside a job would deadlock, so it panics with a
    /// diagnosis instead.
    static IN_EXECUTOR_JOB: Cell<bool> = const { Cell::new(false) };
}

/// One published batch of jobs.
struct Batch {
    n_jobs: usize,
    /// Next job index to claim.
    next: AtomicUsize,
    /// Jobs finished (success or panic).
    completed: AtomicUsize,
    /// Runs job `i`; the closure writes its result into slot `i`.
    job: Box<dyn Fn(usize) + Send + Sync>,
}

struct State {
    batch: Option<Arc<Batch>>,
    /// Bumped on every publish so sleeping workers can tell a new batch
    /// from a spurious wakeup.
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    batch_done: Condvar,
    jobs_completed: AtomicU64,
    batches: AtomicU64,
}

/// A persistent worker pool executing scatter/gather batches.
pub struct Executor {
    shared: Arc<Shared>,
    /// Serializes batches: one in flight at a time (batches from
    /// concurrent tests/threads queue up here).
    submit: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl Executor {
    /// Pool with an explicit worker count (0 = jobs run on the submitting
    /// thread only, still correct — useful for tests).
    pub fn new(workers: usize) -> Executor {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batch: None,
                epoch: 0,
                shutdown: false,
            }),
            work_ready: Condvar::new(),
            batch_done: Condvar::new(),
            jobs_completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("tt-exec-{i}"))
                    .spawn(move || worker_loop(&shared))
                    // lint: allow(W03, reason = "thread spawn failure at startup is unrecoverable")
                    .expect("spawn executor worker")
            })
            .collect();
        Executor {
            shared,
            submit: Mutex::new(()),
            workers,
            handles,
        }
    }

    /// The process-wide shared pool (sized to the available parallelism),
    /// created on first use and kept alive for the process lifetime.
    pub fn global() -> Arc<Executor> {
        static GLOBAL: OnceLock<Arc<Executor>> = OnceLock::new();
        Arc::clone(GLOBAL.get_or_init(|| {
            let workers = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4);
            Arc::new(Executor::new(workers))
        }))
    }

    /// Number of pool workers (the submitting thread also participates in
    /// every batch, so effective parallelism is `workers + 1`).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Total jobs completed over the executor's lifetime.
    pub fn jobs_completed(&self) -> u64 {
        self.shared.jobs_completed.load(Ordering::Relaxed)
    }

    /// Total batches executed over the executor's lifetime.
    pub fn batches(&self) -> u64 {
        self.shared.batches.load(Ordering::Relaxed)
    }

    /// Run `n_jobs` independent jobs and gather their results in job
    /// order. Blocks until every job finished; panics (after the batch
    /// drains) if any job panicked, mirroring `thread::scope` semantics —
    /// the re-raised panic carries the first (lowest-index) failing job's
    /// captured payload. Fault-tolerant callers use
    /// [`Executor::scatter_result`] instead.
    ///
    /// Not reentrant: a job (or an observer it calls) must not scatter on
    /// any executor from inside the job — the calling batch would wait on
    /// the nested one while holding its slot. Detected and panicked with
    /// a diagnosis rather than deadlocking.
    pub fn scatter<T, F>(&self, n_jobs: usize, job: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let mut out = Vec::with_capacity(n_jobs);
        let mut first_failure: Option<JobFailure> = None;
        for r in self.scatter_result(n_jobs, job) {
            match r {
                Ok(v) => out.push(v),
                Err(f) => {
                    if first_failure.is_none() {
                        first_failure = Some(f);
                    }
                }
            }
        }
        if let Some(f) = first_failure {
            // lint: allow(W03, reason = "re-raises a worker panic on the caller thread")
            panic!("executor job {} panicked: {}", f.job, f.message);
        }
        out
    }

    /// Fault-isolating scatter: run `n_jobs` independent jobs and gather
    /// a per-slot `Result` in job order. Panicking jobs are contained by
    /// `catch_unwind` — the batch always drains, the pool stays usable,
    /// and each failed slot carries a [`JobFailure`] with the job index
    /// and the captured panic payload. Same reentrancy contract as
    /// [`Executor::scatter`].
    pub fn scatter_result<T, F>(&self, n_jobs: usize, job: F) -> Vec<Result<T, JobFailure>>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        if IN_EXECUTOR_JOB.with(|f| f.get()) {
            // lint: allow(W03, reason = "documented contract: scatter must not be nested")
            panic!(
                "Executor::scatter called from inside an executor job; nested \
                 scatter/Campaign::run would deadlock the pool — restructure so \
                 campaigns are submitted from the driving thread"
            );
        }
        if n_jobs == 0 {
            return Vec::new();
        }
        type Slot<T> = Mutex<Option<Result<T, JobFailure>>>;
        let slots: Arc<Vec<Slot<T>>> = Arc::new((0..n_jobs).map(|_| Mutex::new(None)).collect());
        let write_slots = Arc::clone(&slots);
        let batch = Arc::new(Batch {
            n_jobs,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            job: Box::new(move |i| {
                // The inner catch keeps the payload; run_jobs' outer
                // catch_unwind stays as a backstop for anything that
                // escapes (e.g. a panic while writing the slot).
                let r = catch_unwind(AssertUnwindSafe(|| job(i))).map_err(|payload| JobFailure {
                    job: i,
                    message: panic_message(payload.as_ref()),
                });
                *write_slots[i].lock().unwrap() = Some(r);
            }),
        });

        let submit = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.batch = Some(Arc::clone(&batch));
            self.shared.work_ready.notify_all();
        }
        self.shared.batches.fetch_add(1, Ordering::Relaxed);
        // The submitting thread drains the same counter: a zero-worker
        // executor still completes, and small batches don't wait on pool
        // wakeup latency.
        run_jobs(&self.shared, &batch);
        let mut st = self.shared.state.lock().unwrap();
        while batch.completed.load(Ordering::Acquire) < n_jobs {
            st = self.shared.batch_done.wait(st).unwrap();
        }
        st.batch = None;
        drop(st);
        drop(submit);

        slots
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let slot = match m.lock() {
                    Ok(mut s) => s.take(),
                    Err(poisoned) => poisoned.into_inner().take(),
                };
                slot.unwrap_or_else(|| {
                    Err(JobFailure {
                        job: i,
                        message: "executor job aborted before writing its slot".into(),
                    })
                })
            })
            .collect()
    }
}

/// A contained job panic from [`Executor::scatter_result`]: which job
/// failed and the captured panic payload (the `&str`/`String` message
/// when the payload was one, a placeholder otherwise).
#[derive(Clone, Debug)]
pub struct JobFailure {
    /// Index of the failed job within its batch.
    pub job: usize,
    /// Captured panic payload message.
    pub message: String,
}

impl std::fmt::Display for JobFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {} panicked: {}", self.job, self.message)
    }
}

/// Extract the human-readable message from a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    last_epoch = st.epoch;
                    if let Some(b) = st.batch.clone() {
                        break b;
                    }
                    // Epoch advanced but the batch already drained and was
                    // cleared — keep waiting for the next one.
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        run_jobs(shared, &batch);
    }
}

/// Claim and run jobs from `batch` until its counter is exhausted.
fn run_jobs(shared: &Shared, batch: &Batch) {
    loop {
        let i = batch.next.fetch_add(1, Ordering::Relaxed);
        if i >= batch.n_jobs {
            return;
        }
        IN_EXECUTOR_JOB.with(|f| f.set(true));
        // Backstop: the scatter closure already catches job panics to
        // capture their payloads; this outer catch only guards batch
        // bookkeeping (the drain must complete even if slot-writing
        // itself paniced — the gather reports such slots as failures).
        let _ = catch_unwind(AssertUnwindSafe(|| (batch.job)(i)));
        IN_EXECUTOR_JOB.with(|f| f.set(false));
        shared.jobs_completed.fetch_add(1, Ordering::Relaxed);
        let done = batch.completed.fetch_add(1, Ordering::AcqRel) + 1;
        if done == batch.n_jobs {
            // Take the state lock before notifying so the submitter can't
            // miss the wakeup between its check and its wait.
            let _guard = shared.state.lock().unwrap();
            shared.batch_done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_returns_in_job_order() {
        let ex = Executor::new(4);
        let out = ex.scatter(100, |i| i * 3);
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(ex.jobs_completed(), 100);
        assert_eq!(ex.batches(), 1);
    }

    #[test]
    fn zero_worker_pool_runs_on_submitter() {
        let ex = Executor::new(0);
        let out = ex.scatter(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_is_noop() {
        let ex = Executor::new(2);
        let out: Vec<usize> = ex.scatter(0, |i| i);
        assert!(out.is_empty());
        assert_eq!(ex.batches(), 0);
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let ex = Executor::new(3);
        for round in 0..50u64 {
            let out = ex.scatter(8, move |i| round * 100 + i as u64);
            assert_eq!(out, (0..8).map(|i| round * 100 + i).collect::<Vec<_>>());
        }
        assert_eq!(ex.batches(), 50);
        assert_eq!(ex.jobs_completed(), 400);
    }

    #[test]
    fn concurrent_submitters_serialize() {
        let ex = Arc::new(Executor::new(2));
        std::thread::scope(|scope| {
            for t in 0..4u64 {
                let ex = Arc::clone(&ex);
                scope.spawn(move || {
                    let out = ex.scatter(20, move |i| t * 1000 + i as u64);
                    assert_eq!(out, (0..20).map(|i| t * 1000 + i).collect::<Vec<_>>());
                });
            }
        });
        assert_eq!(ex.jobs_completed(), 80);
    }

    #[test]
    fn nested_scatter_fails_loudly_instead_of_deadlocking() {
        let ex = Arc::new(Executor::new(1));
        let inner = Arc::clone(&ex);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.scatter(2, move |_| inner.scatter(1, |i| i))
        }));
        assert!(r.is_err(), "nested scatter must panic, not hang");
        // The pool survives.
        assert_eq!(ex.scatter(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn job_panic_propagates_to_submitter_with_payload() {
        let ex = Executor::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            ex.scatter(10, |i| {
                if i == 5 {
                    panic!("boom");
                }
                i
            })
        }));
        // The re-raised panic carries the original payload, not a bare
        // "executor job panicked".
        let payload = r.expect_err("scatter must re-raise the job panic");
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("boom"), "payload lost: {msg:?}");
        assert!(msg.contains("job 5"), "job index lost: {msg:?}");
        // The pool survives a panicked batch.
        assert_eq!(ex.scatter(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn scatter_result_isolates_failures_and_drains() {
        let ex = Executor::new(3);
        let faulty = [2usize, 5, 7];
        let out = ex.scatter_result(10, move |i| {
            if faulty.contains(&i) {
                panic!("injected failure in job {i}");
            }
            i * 10
        });
        assert_eq!(out.len(), 10, "batch must drain every slot");
        for (i, r) in out.iter().enumerate() {
            if faulty.contains(&i) {
                let f = r.as_ref().expect_err("faulty slot must be Err");
                assert_eq!(f.job, i);
                assert!(
                    f.message.contains(&format!("injected failure in job {i}")),
                    "payload lost: {}",
                    f.message
                );
            } else {
                assert_eq!(*r.as_ref().unwrap(), i * 10);
            }
        }
        assert_eq!(ex.jobs_completed(), 10, "failed jobs still count as drained");
        // The pool is immediately reusable after a faulted batch.
        let again = ex.scatter_result(4, |i| i);
        assert!(again.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn scatter_result_all_jobs_failing_still_drains() {
        let ex = Executor::new(2);
        let out = ex.scatter_result(6, |i| -> usize { panic!("fail {i}") });
        assert_eq!(out.len(), 6);
        for (i, r) in out.iter().enumerate() {
            let f = r.as_ref().unwrap_err();
            assert_eq!(f.job, i);
            assert!(f.message.contains(&format!("fail {i}")));
        }
        assert_eq!(ex.scatter(2, |i| i), vec![0, 1], "pool survives");
    }

    #[test]
    fn scatter_result_captures_string_payloads() {
        let ex = Executor::new(0);
        let out = ex.scatter_result(1, |_| -> usize {
            // A formatted (heap-allocated String) payload.
            panic!("formatted {} payload", 42);
        });
        let f = out[0].as_ref().unwrap_err();
        assert_eq!(f.message, "formatted 42 payload");
    }
}
