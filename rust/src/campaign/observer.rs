//! Campaign progress events.
//!
//! A [`Campaign`](super::Campaign) reports its life cycle through an
//! [`Observer`]: batch consumers (exhaustive sweeps, experiments) attach
//! the no-op [`NullObserver`], the CLI attaches a [`LogObserver`], and
//! tests attach collectors to assert event ordering. All methods have
//! empty default bodies, so observers implement only what they need.
//!
//! Threading: `campaign_started`, `space_started`, `space_scored`,
//! `config_scored` and `campaign_finished` are emitted from the
//! submitting thread in deterministic order; `run_started` and
//! `trace_completed` are emitted from pool workers as runs execute, so
//! their relative order across (space, repeat) pairs depends on
//! scheduling. The guaranteed partial order: every `space_started`
//! precedes every `run_started`/`trace_completed` of the campaign, and
//! every `trace_completed` precedes every `space_scored`.
//!
//! Higher-level drivers reuse the same trait: the registry sweep emits
//! the `sweep_*` family and the metasweep the `meta_*` family, both
//! strictly ordered from their driving thread (see the per-family
//! comments below), wrapped around the campaign events of the runs they
//! launch.

/// Receives campaign progress events. Implementations must be cheap and
/// non-blocking — `trace_completed` fires on the tuning hot path.
pub trait Observer: Send + Sync {
    /// A campaign began: algorithm, stable hyperparameter key, number of
    /// prepared spaces and repeats per space.
    fn campaign_started(&self, _algo: &str, _hp_key: &str, _spaces: usize, _repeats: usize) {}

    /// A search space is about to be tuned (emitted once per space, in
    /// space order, before any run starts).
    fn space_started(&self, _space_idx: usize, _label: &str, _budget_seconds: f64) {}

    /// One (space, repeat) tuning run was claimed by a worker.
    fn run_started(&self, _space_idx: usize, _repeat: usize) {}

    /// One tuning run finished with its best value, unique-evaluation
    /// count, and simulated seconds consumed.
    fn trace_completed(
        &self,
        _space_idx: usize,
        _repeat: usize,
        _best: f64,
        _unique_evals: usize,
        _elapsed: f64,
    ) {
    }

    /// A space's repeats were aggregated into its Eq. 2 score curve.
    fn space_scored(&self, _space_idx: usize, _label: &str, _mean_score: f64) {}

    /// A hyperparameter configuration received its aggregate (Eq. 3)
    /// score — emitted by the hypertuning drivers, once per campaign they
    /// run, with the configuration's index in the hyperparameter space.
    fn config_scored(&self, _config_idx: usize, _hp_key: &str, _score: f64) {}

    /// The campaign finished with its scalar score.
    fn campaign_finished(&self, _score: f64, _wallclock_seconds: f64) {}

    // ---- full-registry sweep events (`hypertuning::sweep`) ------------------
    // Emitted from the sweep-driving thread, strictly ordered:
    // `sweep_started`, then per optimizer `sweep_optimizer_started` ..
    // campaign/config events .. `sweep_optimizer_finished`, and finally
    // `sweep_finished`.

    /// A full-registry sweep began: number of grid-bearing optimizers it
    /// will hypertune and the repeats per (configuration, space).
    fn sweep_started(&self, _optimizers: usize, _repeats: usize) {}

    /// One optimizer's sweep leg began: its index in the sweep, name,
    /// and limited-grid size.
    fn sweep_optimizer_started(&self, _idx: usize, _algo: &str, _configs: usize) {}

    /// One optimizer's sweep leg finished with its schema-default and
    /// hypertuned-best Eq. 3 scores.
    fn sweep_optimizer_finished(
        &self,
        _idx: usize,
        _algo: &str,
        _default_score: f64,
        _best_score: f64,
    ) {
    }

    /// The sweep finished with its mean improvement percentage.
    fn sweep_finished(&self, _mean_improvement_pct: f64, _wallclock_seconds: f64) {}

    // ---- metasweep events (`hypertuning::metasweep`) ------------------------
    // Emitted from the metasweep-driving thread, strictly ordered:
    // `meta_sweep_started`, then per (strategy, target) leg
    // `meta_leg_started` .. `meta_eval_scored`* .. `meta_leg_finished`,
    // and finally `meta_sweep_finished`. Every `meta_eval_scored` fires
    // after the underlying campaign's `campaign_finished`; legs replayed
    // from a resumed envelope emit `meta_leg_started`/`meta_leg_finished`
    // with no `meta_eval_scored` in between.

    /// A metasweep began: number of strategies raced and the full-budget
    /// repeat count (the cost-unit denominator).
    fn meta_sweep_started(&self, _strategies: usize, _repeats: usize) {}

    /// One (strategy, target) leg began with its grid size and budget in
    /// full-repeat-equivalent units. `target` is an optimizer name, or
    /// `"registry"` for registry-wide strategies.
    fn meta_leg_started(&self, _strategy: &str, _target: &str, _configs: usize, _budget_cost: f64) {
    }

    /// A strategy's fresh (non-memoized) meta-evaluation was scored:
    /// running eval count within the leg, the evaluated hyperparameter
    /// key, the repeats it ran at, and its Eq. 3 score.
    fn meta_eval_scored(
        &self,
        _strategy: &str,
        _target: &str,
        _eval: usize,
        _hp_key: &str,
        _repeats: usize,
        _score: f64,
    ) {
    }

    /// One leg finished: best full-repeat score found, cost actually
    /// spent, and fresh evaluations performed.
    fn meta_leg_finished(
        &self,
        _strategy: &str,
        _target: &str,
        _best_score: f64,
        _spent_cost: f64,
        _evals: usize,
    ) {
    }

    /// The metasweep finished.
    fn meta_sweep_finished(&self, _wallclock_seconds: f64) {}

    // ---- fault-tolerance events (campaign retry, sweep quarantine,
    // checkpointing) ----------------------------------------------------------
    // `leg_retried` is emitted from the campaign-driving thread between
    // scatter rounds; `leg_failed` from the sweep/metasweep driver when a
    // leg exhausts its retries and is quarantined; `checkpoint_saved`
    // after each successful incremental envelope save.

    /// A failed job/leg is about to be retried: which leg (a
    /// human-readable identity like `"pso[s0r3]"`), the attempt number
    /// being started (2 = first retry), the retry policy's cap, and the
    /// captured error of the previous attempt. Retries re-derive the
    /// job's RNG stream from its identity, so a transient fault replays
    /// the original trace bitwise.
    fn leg_retried(&self, _leg: &str, _attempt: usize, _max_attempts: usize, _error: &str) {}

    /// A leg exhausted its retry budget and was quarantined into the
    /// envelope's `failed_legs` instead of aborting the sweep.
    fn leg_failed(&self, _leg: &str, _error: &str, _attempts: usize) {}

    /// An incremental checkpoint of the sweep/metasweep envelope was
    /// atomically saved after `completed_legs` finished legs.
    fn checkpoint_saved(&self, _path: &str, _completed_legs: usize) {}
}

/// Ignores every event (the default for batch/library use).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullObserver;

impl Observer for NullObserver {}

/// Logs campaign progress through the crate logger: space/campaign
/// milestones at info level, per-run completions at debug level (visible
/// with `--verbose`).
#[derive(Clone, Copy, Debug, Default)]
pub struct LogObserver;

impl Observer for LogObserver {
    fn campaign_started(&self, algo: &str, hp_key: &str, spaces: usize, repeats: usize) {
        let hp = if hp_key.is_empty() { "defaults" } else { hp_key };
        crate::log_info!("campaign {algo} [{hp}]: {spaces} spaces x {repeats} repeats");
    }

    fn space_started(&self, space_idx: usize, label: &str, budget_seconds: f64) {
        crate::log_debug!("  space {space_idx} {label}: budget {budget_seconds:.1}s");
    }

    fn trace_completed(
        &self,
        space_idx: usize,
        repeat: usize,
        best: f64,
        unique_evals: usize,
        elapsed: f64,
    ) {
        crate::log_debug!(
            "  space {space_idx} repeat {repeat}: best {best:.6} \
             ({unique_evals} unique evals, {elapsed:.1}s simulated)"
        );
    }

    fn space_scored(&self, _space_idx: usize, label: &str, mean_score: f64) {
        crate::log_info!("  {label}: mean score {mean_score:.3}");
    }

    fn config_scored(&self, config_idx: usize, hp_key: &str, score: f64) {
        crate::log_info!("config {config_idx} [{hp_key}]: score {score:.3}");
    }

    fn campaign_finished(&self, score: f64, wallclock_seconds: f64) {
        crate::log_info!("campaign done: score {score:.3} in {wallclock_seconds:.1}s");
    }

    fn sweep_started(&self, optimizers: usize, repeats: usize) {
        crate::log_info!("registry sweep: {optimizers} optimizers x {repeats} repeats");
    }

    fn sweep_optimizer_started(&self, idx: usize, algo: &str, configs: usize) {
        crate::log_info!("sweep [{idx}] {algo}: {configs} hyperparameter configs");
    }

    fn sweep_optimizer_finished(&self, idx: usize, algo: &str, default: f64, best: f64) {
        crate::log_info!("sweep [{idx}] {algo}: default {default:.3} -> best {best:.3}");
    }

    fn sweep_finished(&self, mean_improvement_pct: f64, wallclock_seconds: f64) {
        crate::log_info!(
            "registry sweep done: mean improvement {mean_improvement_pct:+.1}% \
             in {wallclock_seconds:.1}s"
        );
    }

    fn meta_sweep_started(&self, strategies: usize, repeats: usize) {
        crate::log_info!("metasweep: {strategies} strategies, {repeats} full repeats");
    }

    fn meta_leg_started(&self, strategy: &str, target: &str, configs: usize, budget_cost: f64) {
        crate::log_info!(
            "metasweep {strategy}/{target}: {configs} configs, budget {budget_cost:.1}"
        );
    }

    fn meta_eval_scored(
        &self,
        strategy: &str,
        target: &str,
        eval: usize,
        hp_key: &str,
        repeats: usize,
        score: f64,
    ) {
        let hp = if hp_key.is_empty() { "defaults" } else { hp_key };
        crate::log_debug!(
            "  {strategy}/{target} eval {eval} [{hp}] @{repeats}r: score {score:.3}"
        );
    }

    fn meta_leg_finished(
        &self,
        strategy: &str,
        target: &str,
        best_score: f64,
        spent_cost: f64,
        evals: usize,
    ) {
        crate::log_info!(
            "metasweep {strategy}/{target}: best {best_score:.3} \
             ({evals} evals, {spent_cost:.1} units)"
        );
    }

    fn meta_sweep_finished(&self, wallclock_seconds: f64) {
        crate::log_info!("metasweep done in {wallclock_seconds:.1}s");
    }

    fn leg_retried(&self, leg: &str, attempt: usize, max_attempts: usize, error: &str) {
        crate::log_warn!("retrying {leg} (attempt {attempt}/{max_attempts}): {error}");
    }

    fn leg_failed(&self, leg: &str, error: &str, attempts: usize) {
        crate::log_warn!("quarantined {leg} after {attempts} attempt(s): {error}");
    }

    fn checkpoint_saved(&self, path: &str, completed_legs: usize) {
        crate::log_debug!("checkpoint: {completed_legs} legs -> {path}");
    }
}
