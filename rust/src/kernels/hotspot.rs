//! Hotspot: thermal simulation stencil (Rodinia-style) on an 8192² grid.
//!
//! Iteratively solves the temperature diffusion equation from power and
//! temperature inputs; a measurement runs the full 1000-timestep
//! simulation. The key tunable is *temporal tiling*: executing several
//! timesteps per kernel launch trades redundant halo computation for DRAM
//! traffic — a classically rugged, bandwidth-bound tuning space (and the
//! application all four algorithms struggled with in the paper's Fig. 4).
//! Long per-configuration runtimes also make hotspot one of the most
//! expensive spaces to brute-force, as in the paper's Table II.

use super::{geti, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::*;
use crate::searchspace::{Constraint, SearchSpace, TunableParam, Value};
use crate::error::Result;

const W: f64 = 8192.0;
const H: f64 = 8192.0;
const FLOP_PER_POINT: f64 = 15.0;
/// Total simulated timesteps per measurement.
const N_STEPS: f64 = 1000.0;

const BSX: usize = 0;
const BSY: usize = 1;
const TSX: usize = 2;
const TTF: usize = 3;
const SH_POWER: usize = 4;
const BPS: usize = 5; // blocks-per-SM launch-bounds hint

pub fn build() -> Result<Kernel> {
    let params = vec![
        TunableParam::new("block_size_x", vec![8i64, 16, 32, 64, 128, 256]),
        TunableParam::new("block_size_y", vec![2i64, 4, 8, 16, 32]),
        TunableParam::new("tile_size_x", vec![1i64, 2, 4, 8]),
        TunableParam::new("temporal_tiling_factor", vec![1i64, 2, 3, 4, 6, 8, 10]),
        TunableParam::new("sh_power", vec![0i64, 1]),
        TunableParam::new("blocks_per_sm", vec![0i64, 2, 4, 8]),
    ];
    let constraints = vec![
        Constraint::parse("block_size_x * block_size_y >= 32")?,
        Constraint::parse("block_size_x * block_size_y <= 1024")?,
        // The temporal halo must leave a positive output tile.
        Constraint::parse("block_size_x * tile_size_x - 2 * temporal_tiling_factor >= 8")?,
        Constraint::parse(
            "block_size_y - 2 * temporal_tiling_factor >= 1 || block_size_y * 4 > temporal_tiling_factor * 8",
        )?,
        // Staged temperature+power planes must fit LDS.
        Constraint::parse(
            "(block_size_x * tile_size_x + 2 * temporal_tiling_factor) * (block_size_y + 2 * temporal_tiling_factor) * 4 * (1 + sh_power) <= 65536",
        )?,
        // A launch-bounds hint must be satisfiable thread-count-wise.
        Constraint::parse(
            "blocks_per_sm == 0 || blocks_per_sm * block_size_x * block_size_y <= 2048",
        )?,
    ];
    let space = SearchSpace::build("hotspot", params, constraints)?;
    Ok(Kernel {
        name: "hotspot",
        problem: format!("{W}x{H} grid thermal stencil, {N_STEPS} timesteps, fp32"),
        space: std::sync::Arc::new(space),
        extract,
    })
}

fn extract(values: &[Value]) -> Features {
    let bsx = geti(values, BSX);
    let bsy = geti(values, BSY);
    let tsx = geti(values, TSX);
    let ttf = geti(values, TTF);
    let sh_power = geti(values, SH_POWER);
    let bps = geti(values, BPS);

    let tpb = bsx * bsy;
    let out_w = bsx * tsx - 2.0 * ttf;
    let out_h = (bsy - 2.0 * ttf).max(bsy * 0.25);
    // One launch covers the grid; the full simulation needs N_STEPS/ttf
    // launches (each advancing ttf steps).
    let launches = (N_STEPS / ttf).ceil();
    let blocks = (W / out_w).ceil() * (H / out_h).ceil();

    // Redundant halo compute inflates FLOPs per launch.
    let tile_area = (bsx * tsx) * bsy;
    let useful_area = out_w * out_h;
    let redundancy = tile_area / useful_area;
    let flops = W * H * FLOP_PER_POINT * N_STEPS * redundancy;

    // Traffic per launch: temp in+out, power in, plus block halos; temporal
    // tiling amortizes it over ttf steps.
    let halo_bytes =
        blocks * ((bsx * tsx + 2.0 * ttf) * (bsy + 2.0 * ttf) - tile_area).max(0.0) * 4.0;
    let bytes = (W * H * 4.0 * 3.0 + halo_bytes) * launches;

    let smem = (bsx * tsx + 2.0 * ttf) * (bsy + 2.0 * ttf) * 4.0 * (1.0 + sh_power);
    // A launch-bounds hint caps register allocation to keep `bps` blocks
    // resident, trading spilling (handled as unroll penalty) for occupancy.
    let regs_natural = 24.0 + 4.0 * tsx + 2.0 * ttf;
    let regs = if bps > 0.0 {
        regs_natural.min((65536.0 / (bps * tpb)).floor())
    } else {
        regs_natural
    };

    let mut f = [0f32; NUM_FEATURES];
    f[F_FLOPS] = flops as f32;
    f[F_BYTES] = bytes as f32;
    f[F_TPB] = tpb as f32;
    f[F_REGS] = regs.min(255.0) as f32;
    f[F_SMEM] = smem as f32;
    f[F_BLOCKS] = (blocks * launches).min(f32::MAX as f64) as f32;
    f[F_VECW] = tsx as f32;
    f[F_UNROLL] = ttf.min(16.0) as f32;
    f[F_COAL] = ((bsx / 256.0).min(1.0) * 0.4 + 0.6) as f32;
    f[F_CACHE] = (sh_power * 0.8) as f32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_builds() {
        let k = build().unwrap();
        assert!(k.space().len() > 1000, "{}", k.space().len());
    }

    #[test]
    fn temporal_tiling_amortizes_traffic() {
        let k = build().unwrap();
        let s = k.space();
        // Find configs differing only in ttf (value idx 0 vs later).
        for i in 0..s.len() {
            let enc = s.encoded(i);
            if enc[TTF] == 0 {
                let mut e2 = enc.to_vec();
                e2[TTF] = 3;
                if let Some(j) = s.index_of(&e2) {
                    let fi = k.features(i);
                    let fj = k.features(j);
                    // More ttf -> more redundant flops but less traffic.
                    assert!(fj[F_FLOPS] > fi[F_FLOPS]);
                    assert!(fj[F_BYTES] < fi[F_BYTES]);
                    return;
                }
            }
        }
        panic!("no ttf pair found");
    }

    #[test]
    fn launch_bounds_hint_caps_registers() {
        let k = build().unwrap();
        let s = k.space();
        let mut checked = 0usize;
        let mut capped = 0usize;
        for i in 0..s.len() {
            let v = s.values(i);
            let bps = v[BPS].as_i64().unwrap();
            if bps == 0 {
                continue;
            }
            let tpb = (v[BSX].as_i64().unwrap() * v[BSY].as_i64().unwrap()) as f64;
            let cap = (65536.0 / (bps as f64 * tpb)).floor();
            let regs = k.features(i)[F_REGS] as f64;
            assert!(regs <= cap + 1e-6, "config {i}: regs {regs} > cap {cap}");
            checked += 1;
            // Count configs where the hint actually bites.
            let v = s.values(i);
            let natural = 24.0
                + 4.0 * v[TSX].as_i64().unwrap() as f64
                + 2.0 * v[TTF].as_i64().unwrap() as f64;
            if cap < natural {
                capped += 1;
            }
        }
        assert!(checked > 100);
        assert!(capped > 10, "the hint never binds ({capped})");
    }

    #[test]
    fn bandwidth_bound_regime() {
        let k = build().unwrap();
        let f = k.features(0);
        assert!(f[F_FLOPS] / f[F_BYTES] < 30.0);
    }
}
