//! GEMM: dense matrix–matrix multiplication, C = alpha*A*B + beta*C.
//!
//! Modeled after the CLBlast kernel the paper tunes: workgroup tile sizes
//! (MWG, NWG, KWG), thread-block shape (MDIMC, NDIMC), per-thread vector
//! widths (VWM, VWN) and the shared-memory staging toggles (SA, SB). The
//! constraints are the classic CLBlast divisibility and capacity rules.
//! Compute-bound at M = N = K = 4096.

use super::{geti, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::*;
use crate::searchspace::{Constraint, SearchSpace, TunableParam, Value};
use crate::error::Result;

const M: f64 = 4096.0;
const N: f64 = 4096.0;
const K: f64 = 4096.0;

// Parameter order (indices into the values slice).
const MWG: usize = 0;
const NWG: usize = 1;
const KWG: usize = 2;
const MDIMC: usize = 3;
const NDIMC: usize = 4;
const VWM: usize = 5;
const VWN: usize = 6;
const SA: usize = 7;
const SB: usize = 8;

pub fn build() -> Result<Kernel> {
    let params = vec![
        TunableParam::new("MWG", vec![16i64, 32, 64, 128]),
        TunableParam::new("NWG", vec![16i64, 32, 64, 128]),
        TunableParam::new("KWG", vec![16i64, 32]),
        TunableParam::new("MDIMC", vec![8i64, 16, 32]),
        TunableParam::new("NDIMC", vec![8i64, 16, 32]),
        TunableParam::new("VWM", vec![1i64, 2, 4, 8]),
        TunableParam::new("VWN", vec![1i64, 2, 4, 8]),
        TunableParam::new("SA", vec![0i64, 1]),
        TunableParam::new("SB", vec![0i64, 1]),
    ];
    let constraints = vec![
        // Work distribution must divide the workgroup tile.
        Constraint::parse("MWG % (MDIMC * VWM) == 0")?,
        Constraint::parse("NWG % (NDIMC * VWN) == 0")?,
        // Thread block between one warp and the hardware limit.
        Constraint::parse("MDIMC * NDIMC >= 32 && MDIMC * NDIMC <= 1024")?,
        // KWG unrolling must cover the staging strides.
        Constraint::parse("KWG % VWM == 0 && KWG % VWN == 0")?,
        // Shared-memory staging must fit the smallest LDS (64 KiB).
        Constraint::parse("(SA * MWG + SB * NWG) * KWG * 4 <= 65536")?,
    ];
    let space = SearchSpace::build("gemm", params, constraints)?;
    Ok(Kernel {
        name: "gemm",
        problem: format!("C[{M}x{N}] = A[{M}x{K}] * B[{K}x{N}], fp32"),
        space: std::sync::Arc::new(space),
        extract,
    })
}

fn extract(values: &[Value]) -> Features {
    let mwg = geti(values, MWG);
    let nwg = geti(values, NWG);
    let kwg = geti(values, KWG);
    let mdimc = geti(values, MDIMC);
    let ndimc = geti(values, NDIMC);
    let vwm = geti(values, VWM);
    let vwn = geti(values, VWN);
    let sa = geti(values, SA);
    let sb = geti(values, SB);

    let tpb = mdimc * ndimc;
    // Per-thread accumulator tile + staging pointers.
    let wpt_m = mwg / mdimc;
    let wpt_n = nwg / ndimc;
    let regs = (16.0 + wpt_m * wpt_n + 2.0 * (vwm + vwn)).min(255.0);
    let smem = (sa * mwg + sb * nwg) * kwg * 4.0;
    let blocks = (M / mwg) * (N / nwg);

    let flops = 2.0 * M * N * K;
    // Tiled traffic: each column-panel of C re-reads A (and row-panel
    // re-reads B); skipping shared-memory staging costs extra traffic.
    let a_bytes = M * K * 4.0 * (N / nwg) * if sa > 0.0 { 1.0 } else { 1.6 };
    let b_bytes = N * K * 4.0 * (M / mwg) * if sb > 0.0 { 1.0 } else { 1.6 };
    let c_bytes = M * N * 4.0 * 2.0;
    // L2 captures most of the panel re-reads; scale to effective DRAM traffic.
    let bytes = (a_bytes + b_bytes) / 48.0 + c_bytes;

    let mut f = [0f32; NUM_FEATURES];
    f[F_FLOPS] = flops as f32;
    f[F_BYTES] = bytes as f32;
    f[F_TPB] = tpb as f32;
    f[F_REGS] = regs as f32;
    f[F_SMEM] = smem as f32;
    f[F_BLOCKS] = blocks as f32;
    f[F_VECW] = vwm as f32;
    f[F_UNROLL] = (kwg / 8.0) as f32;
    // Wider M-vectors coalesce the dominant A/C accesses.
    f[F_COAL] = (0.25 + 0.25 * (vwm.log2() + 1.0)).min(1.0) as f32;
    f[F_CACHE] = ((sa + sb) / 2.0) as f32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_size_and_constraints() {
        let k = build().unwrap();
        let s = k.space();
        assert!(s.len() > 500, "{}", s.len());
        for i in (0..s.len()).step_by(7) {
            let v = s.values(i);
            let mwg = v[MWG].as_i64().unwrap();
            let mdimc = v[MDIMC].as_i64().unwrap();
            let vwm = v[VWM].as_i64().unwrap();
            assert_eq!(mwg % (mdimc * vwm), 0);
        }
    }

    #[test]
    fn flops_constant_bytes_vary() {
        let k = build().unwrap();
        let f0 = k.features(0);
        let f1 = k.features(k.space().len() - 1);
        assert_eq!(f0[F_FLOPS], f1[F_FLOPS]);
        assert_ne!(f0[F_BYTES], f1[F_BYTES]);
        // 2*4096^3 ~ 1.37e11
        assert!((f0[F_FLOPS] as f64 - 2.0 * 4096f64.powi(3)).abs() < 1e6);
    }

    #[test]
    fn staging_reduces_traffic() {
        let k = build().unwrap();
        let s = k.space();
        // Find two configs differing only in SA.
        for i in 0..s.len() {
            let vi = s.values(i);
            if vi[SA].as_i64() == Some(1) {
                let mut enc = s.encoded(i).to_vec();
                enc[SA] = 0; // SA value index: values are [0, 1]
                if let Some(j) = s.index_of(&enc) {
                    let fi = k.features(i);
                    let fj = k.features(j);
                    assert!(fi[F_BYTES] < fj[F_BYTES]);
                    return;
                }
            }
        }
        panic!("no SA pair found");
    }
}
