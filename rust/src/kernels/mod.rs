//! The four tuning problems of the paper's benchmark hub.
//!
//! Each kernel definition carries its tunable parameters, the validity
//! constraints of the implementation, and a *feature extractor* that maps
//! a configuration to the resource-usage feature vector the device model
//! consumes (total FLOPs, DRAM traffic, threads/block, registers, shared
//! memory, grid size, vectorization, coalescing, caching, and the two
//! landscape hashes). Features are device-independent; all device effects
//! live in the model itself.
//!
//! The four kernels mirror the paper's: dedispersion and hotspot are
//! bandwidth-bound, convolution and GEMM compute-bound, giving the
//! cross-application diversity that the hyperparameter generalization
//! experiments need.

pub mod gemm;
pub mod convolution;
pub mod hotspot;
pub mod dedispersion;
pub mod synthetic;

use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::{F_HASH_A, F_HASH_B};
use crate::searchspace::{SearchSpace, Value};
use crate::util::rng::mix64;
use crate::error::Result;
use std::sync::Arc;

/// A tuning problem: a named kernel with a search space and a feature
/// extractor for the device model.
pub struct Kernel {
    pub name: &'static str,
    /// Human description of the problem size being tuned.
    pub problem: String,
    space: Arc<SearchSpace>,
    extract: fn(&[Value]) -> Features,
}

impl Kernel {
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Shared handle to the search space (avoids re-enumeration when many
    /// repeated runs need it).
    pub fn space_arc(&self) -> Arc<SearchSpace> {
        Arc::clone(&self.space)
    }

    /// Feature vector for the configuration at `idx`, with the two
    /// landscape hashes filled from a deterministic per-(kernel, config)
    /// stream.
    pub fn features(&self, idx: usize) -> Features {
        let values = self.space.values(idx);
        let mut f = (self.extract)(&values);
        let kernel_seed = str_seed(self.name);
        let cfg_seed = str_seed(&self.space.key(idx));
        let h = mix64(kernel_seed, cfg_seed);
        f[F_HASH_A] = unit_from_bits(h);
        f[F_HASH_B] = unit_from_bits(h.rotate_left(32) ^ 0x5bf0_3635);
        f
    }

    /// All feature vectors, in configuration-index order.
    pub fn all_features(&self) -> Vec<Features> {
        (0..self.space.len()).map(|i| self.features(i)).collect()
    }
}

/// FNV-1a of a string, for seeding per-kernel/config hash streams.
pub fn str_seed(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Map 64 random bits to f32 in [0, 1).
fn unit_from_bits(h: u64) -> f32 {
    (h >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
}

/// All four paper kernels.
pub fn all_kernels() -> Result<Vec<Kernel>> {
    Ok(vec![
        dedispersion::build()?,
        convolution::build()?,
        hotspot::build()?,
        gemm::build()?,
    ])
}

/// Look up a kernel by name (case-insensitive).
pub fn kernel_by_name(name: &str) -> Result<Kernel> {
    match name.to_ascii_lowercase().as_str() {
        "gemm" => gemm::build(),
        "convolution" | "conv" => convolution::build(),
        "hotspot" => hotspot::build(),
        "dedispersion" | "dedisp" => dedispersion::build(),
        "synthetic" => synthetic::build(),
        other => return Err(crate::error::TuneError::UnknownKernel(other.to_string())),
    }
}

/// Shorthand used by the kernel definitions.
pub(crate) fn geti(values: &[Value], i: usize) -> f64 {
    // lint: allow(W03, reason = "kernel definitions pass numeric params only")
    values[i].as_f64().expect("numeric parameter")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::contract::*;
    use crate::gpu::specs::all_devices;
    use crate::perfmodel::analytical::predict_time;

    #[test]
    fn all_kernels_build_with_reasonable_spaces() {
        for k in all_kernels().unwrap() {
            let n = k.space().len();
            assert!(
                (200..200_000).contains(&n),
                "{}: {} valid configs",
                k.name,
                n
            );
            // Constraint filtering really happened.
            assert!((n as u128) < k.space().cartesian_size());
        }
    }

    #[test]
    fn features_are_finite_and_positive() {
        for k in all_kernels().unwrap() {
            for idx in (0..k.space().len()).step_by(17) {
                let f = k.features(idx);
                assert!(f.iter().all(|x| x.is_finite()), "{}@{idx}: {f:?}", k.name);
                assert!(f[F_FLOPS] > 0.0);
                assert!(f[F_BYTES] > 0.0);
                assert!(f[F_TPB] >= 32.0);
                assert!(f[F_BLOCKS] >= 1.0);
                assert!((0.0..1.0).contains(&f[F_HASH_A]));
                assert!((0.0..1.0).contains(&f[F_HASH_B]));
                assert!((0.0..=1.0).contains(&f[F_COAL]));
                assert!((0.0..=1.0).contains(&f[F_CACHE]));
            }
        }
    }

    #[test]
    fn hashes_differ_across_configs() {
        let k = gemm::build().unwrap();
        let a = k.features(0)[F_HASH_A];
        let b = k.features(1)[F_HASH_A];
        assert_ne!(a, b);
        // but stable per config
        assert_eq!(k.features(0)[F_HASH_A], a);
    }

    #[test]
    fn most_configs_launch_on_every_device() {
        // A space where almost nothing is valid on a device would make
        // tuning degenerate; require >= 30% launchable everywhere.
        for k in all_kernels().unwrap() {
            for dev in all_devices() {
                let d = dev.to_vector();
                let total = k.space().len();
                let valid = (0..total)
                    .step_by(3)
                    .filter(|&i| predict_time(&k.features(i), &d) < INVALID_TIME)
                    .count();
                let frac = valid as f64 / (total as f64 / 3.0);
                assert!(
                    frac > 0.3,
                    "{} on {}: only {frac:.2} launchable",
                    k.name,
                    dev.name
                );
            }
        }
    }

    #[test]
    fn intended_boundedness_regimes() {
        // dedispersion/hotspot bandwidth-bound, gemm/convolution
        // compute-bound — separated by median arithmetic intensity
        // (flop/byte); 14 sits between the two clusters and below the
        // machine balance of the bandwidth-rich devices.
        for k in all_kernels().unwrap() {
            let mut intensities: Vec<f64> = (0..k.space().len())
                .step_by(5)
                .map(|i| {
                    let f = k.features(i);
                    f[F_FLOPS] as f64 / f[F_BYTES] as f64
                })
                .collect();
            intensities.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = intensities[intensities.len() / 2];
            match k.name {
                "gemm" | "convolution" => {
                    assert!(med > 14.0, "{} intensity {med}", k.name)
                }
                "dedispersion" | "hotspot" => {
                    assert!(med < 14.0, "{} intensity {med}", k.name)
                }
                _ => {}
            }
        }
    }

    #[test]
    fn kernel_lookup() {
        assert!(kernel_by_name("GEMM").is_ok());
        assert!(kernel_by_name("conv").is_ok());
        assert!(kernel_by_name("nope").is_err());
    }
}
