//! 2D convolution: 15×15 stencil filter over a 4096×4096 image.
//!
//! Modeled after the Kernel Tuner convolution example the paper uses:
//! 2D thread blocks, per-thread output tiling, optional shared-memory
//! input staging with padding (bank-conflict avoidance), and read-only
//! cache usage. Compute-bound: 225 MACs per output pixel.

use super::{geti, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::*;
use crate::searchspace::{Constraint, SearchSpace, TunableParam, Value};
use crate::error::Result;

const W: f64 = 4096.0;
const H: f64 = 4096.0;
const FILTER: f64 = 15.0; // 15x15

const BSX: usize = 0;
const BSY: usize = 1;
const TSX: usize = 2;
const TSY: usize = 3;
const USE_PADDING: usize = 4;
const READ_ONLY: usize = 5;
const UNROLL: usize = 6;

pub fn build() -> Result<Kernel> {
    let params = vec![
        TunableParam::new("block_size_x", vec![16i64, 32, 48, 64, 96, 128]),
        TunableParam::new("block_size_y", vec![1i64, 2, 4, 8, 16]),
        TunableParam::new("tile_size_x", vec![1i64, 2, 4, 8]),
        TunableParam::new("tile_size_y", vec![1i64, 2, 4, 8]),
        TunableParam::new("use_padding", vec![0i64, 1]),
        TunableParam::new("read_only", vec![0i64, 1]),
        TunableParam::new("unroll_filter", vec![0i64, 1]),
    ];
    let constraints = vec![
        Constraint::parse("block_size_x * block_size_y >= 32")?,
        Constraint::parse("block_size_x * block_size_y <= 1024")?,
        // Per-thread tile kept within register budget.
        Constraint::parse("tile_size_x * tile_size_y <= 16")?,
        // Shared-memory staging (use_padding) needs the halo to fit LDS.
        Constraint::parse(
            "use_padding == 0 || (block_size_x * tile_size_x + 14) * (block_size_y * tile_size_y + 14) * 4 <= 65536",
        )?,
        // Padding only helps when x-dim is warp-aligned.
        Constraint::parse("use_padding == 0 || block_size_x % 16 == 0")?,
    ];
    let space = SearchSpace::build("convolution", params, constraints)?;
    Ok(Kernel {
        name: "convolution",
        problem: format!("{W}x{H} image, {FILTER}x{FILTER} filter, fp32"),
        space: std::sync::Arc::new(space),
        extract,
    })
}

fn extract(values: &[Value]) -> Features {
    let bsx = geti(values, BSX);
    let bsy = geti(values, BSY);
    let tsx = geti(values, TSX);
    let tsy = geti(values, TSY);
    let padding = geti(values, USE_PADDING);
    let read_only = geti(values, READ_ONLY);
    let unroll = geti(values, UNROLL);

    let tpb = bsx * bsy;
    let out_w = bsx * tsx;
    let out_h = bsy * tsy;
    let blocks = (W / out_w).ceil() * (H / out_h).ceil();

    let flops = W * H * FILTER * FILTER * 2.0;
    // Input halo per block; staging (padding) loads it once, otherwise the
    // cache absorbs some of the 225x re-reads.
    let halo_bytes = (out_w + FILTER - 1.0) * (out_h + FILTER - 1.0) * 4.0;
    let reread = if padding > 0.0 {
        1.0
    } else if read_only > 0.0 {
        2.5
    } else {
        4.0
    };
    let bytes = blocks * halo_bytes * reread + W * H * 4.0;

    let smem = if padding > 0.0 { halo_bytes + (out_h + FILTER - 1.0) * 4.0 } else { 0.0 };
    let regs = (20.0 + 2.0 * tsx * tsy + unroll * 24.0).min(255.0);

    let mut f = [0f32; NUM_FEATURES];
    f[F_FLOPS] = flops as f32;
    f[F_BYTES] = bytes as f32;
    f[F_TPB] = tpb as f32;
    f[F_REGS] = regs as f32;
    f[F_SMEM] = smem as f32;
    f[F_BLOCKS] = blocks as f32;
    f[F_VECW] = tsx.min(8.0) as f32;
    f[F_UNROLL] = if unroll > 0.0 { 8.0 } else { 1.0 };
    f[F_COAL] = ((bsx / 128.0).min(1.0) * 0.5 + 0.5) as f32;
    f[F_CACHE] = (read_only * 0.7 + padding * 0.3) as f32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_builds() {
        let k = build().unwrap();
        assert!(k.space().len() > 500);
    }

    #[test]
    fn staging_cuts_traffic() {
        let k = build().unwrap();
        let s = k.space();
        for i in 0..s.len() {
            let v = s.values(i);
            if v[USE_PADDING].as_i64() == Some(1) {
                let mut enc = s.encoded(i).to_vec();
                enc[USE_PADDING] = 0;
                if let Some(j) = s.index_of(&enc) {
                    assert!(k.features(i)[F_BYTES] < k.features(j)[F_BYTES]);
                    return;
                }
            }
        }
        panic!("no padding pair found");
    }

    #[test]
    fn high_arithmetic_intensity() {
        // Median over the space (config 0 is the worst-tiled corner).
        let k = build().unwrap();
        let mut ints: Vec<f64> = (0..k.space().len())
            .map(|i| {
                let f = k.features(i);
                f[F_FLOPS] as f64 / f[F_BYTES] as f64
            })
            .collect();
        ints.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(ints[ints.len() / 2] > 14.0, "median {}", ints[ints.len() / 2]);
    }
}
