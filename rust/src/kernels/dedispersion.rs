//! Dedispersion: radio-astronomy signal reconstruction.
//!
//! Applies a range of dispersion measures (DMs) to time-domain samples
//! across frequency channels (AMBER-style). Each DM shifts each channel
//! by a different delay, so the input is effectively re-read once per DM
//! block — a heavily bandwidth-bound kernel whose tuning space rewards
//! DM-tiling to amortize traffic.

use super::{geti, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::*;
use crate::searchspace::{Constraint, SearchSpace, TunableParam, Value};
use crate::error::Result;

const NR_DMS: f64 = 2048.0;
const NR_SAMPLES: f64 = 32768.0;
const NR_CHANNELS: f64 = 512.0;

const BSX: usize = 0; // threads over samples
const BSY: usize = 1; // threads over DMs
const TSD: usize = 2; // DMs per thread
const TSS: usize = 3; // samples per thread
const UNROLL: usize = 4; // channel unroll
const VEC: usize = 5; // sample vector width

pub fn build() -> Result<Kernel> {
    let params = vec![
        TunableParam::new("block_size_x", vec![32i64, 64, 128, 256]),
        TunableParam::new("block_size_y", vec![1i64, 2, 4, 8, 16, 32]),
        TunableParam::new("tile_size_dm", vec![1i64, 2, 4, 8]),
        TunableParam::new("tile_size_sample", vec![1i64, 2, 4]),
        TunableParam::new("unroll_channels", vec![1i64, 2, 4, 8, 16]),
        TunableParam::new("vector_size", vec![1i64, 2, 4]),
    ];
    let constraints = vec![
        Constraint::parse("block_size_x * block_size_y <= 1024")?,
        // Per-thread work bounded by register pressure.
        Constraint::parse("tile_size_dm * tile_size_sample <= 16")?,
        // The DM tile must divide the DM dimension evenly.
        Constraint::parse("2048 % (block_size_y * tile_size_dm) == 0")?,
        // Vector loads require matching sample tiling.
        Constraint::parse("tile_size_sample % vector_size == 0 || vector_size == 1")?,
    ];
    let space = SearchSpace::build("dedispersion", params, constraints)?;
    Ok(Kernel {
        name: "dedispersion",
        problem: format!("{NR_DMS} DMs x {NR_SAMPLES} samples x {NR_CHANNELS} channels"),
        space: std::sync::Arc::new(space),
        extract,
    })
}

fn extract(values: &[Value]) -> Features {
    let bsx = geti(values, BSX);
    let bsy = geti(values, BSY);
    let tsd = geti(values, TSD);
    let tss = geti(values, TSS);
    let unroll = geti(values, UNROLL);
    let vec = geti(values, VEC);

    let tpb = bsx * bsy;
    let dm_tile = bsy * tsd;
    let sample_tile = bsx * tss;
    let blocks = (NR_DMS / dm_tile).ceil() * (NR_SAMPLES / sample_tile).ceil();

    // One FMA per (dm, sample, channel).
    let flops = NR_DMS * NR_SAMPLES * NR_CHANNELS * 2.0;
    // Input re-read once per DM tile (shifted reads defeat caching across
    // DM tiles); output written once. Larger dm_tile amortizes traffic.
    let input_bytes = NR_SAMPLES * NR_CHANNELS * 4.0 * (NR_DMS / dm_tile);
    let output_bytes = NR_DMS * NR_SAMPLES * 4.0;
    let bytes = input_bytes + output_bytes;

    let regs = (18.0 + 3.0 * tsd * tss + unroll).min(255.0);
    let smem = 0.0; // AMBER-style dedispersion keeps shifts in registers

    let mut f = [0f32; NUM_FEATURES];
    f[F_FLOPS] = flops as f32;
    f[F_BYTES] = bytes as f32;
    f[F_TPB] = tpb as f32;
    f[F_REGS] = regs as f32;
    f[F_SMEM] = smem as f32;
    f[F_BLOCKS] = blocks as f32;
    f[F_VECW] = vec as f32;
    f[F_UNROLL] = unroll.min(16.0) as f32;
    // Shifted channel reads hurt coalescing unless vectorized.
    f[F_COAL] = (0.45 + 0.15 * vec + 0.1 * (tss - 1.0)).min(1.0) as f32;
    f[F_CACHE] = ((unroll / 16.0) * 0.5) as f32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_builds() {
        let k = build().unwrap();
        assert!(k.space().len() > 500, "{}", k.space().len());
    }

    #[test]
    fn dm_tiling_amortizes_traffic() {
        let k = build().unwrap();
        let s = k.space();
        for i in 0..s.len() {
            let enc = s.encoded(i);
            if enc[BSY] == 0 && enc[TSD] == 0 {
                // bsy=1, tsd=1 -> worst traffic
                let mut e2 = enc.to_vec();
                e2[BSY] = 3; // bsy=8
                if let Some(j) = s.index_of(&e2) {
                    assert!(k.features(j)[F_BYTES] < k.features(i)[F_BYTES]);
                    return;
                }
            }
        }
        panic!("no dm-tile pair found");
    }

    #[test]
    fn bandwidth_bound_regime() {
        let k = build().unwrap();
        // With dm_tile=1 intensity is ~2 flop/byte; even the best tiling
        // stays below the compute-bound threshold on most devices.
        let f = k.features(0);
        assert!(f[F_FLOPS] / f[F_BYTES] < 64.0);
    }
}
