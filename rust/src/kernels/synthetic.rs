//! A small synthetic kernel for tests and examples: a few hundred valid
//! configurations with the same feature plumbing as the real kernels, so
//! unit tests of runners/optimizers/methodology stay fast.

use super::{geti, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::*;
use crate::searchspace::{Constraint, SearchSpace, TunableParam, Value};
use crate::error::Result;

pub fn build() -> Result<Kernel> {
    build_sized(1.0)
}

/// `scale` multiplies the problem size (used by scaling benches).
pub fn build_sized(scale: f64) -> Result<Kernel> {
    let params = vec![
        TunableParam::new("block_size_x", vec![32i64, 64, 128, 256, 512]),
        TunableParam::new("block_size_y", vec![1i64, 2, 4]),
        TunableParam::new("tile", vec![1i64, 2, 4, 8]),
        TunableParam::new("vector", vec![1i64, 2, 4]),
        TunableParam::new("cache", vec![0i64, 1]),
    ];
    let constraints = vec![
        Constraint::parse("block_size_x * block_size_y <= 1024")?,
        Constraint::parse("tile % vector == 0")?,
    ];
    let space = SearchSpace::build("synthetic", params, constraints)?;
    // The extractor can't capture `scale` (fn pointer), so problem scale is
    // fixed; build_sized exists for API compatibility in benches.
    let _ = scale;
    Ok(Kernel {
        name: "synthetic",
        problem: "synthetic 1e9-flop workload".to_string(),
        space: std::sync::Arc::new(space),
        extract,
    })
}

fn extract(values: &[Value]) -> Features {
    let bsx = geti(values, 0);
    let bsy = geti(values, 1);
    let tile = geti(values, 2);
    let vector = geti(values, 3);
    let cache = geti(values, 4);

    let tpb = bsx * bsy;
    let work = 16_777_216.0; // 2^24 points
    let per_block = tpb * tile;
    let blocks = (work / per_block).ceil();

    let mut f = [0f32; NUM_FEATURES];
    f[F_FLOPS] = (work * 64.0) as f32;
    f[F_BYTES] = (work * 8.0 / tile.sqrt()) as f32;
    f[F_TPB] = tpb as f32;
    f[F_REGS] = (16.0 + tile * 4.0) as f32;
    f[F_SMEM] = (tile * tpb * 4.0 * cache) as f32;
    f[F_BLOCKS] = blocks as f32;
    f[F_VECW] = vector as f32;
    f[F_UNROLL] = tile as f32;
    f[F_COAL] = (0.5 + 0.125 * vector) as f32;
    f[F_CACHE] = cache as f32;
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_but_nontrivial() {
        let k = build().unwrap();
        let n = k.space().len();
        assert!((50..1000).contains(&n), "{n}");
    }
}
