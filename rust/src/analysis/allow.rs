//! The inline suppression grammar for lint diagnostics.
//!
//! Every suppression must name the rule(s) it silences *and* carry a
//! non-empty justification, so exceptions are documented at the site
//! rather than in a central exclusion list. The grammar, anchored
//! anywhere inside a line or block comment:
//!
//! ```text
//! lint: allow(W01, reason = "wallclock telemetry, stripped from diffs")
//! lint: allow(W01, W03, reason = "shared justification for both rules")
//! ```
//!
//! A directive on a comment-only line suppresses matching diagnostics
//! on the next line that contains code (comment-above placement); a
//! directive sharing its line with code (trailing placement) suppresses
//! only that line, never the statement after it. A directive that does
//! not parse —
//! missing reason, empty reason, unknown rule id, bad syntax — is
//! itself reported as rule `W00`, which is always denied: a malformed
//! suppression must never silently succeed.

use super::rules::RuleId;

/// A successfully parsed allow directive.
#[derive(Clone, Debug)]
pub struct AllowDirective {
    pub rules: Vec<RuleId>,
    pub reason: String,
    /// Line the directive's comment starts on.
    pub line: u32,
}

/// A malformed directive (reported as W00).
#[derive(Clone, Debug)]
pub struct BadDirective {
    pub line: u32,
    pub col: u32,
    pub message: String,
}

const MARKER: &str = "lint: allow";

/// Scan one comment token's text for an allow directive. Returns
/// `None` when the comment does not contain the allow marker.
pub fn parse_comment(
    text: &str,
    line: u32,
    col: u32,
) -> Option<Result<AllowDirective, BadDirective>> {
    let start = text.find(MARKER)?;
    let rest = text[start + MARKER.len()..].trim_start();
    let bad = |message: String| BadDirective { line, col, message };
    let Some(body) = rest.strip_prefix('(') else {
        return Some(Err(bad("expected '(' after `lint: allow`".into())));
    };
    // The closing paren must be found outside the quoted reason (which
    // may itself contain parens).
    let mut close = None;
    let mut quoted = false;
    for (idx, c) in body.char_indices() {
        match c {
            '"' => quoted = !quoted,
            ')' if !quoted => {
                close = Some(idx);
                break;
            }
            _ => {}
        }
    }
    let Some(close) = close else {
        return Some(Err(bad("unterminated `lint: allow(...)` directive".into())));
    };
    let body = &body[..close];

    // Split on commas outside the quoted reason string.
    let mut parts: Vec<String> = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(cur.trim().to_string());
                cur = String::new();
            }
            _ => cur.push(c),
        }
    }
    parts.push(cur.trim().to_string());

    let mut rules = Vec::new();
    let mut reason: Option<String> = None;
    for part in &parts {
        if part.is_empty() {
            return Some(Err(bad("empty clause in `lint: allow(...)`".into())));
        }
        if let Some(val) = part.strip_prefix("reason") {
            let val = val.trim_start();
            let Some(val) = val.strip_prefix('=') else {
                return Some(Err(bad("expected `reason = \"...\"`".into())));
            };
            let val = val.trim();
            if val.len() < 2 || !val.starts_with('"') || !val.ends_with('"') {
                return Some(Err(bad("reason must be a double-quoted string".into())));
            }
            let inner = val[1..val.len() - 1].trim();
            if inner.is_empty() {
                return Some(Err(bad("reason must not be empty".into())));
            }
            reason = Some(inner.to_string());
        } else {
            match RuleId::parse(part) {
                Some(id) => rules.push(id),
                None => {
                    let msg = format!("unknown rule id `{part}` (expected W01..W05)");
                    return Some(Err(bad(msg)));
                }
            }
        }
    }
    if rules.is_empty() {
        return Some(Err(bad("directive names no rules".into())));
    }
    let Some(reason) = reason else {
        return Some(Err(bad("directive is missing `reason = \"...\"`".into())));
    };
    Some(Ok(AllowDirective {
        rules,
        reason,
        line,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok(text: &str) -> AllowDirective {
        match parse_comment(text, 1, 1) {
            Some(Ok(d)) => d,
            other => panic!("expected well-formed directive, got {other:?}"),
        }
    }

    fn rejected(text: &str) -> BadDirective {
        match parse_comment(text, 1, 1) {
            Some(Err(e)) => e,
            other => panic!("expected malformed directive, got {other:?}"),
        }
    }

    #[test]
    fn well_formed_single_rule() {
        let d = ok("// lint: allow(W03, reason = \"guarded by chunks_exact\")");
        assert_eq!(d.rules, vec![RuleId::W03]);
        assert_eq!(d.reason, "guarded by chunks_exact");
    }

    #[test]
    fn well_formed_multi_rule() {
        let d = ok("// lint: allow(W01, W03, reason = \"telemetry only\")");
        assert_eq!(d.rules, vec![RuleId::W01, RuleId::W03]);
    }

    #[test]
    fn non_directive_comment_ignored() {
        assert!(parse_comment("// plain comment about linting", 1, 1).is_none());
        assert!(parse_comment("// allow me to explain", 1, 1).is_none());
    }

    #[test]
    fn missing_reason_rejected() {
        let e = rejected("// lint: allow(W03)");
        assert!(e.message.contains("missing"), "{}", e.message);
    }

    #[test]
    fn empty_reason_rejected() {
        let e = rejected("// lint: allow(W03, reason = \"\")");
        assert!(e.message.contains("empty"), "{}", e.message);
        let e = rejected("// lint: allow(W03, reason = \"   \")");
        assert!(e.message.contains("empty"), "{}", e.message);
    }

    #[test]
    fn unknown_rule_rejected() {
        let e = rejected("// lint: allow(W99, reason = \"nope\")");
        assert!(e.message.contains("W99"), "{}", e.message);
    }

    #[test]
    fn w00_not_allowable() {
        let e = rejected("// lint: allow(W00, reason = \"meta\")");
        assert!(e.message.contains("W00"), "{}", e.message);
    }

    #[test]
    fn unquoted_reason_rejected() {
        let e = rejected("// lint: allow(W03, reason = because)");
        assert!(e.message.contains("quoted"), "{}", e.message);
    }

    #[test]
    fn missing_parens_rejected() {
        let e = rejected("// lint: allow W03");
        assert!(e.message.contains("'('"), "{}", e.message);
    }

    #[test]
    fn comma_inside_reason_ok() {
        let d = ok("// lint: allow(W01, reason = \"a, b, and c\")");
        assert_eq!(d.reason, "a, b, and c");
    }

    #[test]
    fn parens_inside_reason_ok() {
        let d = ok("// lint: allow(W03, reason = \"chunks_exact(8) guarantees len\")");
        assert_eq!(d.reason, "chunks_exact(8) guarantees len");
    }

    #[test]
    fn block_comment_form_ok() {
        let d = ok("/* lint: allow(W02, reason = \"fixture writes a temp file\") */");
        assert_eq!(d.rules, vec![RuleId::W02]);
    }
}
