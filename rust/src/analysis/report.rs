//! Lint output: human-readable text and the versioned `tunetuner-lint`
//! JSON envelope (schema + per-rule counts + diagnostics), persisted
//! through [`crate::util::fsio::atomic_write`] like every other
//! artifact the tuner writes.

use super::rules::RuleId;
use super::LintReport;
use crate::error::Result;
use crate::util::fsio;
use crate::util::json::Json;
use std::fmt::Write as _;
use std::path::Path;

/// Envelope schema tag.
pub const LINT_SCHEMA: &str = "tunetuner-lint";
/// Envelope schema version (bump on breaking shape changes).
pub const LINT_SCHEMA_VERSION: u32 = 1;

/// Per-rule violation counts over the surviving diagnostics, in rule
/// order (so tables and envelopes are stable).
pub fn rule_counts(report: &LintReport) -> Vec<(RuleId, usize)> {
    RuleId::all()
        .iter()
        .map(|&id| {
            let n = report.diagnostics.iter().filter(|d| d.rule == id).count();
            (id, n)
        })
        .collect()
}

/// Human-readable report: one `path:line:col: RULE: message` line per
/// diagnostic (clickable in most terminals/editors), then a summary.
pub fn render_text(report: &LintReport) -> String {
    let mut out = String::new();
    for d in &report.diagnostics {
        let _ = writeln!(
            out,
            "{}:{}:{}: {}: {}",
            d.path,
            d.line,
            d.col,
            d.rule.as_str(),
            d.message
        );
    }
    if !report.diagnostics.is_empty() {
        out.push('\n');
        for (id, n) in rule_counts(report) {
            if n > 0 {
                let _ = writeln!(out, "  {} x{:<4} {}", id.as_str(), n, id.summary());
            }
        }
    }
    let _ = writeln!(
        out,
        "{} file(s) checked: {} violation(s), {} suppressed by {} lint allow(s)",
        report.files,
        report.diagnostics.len(),
        report.suppressed,
        report.allows
    );
    out
}

/// The `tunetuner-lint` envelope.
pub fn to_json(report: &LintReport) -> Json {
    let mut counts = Json::obj();
    for (id, n) in rule_counts(report) {
        counts.set(id.as_str(), Json::Num(n as f64));
    }
    let diags: Vec<Json> = report
        .diagnostics
        .iter()
        .map(|d| {
            let mut j = Json::obj();
            j.set("rule", Json::Str(d.rule.as_str().to_string()))
                .set("path", Json::Str(d.path.clone()))
                .set("line", Json::Num(d.line as f64))
                .set("col", Json::Num(d.col as f64))
                .set("message", Json::Str(d.message.clone()));
            j
        })
        .collect();
    let mut j = Json::obj();
    j.set("schema", Json::Str(LINT_SCHEMA.to_string()))
        .set("schema_version", Json::Num(LINT_SCHEMA_VERSION as f64))
        .set("root", Json::Str(report.root.clone()))
        .set("files", Json::Num(report.files as f64))
        .set("violations", Json::Num(report.diagnostics.len() as f64))
        .set("suppressed", Json::Num(report.suppressed as f64))
        .set("allows", Json::Num(report.allows as f64))
        .set("counts", counts)
        .set("diagnostics", Json::Arr(diags));
    j
}

/// Persist the envelope crash-safely (staged temp + rename).
pub fn save(report: &LintReport, path: &Path) -> Result<()> {
    let mut body = to_json(report).to_pretty();
    body.push('\n');
    fsio::atomic_write(path, body.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lint_source;

    fn sample_report() -> LintReport {
        let src = "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        LintReport {
            root: "x".to_string(),
            files: 1,
            diagnostics: fl.diagnostics,
            suppressed: fl.suppressed,
            allows: fl.allows,
        }
    }

    #[test]
    fn text_has_span_and_summary() {
        let text = render_text(&sample_report());
        assert!(text.contains("x/sample.rs:2:7: W03:"), "{text}");
        assert!(text.contains("1 violation(s)"), "{text}");
    }

    #[test]
    fn envelope_shape() {
        let j = to_json(&sample_report());
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(LINT_SCHEMA));
        assert_eq!(j.get("schema_version").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("violations").and_then(Json::as_f64), Some(1.0));
        let w03 = j.at(&["counts", "W03"]).and_then(Json::as_f64);
        assert_eq!(w03, Some(1.0));
        let rule = j.at(&["diagnostics", "0", "rule"]).and_then(Json::as_str);
        assert_eq!(rule, Some("W03"));
        let line = j.at(&["diagnostics", "0", "line"]).and_then(Json::as_f64);
        assert_eq!(line, Some(2.0));
    }

    #[test]
    fn envelope_roundtrips_through_parser() {
        let body = to_json(&sample_report()).to_pretty();
        let parsed = crate::util::json::parse(&body).expect("valid json");
        assert_eq!(parsed, to_json(&sample_report()));
    }

    #[test]
    fn save_writes_atomically() {
        let dir = std::env::temp_dir().join(format!("tunetuner_lint_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lint_report.json");
        save(&sample_report(), &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("tunetuner-lint"));
        std::fs::remove_file(&path).ok();
    }
}
