//! The invariant rules W01–W05 (plus W00, the meta-rule for malformed
//! suppressions). Each rule codifies a contract an earlier PR
//! established by convention; see the README "Static analysis &
//! invariants" section for the full rationale per rule.
//!
//! Rules run over the comment-stripped token stream of one file.
//! Tokens inside test code (`#[test]` / `#[cfg(test)]` regions, as
//! computed by [`super::test_mask`]) are exempt from every rule except
//! W00 — tests may panic, write scratch files, and time things.

use super::lexer::{TokKind, Token};
use super::Diagnostic;

/// Rule identifiers. `W00` is the meta-rule (a malformed allow
/// directive); it can never itself be allowed and is always denied.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RuleId {
    W00,
    W01,
    W02,
    W03,
    W04,
    W05,
}

impl RuleId {
    /// Parse a rule id as written in allow directives and `--deny`.
    /// `W00` is deliberately not parseable: the suppression grammar
    /// itself cannot be suppressed.
    pub fn parse(s: &str) -> Option<RuleId> {
        match s.trim() {
            "W01" | "w01" => Some(RuleId::W01),
            "W02" | "w02" => Some(RuleId::W02),
            "W03" | "w03" => Some(RuleId::W03),
            "W04" | "w04" => Some(RuleId::W04),
            "W05" | "w05" => Some(RuleId::W05),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RuleId::W00 => "W00",
            RuleId::W01 => "W01",
            RuleId::W02 => "W02",
            RuleId::W03 => "W03",
            RuleId::W04 => "W04",
            RuleId::W05 => "W05",
        }
    }

    /// One-line summary, used by the text report and the JSON envelope.
    pub fn summary(&self) -> &'static str {
        match self {
            RuleId::W00 => "malformed `lint: allow` directive",
            RuleId::W01 => "nondeterminism (wallclock time, unordered std collections)",
            RuleId::W02 => "persistence outside util::fsio::atomic_write",
            RuleId::W03 => "panic in library code (unwrap/expect/panic!)",
            RuleId::W04 => "float ordering via partial_cmp instead of total_cmp",
            RuleId::W05 => "RNG construction outside util::rng seed derivation",
        }
    }

    /// Every reportable rule, in id order (for stable count tables).
    pub fn all() -> [RuleId; 6] {
        [
            RuleId::W00,
            RuleId::W01,
            RuleId::W02,
            RuleId::W03,
            RuleId::W04,
            RuleId::W05,
        ]
    }
}

/// Does the `/`-normalized `path` denote the whitelisted crate module
/// `tail` (e.g. `util/fsio.rs`)? Anchored, not suffix-matched: the path
/// must *be* the module path — either relative to the lint root
/// (`lint_tree` strips the `rust/src` walk root) or spelled
/// repo-relative (`rust/src/util/fsio.rs`, as the in-memory fixtures
/// do). A fixture tree or vendored file whose path merely *ends* in
/// `util/fsio.rs` does not inherit the exemption.
fn in_module(path: &str, tail: &str) -> bool {
    path == tail || path.strip_prefix("rust/src/") == Some(tail)
}

/// A code token (comments stripped) plus its test-region flag.
struct Code<'a> {
    toks: Vec<&'a Token>,
    in_test: Vec<bool>,
}

impl<'a> Code<'a> {
    fn id(&self, i: usize, name: &str) -> bool {
        self.toks
            .get(i)
            .map(|t| t.kind == TokKind::Ident && t.text == name)
            .unwrap_or(false)
    }

    fn id_in(&self, i: usize, names: &[&str]) -> bool {
        self.toks
            .get(i)
            .map(|t| t.kind == TokKind::Ident && names.contains(&t.text.as_str()))
            .unwrap_or(false)
    }

    fn punct(&self, i: usize, c: char) -> bool {
        self.toks
            .get(i)
            .map(|t| t.kind == TokKind::Punct && t.text.chars().next() == Some(c))
            .unwrap_or(false)
    }

    /// Index of the `)` matching the `(` at `open`, if any.
    fn close_paren(&self, open: usize) -> Option<usize> {
        let mut depth = 0usize;
        for (off, t) in self.toks.iter().enumerate().skip(open) {
            if t.kind != TokKind::Punct {
                continue;
            }
            match t.text.chars().next() {
                Some('(') => depth += 1,
                Some(')') => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return Some(off);
                    }
                }
                _ => {}
            }
        }
        None
    }

    /// Index of the `(` matching the `)` at `close`, if any.
    fn open_paren(&self, close: usize) -> Option<usize> {
        let mut depth = 0usize;
        for off in (0..=close).rev() {
            let t = self.toks[off];
            if t.kind != TokKind::Punct {
                continue;
            }
            match t.text.chars().next() {
                Some(')') => depth += 1,
                Some('(') => {
                    depth = depth.saturating_sub(1);
                    if depth == 0 {
                        return Some(off);
                    }
                }
                _ => {}
            }
        }
        None
    }
}

/// Run every rule over one file's token stream. `in_test[i]` marks
/// `tokens[i]` as inside test code; `rel_path` selects the per-module
/// whitelists (`util/log.rs` for W01 timing, `util/hash.rs` for the
/// deterministic-hasher wrapper, `util/fsio.rs` for W02, `util/rng.rs`
/// for W05 — root-anchored, see [`in_module`]).
pub fn check(rel_path: &str, tokens: &[Token], in_test: &[bool]) -> Vec<Diagnostic> {
    let path = rel_path.replace('\\', "/");
    let mut code = Code {
        toks: Vec::new(),
        in_test: Vec::new(),
    };
    for (i, t) in tokens.iter().enumerate() {
        if t.kind != TokKind::Comment {
            code.toks.push(t);
            code.in_test.push(in_test.get(i).copied().unwrap_or(false));
        }
    }

    let mut out = Vec::new();
    let mut diag = |rule: RuleId, t: &Token, message: String| {
        out.push(Diagnostic {
            rule,
            path: path.clone(),
            line: t.line,
            col: t.col,
            message,
        });
    };

    let timing_module = in_module(&path, "util/log.rs");
    let hash_module = in_module(&path, "util/hash.rs");
    let fsio_module = in_module(&path, "util/fsio.rs");
    let rng_module = in_module(&path, "util/rng.rs");

    for i in 0..code.toks.len() {
        if code.in_test[i] {
            continue;
        }
        let t = code.toks[i];

        // ---- W01: nondeterminism -------------------------------------
        // Wallclock reads (`Instant::now` / `SystemTime::now`) outside
        // the timing module make envelopes differ run to run.
        if !timing_module
            && code.id(i, "now")
            && i >= 3
            && code.punct(i - 1, ':')
            && code.punct(i - 2, ':')
            && code.id_in(i - 3, &["Instant", "SystemTime"])
        {
            let src = &code.toks[i - 3].text;
            diag(
                RuleId::W01,
                code.toks[i - 3],
                format!(
                    "wallclock read `{src}::now()`; keep timing in util::log \
                     or justify with a lint allow"
                ),
            );
        }
        // Unordered std collections: iteration order is nondeterministic
        // across runs, which poisons anything serialized from it. The
        // repo-wide replacements are FastMap/FastSet (deterministic
        // FxHasher, util::hash) or BTreeMap for sorted envelopes.
        if !hash_module && code.id_in(i, &["HashMap", "HashSet"]) {
            let name = &t.text;
            diag(
                RuleId::W01,
                t,
                format!(
                    "std {name} has nondeterministic iteration order; \
                     use util::hash::FastMap/FastSet or BTreeMap"
                ),
            );
        }

        // ---- W02: persistence ----------------------------------------
        // Raw writes bypass the staged-temp-plus-rename discipline; a
        // crash mid-write leaves a torn artifact the resume path then
        // trusts. All persistence funnels through util::fsio.
        if !fsio_module
            && code.id_in(i, &["write", "rename", "create"])
            && i >= 3
            && code.punct(i - 1, ':')
            && code.punct(i - 2, ':')
            && code.id_in(i - 3, &["fs", "File"])
        {
            let what = format!("{}::{}", code.toks[i - 3].text, t.text);
            diag(
                RuleId::W02,
                t,
                format!("raw `{what}` outside util::fsio; use util::fsio::atomic_write"),
            );
        }

        // ---- W03: panic discipline -----------------------------------
        // Library code returns TuneError; panics tear down worker
        // threads and turn typed failures into WorkerPanic quarantines.
        if code.id_in(i, &["panic", "todo", "unimplemented"]) && code.punct(i + 1, '!') {
            diag(
                RuleId::W03,
                t,
                format!("`{}!` in library code; return a TuneError instead", t.text),
            );
        }
        if code.id(i, "unwrap")
            && i >= 1
            && code.punct(i - 1, '.')
            && code.punct(i + 1, '(')
            && code.punct(i + 2, ')')
            && !unwrap_of_poison_chain(&code, i)
        {
            diag(
                RuleId::W03,
                t,
                "`.unwrap()` in library code; return a TuneError \
                 (or justify with a lint allow)"
                    .to_string(),
            );
        }
        if code.id(i, "expect")
            && i >= 1
            && code.punct(i - 1, '.')
            && code.punct(i + 1, '(')
            && !expect_is_fallible_method(&code, i)
        {
            diag(
                RuleId::W03,
                t,
                "`.expect(..)` in library code; return a TuneError \
                 (or justify with a lint allow)"
                    .to_string(),
            );
        }

        // ---- W04: float ordering -------------------------------------
        // `partial_cmp(..).unwrap()` panics on NaN (the exact bug class
        // PR 1 fixed); `f64::total_cmp` is total and panic-free.
        if code.id(i, "partial_cmp") && !(i >= 1 && code.id(i - 1, "fn")) {
            diag(
                RuleId::W04,
                t,
                "float ordering via `partial_cmp` (panics or misorders on NaN); \
                 use `f64::total_cmp`"
                    .to_string(),
            );
        }

        // ---- W05: RNG discipline -------------------------------------
        // Replay and retry are bitwise only because every stream is
        // derived from the campaign seed via util::rng (mix64/fork).
        if !rng_module
            && code.id_in(
                i,
                &[
                    "thread_rng",
                    "from_entropy",
                    "OsRng",
                    "StdRng",
                    "SmallRng",
                    "ThreadRng",
                    "getrandom",
                ],
            )
        {
            diag(
                RuleId::W05,
                t,
                format!(
                    "foreign RNG `{}`; derive streams from the campaign seed \
                     via util::rng (mix64/fork)",
                    t.text
                ),
            );
        }
        if !rng_module
            && code.id(i, "Rng")
            && code.punct(i + 1, ':')
            && code.punct(i + 2, ':')
            && code.id(i + 3, "new")
            && code.punct(i + 4, '(')
            && rng_new_args_all_literal(&code, i + 4)
        {
            diag(
                RuleId::W05,
                t,
                "`Rng::new` with a hard-coded seed in library code; derive the \
                 seed from the campaign seed via mix64/fork"
                    .to_string(),
            );
        }
    }
    out
}

/// `.unwrap()` directly on a `lock()`/`wait()`/`into_inner()` result is
/// the repo's mutex-poisoning idiom: the only failure is a poisoned
/// lock, i.e. another thread already panicked, and propagating that
/// panic is the documented policy (PR 9's catch_unwind boundary turns
/// it into a typed JobFailure). `unwrap_idx` points at the `unwrap`
/// ident; the preceding chain must be `<recv>.{lock,wait,into_inner}(..)`.
fn unwrap_of_poison_chain(code: &Code<'_>, unwrap_idx: usize) -> bool {
    if unwrap_idx < 2 {
        return false;
    }
    let close = unwrap_idx - 2;
    if !code.punct(close, ')') {
        return false;
    }
    let Some(open) = code.open_paren(close) else {
        return false;
    };
    open >= 1 && code.id_in(open - 1, &["lock", "wait", "into_inner"])
}

/// `self.expect(b'{')?` — an `expect` *method* whose result is
/// immediately propagated with `?` is a fallible user API (the JSON
/// parser's token assertion), not `Option::expect`/`Result::expect`.
/// `open_idx` points at the `(` after the `expect` ident.
fn expect_is_fallible_method(code: &Code<'_>, expect_idx: usize) -> bool {
    let Some(close) = code.close_paren(expect_idx + 1) else {
        return false;
    };
    code.punct(close + 1, '?')
}

/// Are the arguments of the call whose `(` sits at `open_idx` composed
/// solely of literals and punctuation (no identifiers)? Such a
/// `Rng::new(12345)` is a hard-coded seed; `Rng::new(seed)` or
/// `Rng::new(mix64(base, tag))` reference a derived value and pass.
fn rng_new_args_all_literal(code: &Code<'_>, open_idx: usize) -> bool {
    let Some(close) = code.close_paren(open_idx) else {
        return false;
    };
    if close == open_idx + 1 {
        return false; // no args at all — not a seed literal
    }
    code.toks[open_idx + 1..close]
        .iter()
        .all(|t| matches!(t.kind, TokKind::Num | TokKind::Punct))
}
