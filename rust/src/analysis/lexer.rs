//! Span-accurate Rust token scanner for the invariant lint engine.
//!
//! A deliberately small lexer — not a full Rust grammar — that splits a
//! source file into the token classes the rules in
//! [`super::rules`] match on: identifiers, numeric/string/char literals,
//! lifetimes, single-character punctuation, and comments (kept as
//! tokens, because the allow-directive suppression grammar lives in
//! them). Every token carries a 1-based `line:col` span so diagnostics
//! point at the exact site.
//!
//! Handled literal forms: `"…"` with escapes (multi-line allowed),
//! raw strings `r"…"`/`r#"…"#` at any guard depth, byte strings
//! `b"…"`/`br#"…"#`, char literals (incl. `'\u{…}'` and `b'x'`),
//! lifetimes (`'a` without a closing quote), nested block comments, and
//! numeric literals with suffixes. Known simplification: an exponent
//! with a sign (`1e-9`) lexes as `1e` `-` `9`; no rule gives numeric
//! tokens semantics beyond "is a literal", so the span split is
//! harmless.

/// Token class. Punctuation is one token per character (`::` is two
/// `Punct(':')` tokens); rules match multi-character operators by
/// looking at adjacent tokens.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (integer or float, any base, with suffix).
    Num,
    /// String literal of any form (escaped, raw, byte).
    Str,
    /// Char literal (`'a'`, `'\n'`, `b'x'`).
    Char,
    /// Lifetime (`'a` — no closing quote).
    Lifetime,
    /// Single punctuation character.
    Punct,
    /// Line or block comment, doc comments included. The full comment
    /// text (markers kept) is preserved for the allow-directive parser.
    Comment,
}

/// One token with its source span (1-based line and column, counted in
/// characters).
#[derive(Clone, Debug)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// Character cursor that tracks line/column.
struct Cursor {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn new(source: &str) -> Cursor {
        Cursor {
            chars: source.chars().collect(),
            pos: 0,
            line: 1,
            col: 1,
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn peek_at(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek()?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Tokenize a source file. Never fails: unterminated literals and
/// comments lex as a final token running to end of input (the rules
/// still see every token before the malformed tail, and rustc itself
/// rejects such files long before CI runs the linter).
pub fn tokenize(source: &str) -> Vec<Token> {
    let mut cur = Cursor::new(source);
    let mut out = Vec::new();
    while let Some(c) = cur.peek() {
        let (line, col) = (cur.line, cur.col);
        if c.is_whitespace() {
            cur.bump();
            continue;
        }
        if c == '/' && cur.peek_at(1) == Some('/') {
            out.push(tok(TokKind::Comment, line_comment(&mut cur), line, col));
            continue;
        }
        if c == '/' && cur.peek_at(1) == Some('*') {
            out.push(tok(TokKind::Comment, block_comment(&mut cur), line, col));
            continue;
        }
        // Raw / byte string prefixes take precedence over identifiers.
        if let Some(text) = raw_or_byte_literal(&mut cur) {
            out.push(tok(text.1, text.0, line, col));
            continue;
        }
        if is_ident_start(c) {
            out.push(tok(TokKind::Ident, ident(&mut cur), line, col));
            continue;
        }
        if c.is_ascii_digit() {
            out.push(tok(TokKind::Num, number(&mut cur), line, col));
            continue;
        }
        if c == '"' {
            out.push(tok(TokKind::Str, string_literal(&mut cur), line, col));
            continue;
        }
        if c == '\'' {
            let (text, kind) = char_or_lifetime(&mut cur);
            out.push(tok(kind, text, line, col));
            continue;
        }
        cur.bump();
        out.push(tok(TokKind::Punct, c.to_string(), line, col));
    }
    out
}

fn tok(kind: TokKind, text: String, line: u32, col: u32) -> Token {
    Token {
        kind,
        text,
        line,
        col,
    }
}

fn line_comment(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if c == '\n' {
            break;
        }
        s.push(c);
        cur.bump();
    }
    s
}

fn block_comment(cur: &mut Cursor) -> String {
    let mut s = String::new();
    let mut depth = 0usize;
    while let Some(c) = cur.peek() {
        if c == '/' && cur.peek_at(1) == Some('*') {
            depth += 1;
            s.push('/');
            s.push('*');
            cur.bump();
            cur.bump();
            continue;
        }
        if c == '*' && cur.peek_at(1) == Some('/') {
            depth = depth.saturating_sub(1);
            s.push('*');
            s.push('/');
            cur.bump();
            cur.bump();
            if depth == 0 {
                break;
            }
            continue;
        }
        s.push(c);
        cur.bump();
    }
    s
}

fn ident(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        s.push(c);
        cur.bump();
    }
    s
}

fn number(cur: &mut Cursor) -> String {
    let mut s = String::new();
    while let Some(c) = cur.peek() {
        if !is_ident_continue(c) {
            break;
        }
        s.push(c);
        cur.bump();
    }
    // Fractional part: `.` followed by a digit (so `0..n` and `1.max(2)`
    // stay a separate `.` token).
    if cur.peek() == Some('.') && cur.peek_at(1).map(|c| c.is_ascii_digit()).unwrap_or(false) {
        s.push('.');
        cur.bump();
        while let Some(c) = cur.peek() {
            if !is_ident_continue(c) {
                break;
            }
            s.push(c);
            cur.bump();
        }
    }
    s
}

/// Consume `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, or `b'x'` when the
/// cursor sits on such a prefix; returns None (consuming nothing) for a
/// plain identifier starting with `r`/`b`.
fn raw_or_byte_literal(cur: &mut Cursor) -> Option<(String, TokKind)> {
    let c = cur.peek()?;
    if c != 'r' && c != 'b' {
        return None;
    }
    // Figure out the literal shape from the next couple of characters.
    let mut ahead = 1;
    let mut raw = c == 'r';
    if c == 'b' {
        match cur.peek_at(1) {
            Some('r') => {
                raw = true;
                ahead = 2;
            }
            Some('"') => {
                // b"…" — plain (escaped) byte string.
                let mut s = String::from("b");
                cur.bump();
                s.push_str(&string_literal(cur));
                return Some((s, TokKind::Str));
            }
            Some('\'') => {
                // b'x' — byte char.
                let mut s = String::from("b");
                cur.bump();
                let (body, _) = char_or_lifetime(cur);
                s.push_str(&body);
                return Some((s, TokKind::Char));
            }
            _ => return None,
        }
    }
    if !raw {
        return None;
    }
    // r / br followed by zero-or-more '#' then '"'.
    let mut guards = 0usize;
    while cur.peek_at(ahead + guards) == Some('#') {
        guards += 1;
    }
    if cur.peek_at(ahead + guards) != Some('"') {
        return None;
    }
    let mut s = String::new();
    for _ in 0..(ahead + guards + 1) {
        if let Some(ch) = cur.bump() {
            s.push(ch);
        }
    }
    // Body runs to `"` followed by `guards` hashes.
    while let Some(ch) = cur.peek() {
        if ch == '"' {
            let mut ok = true;
            for g in 0..guards {
                if cur.peek_at(1 + g) != Some('#') {
                    ok = false;
                    break;
                }
            }
            if ok {
                for _ in 0..(guards + 1) {
                    if let Some(q) = cur.bump() {
                        s.push(q);
                    }
                }
                break;
            }
        }
        s.push(ch);
        cur.bump();
    }
    Some((s, TokKind::Str))
}

fn string_literal(cur: &mut Cursor) -> String {
    let mut s = String::new();
    if let Some(q) = cur.bump() {
        s.push(q); // opening quote
    }
    while let Some(c) = cur.peek() {
        if c == '\\' {
            s.push(c);
            cur.bump();
            if let Some(esc) = cur.bump() {
                s.push(esc);
            }
            continue;
        }
        s.push(c);
        cur.bump();
        if c == '"' {
            break;
        }
    }
    s
}

/// Disambiguate `'a'` / `'\n'` / `'\u{…}'` (char literal) from `'a`
/// (lifetime). Called with the cursor on the opening `'`.
fn char_or_lifetime(cur: &mut Cursor) -> (String, TokKind) {
    let mut s = String::new();
    if let Some(q) = cur.bump() {
        s.push(q);
    }
    match cur.peek() {
        Some('\\') => {
            // Escaped char literal: consume escape, then to closing quote.
            s.push('\\');
            cur.bump();
            if let Some(esc) = cur.bump() {
                s.push(esc);
                if esc == 'u' && cur.peek() == Some('{') {
                    while let Some(c) = cur.peek() {
                        s.push(c);
                        cur.bump();
                        if c == '}' {
                            break;
                        }
                    }
                }
            }
            if cur.peek() == Some('\'') {
                s.push('\'');
                cur.bump();
            }
            (s, TokKind::Char)
        }
        Some(c) if is_ident_start(c) => {
            if cur.peek_at(1) == Some('\'') {
                // 'x' — single-character char literal.
                s.push(c);
                cur.bump();
                s.push('\'');
                cur.bump();
                (s, TokKind::Char)
            } else {
                // 'lifetime — consume the identifier, no closing quote.
                s.push_str(&ident(cur));
                (s, TokKind::Lifetime)
            }
        }
        Some(c) => {
            // Punctuation char literal like '(' or '0'.
            s.push(c);
            cur.bump();
            if cur.peek() == Some('\'') {
                s.push('\'');
                cur.bump();
            }
            (s, TokKind::Char)
        }
        None => (s, TokKind::Char),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src)
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn idents_puncts_and_spans() {
        let toks = tokenize("let x = a::b;\nlet y = 2;");
        assert_eq!(toks[0].text, "let");
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        let colons: Vec<&Token> = toks.iter().filter(|t| t.text == ":").collect();
        assert_eq!(colons.len(), 2, "`::` lexes as two ':' puncts");
        let second_let = toks.iter().filter(|t| t.text == "let").nth(1).unwrap();
        assert_eq!((second_let.line, second_let.col), (2, 1));
    }

    #[test]
    fn comments_are_tokens_with_text() {
        let toks = kinds("a // trailing note\n/* block */ b");
        assert_eq!(
            toks[1],
            (TokKind::Comment, "// trailing note".to_string())
        );
        assert_eq!(toks[2], (TokKind::Comment, "/* block */".to_string()));
        assert_eq!(toks[3], (TokKind::Ident, "b".to_string()));
    }

    #[test]
    fn nested_block_comment() {
        let toks = kinds("/* outer /* inner */ tail */ x");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[0].0, TokKind::Comment);
        assert_eq!(toks[1], (TokKind::Ident, "x".to_string()));
    }

    #[test]
    fn string_forms_swallow_contents() {
        // Identifier-looking text inside every string form must not
        // produce Ident tokens (rules would otherwise match inside
        // fixture snippets and documentation strings).
        for src in [
            "let s = \"fs::write inside\";",
            "let s = r\"fs::write inside\";",
            "let s = r#\"fs::write \" inside\"#;",
            "let s = b\"fs::write inside\";",
            "let s = \"esc \\\" fs::write\";",
        ] {
            let idents: Vec<String> = tokenize(src)
                .into_iter()
                .filter(|t| t.kind == TokKind::Ident)
                .map(|t| t.text)
                .collect();
            assert_eq!(idents, vec!["let", "s"], "src: {src}");
        }
    }

    #[test]
    fn char_vs_lifetime() {
        let toks = kinds("fn f<'a>(x: &'a u8) { let c = 'x'; let n = '\\n'; }");
        let lifetimes: Vec<&(TokKind, String)> =
            toks.iter().filter(|t| t.0 == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(lifetimes.iter().all(|t| t.1 == "'a"));
        let chars: Vec<&(TokKind, String)> =
            toks.iter().filter(|t| t.0 == TokKind::Char).collect();
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn byte_char_and_unicode_escape() {
        let toks = kinds("m(b'{')?; let u = '\\u{1F600}';");
        assert!(toks.iter().any(|t| t.0 == TokKind::Char && t.1 == "b'{'"));
        assert!(toks
            .iter()
            .any(|t| t.0 == TokKind::Char && t.1 == "'\\u{1F600}'"));
    }

    #[test]
    fn numbers_and_ranges() {
        let toks = kinds("0..n 1.5 0xFF 1_000 idx.0");
        assert_eq!(toks[0], (TokKind::Num, "0".to_string()));
        assert_eq!(toks[1], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[2], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[3], (TokKind::Ident, "n".to_string()));
        assert_eq!(toks[4], (TokKind::Num, "1.5".to_string()));
        assert_eq!(toks[5], (TokKind::Num, "0xFF".to_string()));
        assert_eq!(toks[6], (TokKind::Num, "1_000".to_string()));
        assert_eq!(toks[7], (TokKind::Ident, "idx".to_string()));
        assert_eq!(toks[8], (TokKind::Punct, ".".to_string()));
        assert_eq!(toks[9], (TokKind::Num, "0".to_string()));
    }

    #[test]
    fn multiline_string_keeps_line_numbers() {
        let toks = tokenize("let s = \"one\ntwo\";\nlet t = 1;");
        let t_tok = toks.iter().find(|t| t.text == "t").unwrap();
        assert_eq!(t_tok.line, 3, "line count continues through the string");
    }
}
