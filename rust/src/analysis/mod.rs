//! Rule-based static analysis over the repo's own source (`tunetuner
//! lint`): the determinism, persistence, and panic-discipline contracts
//! PRs 1–9 established by convention, codified as checkable rules and
//! gated in CI.
//!
//! The engine is a span-accurate token walk ([`lexer`]) — `syn` is not
//! vendored, and the rules only need token patterns, not types. Each
//! file is tokenized once; [`test_mask`] marks `#[test]`/`#[cfg(test)]`
//! regions (exempt from every rule but W00), [`rules::check`] produces
//! raw diagnostics, and inline allow directives ([`allow`]) suppress
//! individual sites with a mandatory written justification. Malformed
//! directives are themselves reported as rule `W00` and can never be
//! suppressed or un-denied.
//!
//! Entry points: [`lint_source`] for one in-memory file (what the
//! fixture tests drive) and [`lint_tree`] for a directory walk (what
//! the CLI and the `repo_is_lint_clean` golden test drive). Rendering
//! and the versioned `tunetuner-lint` JSON envelope live in [`report`].

pub mod allow;
pub mod lexer;
pub mod report;
pub mod rules;

use crate::error::Result;
use lexer::{TokKind, Token};
use std::path::Path;

pub use rules::RuleId;

/// One finding: a rule violation at an exact source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: RuleId,
    /// `/`-normalized path as given to the engine; [`lint_tree`] passes
    /// paths relative to the walk root.
    pub path: String,
    pub line: u32,
    pub col: u32,
    pub message: String,
}

/// Lint result for a single file.
#[derive(Debug, Default)]
pub struct FileLint {
    /// Violations that survived suppression, in (line, col) order.
    pub diagnostics: Vec<Diagnostic>,
    /// Violations silenced by a well-formed allow directive.
    pub suppressed: usize,
    /// Well-formed allow directives seen in the file.
    pub allows: usize,
}

/// Aggregated lint result for a directory tree.
#[derive(Debug)]
pub struct LintReport {
    /// Root the walk started from, as given.
    pub root: String,
    /// Number of `.rs` files scanned.
    pub files: usize,
    pub diagnostics: Vec<Diagnostic>,
    pub suppressed: usize,
    pub allows: usize,
}

/// Which rules fail the run (vs merely report). Parsed from `--deny`:
/// `all`, `none`, or a comma list like `W01,W03`. `W00` is always
/// denied regardless of the spec — a malformed suppression must never
/// pass silently.
#[derive(Clone, Debug)]
pub struct DenySet {
    all: bool,
    rules: Vec<RuleId>,
}

impl DenySet {
    pub fn parse(spec: &str) -> Result<DenySet> {
        let spec = spec.trim();
        match spec {
            "all" => {
                return Ok(DenySet {
                    all: true,
                    rules: Vec::new(),
                })
            }
            "none" => {
                return Ok(DenySet {
                    all: false,
                    rules: Vec::new(),
                })
            }
            _ => {}
        }
        let mut rules = Vec::new();
        for part in spec.split(',') {
            match RuleId::parse(part) {
                Some(id) => rules.push(id),
                None => crate::bail!(
                    "--deny expects `all`, `none`, or a comma list of W01..W05; got {part:?}"
                ),
            }
        }
        Ok(DenySet { all: false, rules })
    }

    /// Does a diagnostic with this rule fail the run?
    pub fn denies(&self, rule: RuleId) -> bool {
        rule == RuleId::W00 || self.all || self.rules.contains(&rule)
    }
}

/// Mark every token inside test code: an item annotated `#[test]` /
/// `#[cfg(test)]` (any attribute whose idents include `test` but not
/// `not`, so `#[cfg(not(test))]` items stay live code), through the
/// item's closing brace (or terminating `;`). An inner `#![cfg(test)]`
/// marks the whole file.
pub fn test_mask(tokens: &[Token]) -> Vec<bool> {
    let mut mask = vec![false; tokens.len()];
    let is_punct = |i: usize, c: char| {
        tokens
            .get(i)
            .map(|t| t.kind == TokKind::Punct && t.text.chars().next() == Some(c))
            .unwrap_or(false)
    };
    let mut i = 0usize;
    while i < tokens.len() {
        if !is_punct(i, '#') {
            i += 1;
            continue;
        }
        let attr_start = i;
        let mut j = i + 1;
        let inner = is_punct(j, '!');
        if inner {
            j += 1;
        }
        if !is_punct(j, '[') {
            i += 1;
            continue;
        }
        // Scan to the matching `]`, noting the idents inside.
        let mut depth = 0usize;
        let mut has_test = false;
        let mut has_not = false;
        let mut k = j;
        while k < tokens.len() {
            let t = &tokens[k];
            if t.kind == TokKind::Punct {
                match t.text.chars().next() {
                    Some('[') => depth += 1,
                    Some(']') => {
                        depth = depth.saturating_sub(1);
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
            } else if t.kind == TokKind::Ident {
                if t.text == "test" {
                    has_test = true;
                } else if t.text == "not" {
                    has_not = true;
                }
            }
            k += 1;
        }
        if !(has_test && !has_not) {
            i = k + 1;
            continue;
        }
        if inner {
            for m in mask.iter_mut() {
                *m = true;
            }
            return mask;
        }
        // Skip any further attributes between this one and the item.
        let mut p = k + 1;
        while is_punct(p, '#') && is_punct(p + 1, '[') {
            let mut d = 0usize;
            let mut q = p + 1;
            while q < tokens.len() {
                if is_punct(q, '[') {
                    d += 1;
                } else if is_punct(q, ']') {
                    d = d.saturating_sub(1);
                    if d == 0 {
                        break;
                    }
                }
                q += 1;
            }
            p = q + 1;
        }
        // The item runs to its matching close brace, or to a `;` for
        // brace-less items (`#[cfg(test)] use ...;`, `mod tests;`).
        let mut end = tokens.len().saturating_sub(1);
        let mut q = p;
        while q < tokens.len() {
            if is_punct(q, ';') {
                end = q;
                break;
            }
            if is_punct(q, '{') {
                let mut d = 0usize;
                let mut r = q;
                end = tokens.len().saturating_sub(1);
                while r < tokens.len() {
                    if is_punct(r, '{') {
                        d += 1;
                    } else if is_punct(r, '}') {
                        d = d.saturating_sub(1);
                        if d == 0 {
                            end = r;
                            break;
                        }
                    }
                    r += 1;
                }
                break;
            }
            q += 1;
        }
        for m in mask.iter_mut().take(end + 1).skip(attr_start) {
            *m = true;
        }
        i = end + 1;
    }
    mask
}

/// Lint one file's source. `rel_path` is used for diagnostics and for
/// the per-module whitelists (root-anchored, `/`-normalized; see
/// `rules::in_module`).
pub fn lint_source(rel_path: &str, source: &str) -> FileLint {
    let tokens = lexer::tokenize(source);
    let mask = test_mask(&tokens);
    let mut diags = rules::check(rel_path, &tokens, &mask);
    let path_norm = rel_path.replace('\\', "/");

    // Collect directives; malformed ones become W00 diagnostics.
    let mut covers: Vec<(Vec<RuleId>, [u32; 3])> = Vec::new();
    for t in &tokens {
        if t.kind != TokKind::Comment {
            continue;
        }
        match allow::parse_comment(&t.text, t.line, t.col) {
            None => {}
            Some(Ok(d)) => {
                let end = d.line + t.text.matches('\n').count() as u32;
                covers.push((d.rules, [d.line, end, 0]));
            }
            Some(Err(b)) => diags.push(Diagnostic {
                rule: RuleId::W00,
                path: path_norm.clone(),
                line: b.line,
                col: b.col,
                message: b.message,
            }),
        }
    }
    let allows = covers.len();

    // A directive on a comment-only line covers its own line(s) plus
    // the next line holding code (comment-above placement). A directive
    // sharing a line with code (trailing placement) covers only its own
    // line(s) — extending it would let one justified allow silently
    // suppress an unrelated violation on the following statement.
    let mut suppressed = 0usize;
    if !covers.is_empty() {
        let code_lines: Vec<u32> = tokens
            .iter()
            .filter(|t| t.kind != TokKind::Comment)
            .map(|t| t.line)
            .collect();
        for (_, lines) in covers.iter_mut() {
            let (start, end) = (lines[0], lines[1]);
            let trailing = code_lines.iter().any(|&l| l >= start && l <= end);
            lines[2] = if trailing {
                0 // lines are 1-based, so 0 matches no diagnostic
            } else {
                code_lines
                    .iter()
                    .copied()
                    .filter(|&l| l > end)
                    .min()
                    .unwrap_or(0)
            };
        }
        diags.retain(|d| {
            if d.rule == RuleId::W00 {
                return true;
            }
            let hit = covers
                .iter()
                .any(|(rules, lines)| rules.contains(&d.rule) && lines.contains(&d.line));
            if hit {
                suppressed += 1;
            }
            !hit
        });
    }

    diags.sort_by_key(|d| (d.line, d.col, d.rule));
    FileLint {
        diagnostics: diags,
        suppressed,
        allows,
    }
}

/// Recursively collect `.rs` files under `dir`, sorted by path so the
/// report (and the envelope) is deterministic.
fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        // DirEntry::file_type does not follow symlinks: a link is
        // skipped outright, so a directory-symlink cycle cannot recurse
        // forever and out-of-tree targets are never linted as in-tree.
        let ft = entry.file_type()?;
        if ft.is_symlink() {
            continue;
        }
        entries.push((entry.path(), ft.is_dir()));
    }
    entries.sort();
    for (p, is_dir) in entries {
        if is_dir {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (the CLI default is `rust/src`).
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    let mut report = LintReport {
        root: root.to_string_lossy().replace('\\', "/"),
        files: files.len(),
        diagnostics: Vec::new(),
        suppressed: 0,
        allows: 0,
    };
    for f in &files {
        let source = std::fs::read_to_string(f)?;
        let rel = f.strip_prefix(root).unwrap_or(f.as_path());
        let rel = rel.to_string_lossy().replace('\\', "/");
        let fl = lint_source(&rel, &source);
        report.diagnostics.extend(fl.diagnostics);
        report.suppressed += fl.suppressed;
        report.allows += fl.allows;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fired(src: &str) -> Vec<RuleId> {
        lint_source("x/sample.rs", src)
            .diagnostics
            .iter()
            .map(|d| d.rule)
            .collect()
    }

    // ---- W01: nondeterminism ----------------------------------------

    #[test]
    fn w01_fires_on_wallclock() {
        let src = "fn f() -> u64 { let t = std::time::Instant::now(); 0 }";
        assert_eq!(fired(src), vec![RuleId::W01]);
        let src = "fn f() { let t = SystemTime::now(); }";
        assert_eq!(fired(src), vec![RuleId::W01]);
    }

    #[test]
    fn w01_silent_on_corrected_and_whitelisted() {
        assert!(fired("fn f(start_ns: u64) -> u64 { start_ns }").is_empty());
        let src = "fn f() { let t = Instant::now(); }";
        let fl = lint_source("rust/src/util/log.rs", src);
        assert!(fl.diagnostics.is_empty(), "timing module is whitelisted");
    }

    #[test]
    fn w01_fires_on_std_hashmap() {
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, u32>) {}";
        assert_eq!(fired(src), vec![RuleId::W01, RuleId::W01]);
    }

    #[test]
    fn w01_silent_on_fastmap_and_in_hash_module() {
        let src = "use crate::util::hash::FastMap;\nfn f(m: &FastMap<u32, u32>) {}";
        assert!(fired(src).is_empty());
        let src = "use std::collections::HashMap;";
        let fl = lint_source("rust/src/util/hash.rs", src);
        assert!(fl.diagnostics.is_empty(), "hash wrapper is whitelisted");
    }

    // ---- W02: persistence -------------------------------------------

    #[test]
    fn w02_fires_on_raw_writes() {
        let src = "fn save(p: &Path) { std::fs::write(p, b\"x\").ok(); }";
        assert_eq!(fired(src), vec![RuleId::W02]);
        let src = "fn save(p: &Path) { let f = File::create(p); }";
        assert_eq!(fired(src), vec![RuleId::W02]);
        let src = "fn mv(a: &Path, b: &Path) { fs::rename(a, b).ok(); }";
        assert_eq!(fired(src), vec![RuleId::W02]);
    }

    #[test]
    fn w02_silent_on_atomic_write_and_in_fsio() {
        let src = "fn save(p: &Path, b: &[u8]) -> Result<()> { atomic_write(p, b) }";
        assert!(fired(src).is_empty());
        let src = "fn stage(p: &Path) { std::fs::write(p, b\"x\").ok(); }";
        let fl = lint_source("rust/src/util/fsio.rs", src);
        assert!(fl.diagnostics.is_empty(), "fsio implements the discipline");
    }

    #[test]
    fn w02_silent_in_test_code() {
        let src = "#[cfg(test)]\nmod tests {\n fn f() { std::fs::write(\"t\", b\"x\").ok(); }\n}";
        assert!(fired(src).is_empty());
    }

    // ---- W03: panic discipline --------------------------------------

    #[test]
    fn w03_fires_on_unwrap_expect_panic() {
        assert_eq!(fired("fn f(o: Option<u8>) -> u8 { o.unwrap() }"), vec![RuleId::W03]);
        let src = "fn f(o: Option<u8>) -> u8 { o.expect(\"present\") }";
        assert_eq!(fired(src), vec![RuleId::W03]);
        assert_eq!(fired("fn f() { panic!(\"boom\"); }"), vec![RuleId::W03]);
        assert_eq!(fired("fn f() { todo!(); }"), vec![RuleId::W03]);
    }

    #[test]
    fn w03_silent_on_typed_errors_and_idioms() {
        let src = "fn f(o: Option<u8>) -> Result<u8> { o.context(\"missing\") }";
        assert!(fired(src).is_empty());
        // Mutex-poisoning propagation idiom: unwrap directly on lock().
        assert!(fired("fn f(m: &Mutex<u8>) -> u8 { *m.lock().unwrap() }").is_empty());
        let src = "fn f(p: Pool) -> u8 { p.inner.wait(g).unwrap().1 }";
        assert!(fired(src).is_empty());
        // A fallible user `expect` method propagated with `?`.
        assert!(fired("fn f(&mut self) -> Result<()> { self.expect(b'{')?; Ok(()) }").is_empty());
        // Invariant assertion stays allowed.
        assert!(fired("fn f(x: u8) { if x > 2 { unreachable!() } }").is_empty());
    }

    #[test]
    fn w03_silent_in_test_fn() {
        let src = "#[test]\nfn t() { assert_eq!(parse(\"x\").unwrap(), 1); }";
        assert!(fired(src).is_empty());
    }

    // ---- W04: float ordering ----------------------------------------

    #[test]
    fn w04_fires_on_partial_cmp() {
        let src = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        let rules = fired(src);
        assert!(rules.contains(&RuleId::W04), "{rules:?}");
    }

    #[test]
    fn w04_silent_on_total_cmp() {
        let src = "fn f(v: &mut [f64]) { v.sort_by(|a, b| a.total_cmp(b)); }";
        assert!(fired(src).is_empty());
    }

    // ---- W05: RNG discipline ----------------------------------------

    #[test]
    fn w05_fires_on_foreign_rng_and_literal_seed() {
        assert_eq!(fired("fn f() { let mut r = thread_rng(); }"), vec![RuleId::W05]);
        assert_eq!(fired("fn f() { let r = Rng::new(42); }"), vec![RuleId::W05]);
        let src = "fn f() { let r = Rng::new(0xDEAD_BEEF); }";
        assert_eq!(fired(src), vec![RuleId::W05]);
    }

    #[test]
    fn w05_silent_on_derived_seed_and_in_rng_module() {
        assert!(fired("fn f(seed: u64) { let r = Rng::new(seed); }").is_empty());
        let src = "fn f(s: u64) { let r = Rng::new(mix64(s, 7)); }";
        assert!(fired(src).is_empty());
        let fl = lint_source("rust/src/util/rng.rs", "fn f() { let r = Rng::new(1); }");
        assert!(fl.diagnostics.is_empty(), "rng module is whitelisted");
    }

    // ---- allow directives -------------------------------------------

    #[test]
    fn allow_suppresses_next_code_line() {
        let src = "fn f(o: Option<u8>) -> u8 {\n\
                   // lint: allow(W03, reason = \"guarded by caller\")\n\
                   o.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        assert!(fl.diagnostics.is_empty(), "{:?}", fl.diagnostics);
        assert_eq!(fl.suppressed, 1);
        assert_eq!(fl.allows, 1);
    }

    #[test]
    fn allow_suppresses_trailing_comment_line() {
        let src = "fn f(o: Option<u8>) -> u8 {\n\
                   o.unwrap() // lint: allow(W03, reason = \"guarded\")\n}";
        let fl = lint_source("x/sample.rs", src);
        assert!(fl.diagnostics.is_empty(), "{:?}", fl.diagnostics);
        assert_eq!(fl.suppressed, 1);
    }

    #[test]
    fn trailing_allow_does_not_cover_next_line() {
        let src = "fn f(a: Option<u8>, b: Option<u8>) -> u8 {\n\
                   let x = a.unwrap(); // lint: allow(W03, reason = \"guarded\")\n\
                   x + b.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        assert_eq!(fl.diagnostics.len(), 1, "{:?}", fl.diagnostics);
        assert_eq!(fl.diagnostics[0].rule, RuleId::W03);
        assert_eq!(fl.diagnostics[0].line, 3, "line 3's unwrap needs its own allow");
        assert_eq!(fl.suppressed, 1);
    }

    #[test]
    fn module_whitelist_is_root_anchored_not_suffix_matched() {
        // A file that merely *ends* in a whitelisted module path (a
        // fixture tree, vendored code) must not inherit the exemption.
        let src = "fn f() { let r = Rng::new(1); }";
        let fl = lint_source("fixtures/util/rng.rs", src);
        assert_eq!(fl.diagnostics.len(), 1, "{:?}", fl.diagnostics);
        assert_eq!(fl.diagnostics[0].rule, RuleId::W05);
        let src = "fn stage(p: &Path) { std::fs::write(p, b\"x\").ok(); }";
        let fl = lint_source("vendor/other/src/util/fsio.rs", src);
        assert_eq!(fl.diagnostics.len(), 1, "{:?}", fl.diagnostics);
        assert_eq!(fl.diagnostics[0].rule, RuleId::W02);
    }

    #[test]
    fn allow_for_other_rule_does_not_suppress() {
        let src = "fn f(o: Option<u8>) -> u8 {\n\
                   // lint: allow(W01, reason = \"wrong rule\")\n\
                   o.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        assert_eq!(fl.diagnostics.len(), 1);
        assert_eq!(fl.diagnostics[0].rule, RuleId::W03);
        assert_eq!(fl.suppressed, 0);
    }

    #[test]
    fn malformed_allow_reports_w00() {
        let src = "fn f(o: Option<u8>) -> u8 {\n\
                   // lint: allow(W03)\n\
                   o.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        let rules: Vec<RuleId> = fl.diagnostics.iter().map(|d| d.rule).collect();
        assert!(rules.contains(&RuleId::W00), "{rules:?}");
        assert!(rules.contains(&RuleId::W03), "broken directive must not suppress");
    }

    // ---- deny set ----------------------------------------------------

    #[test]
    fn deny_set_parsing_and_membership() {
        let all = DenySet::parse("all").unwrap();
        assert!(all.denies(RuleId::W03) && all.denies(RuleId::W00));
        let none = DenySet::parse("none").unwrap();
        assert!(!none.denies(RuleId::W03));
        assert!(none.denies(RuleId::W00), "W00 is always denied");
        let some = DenySet::parse("W01,W04").unwrap();
        assert!(some.denies(RuleId::W04) && !some.denies(RuleId::W03));
        assert!(DenySet::parse("bogus").is_err());
    }

    // ---- spans and test-mask edges ----------------------------------

    #[test]
    fn diagnostics_carry_exact_spans() {
        let src = "fn f(o: Option<u8>) -> u8 {\n    o.unwrap()\n}";
        let fl = lint_source("x/sample.rs", src);
        assert_eq!(fl.diagnostics.len(), 1);
        let d = &fl.diagnostics[0];
        assert_eq!((d.line, d.col), (2, 7), "points at the `unwrap` ident");
        assert_eq!(d.path, "x/sample.rs");
    }

    #[test]
    fn cfg_not_test_is_still_live_code() {
        let src = "#[cfg(not(test))]\nfn f(o: Option<u8>) -> u8 { o.unwrap() }";
        assert_eq!(fired(src), vec![RuleId::W03]);
    }

    #[test]
    fn code_after_test_region_is_live_again() {
        let src = "#[test]\nfn t() { x.unwrap(); }\n\
                   fn live(o: Option<u8>) -> u8 { o.unwrap() }";
        assert_eq!(fired(src), vec![RuleId::W03]);
    }
}
