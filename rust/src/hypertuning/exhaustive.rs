//! Exhaustive hyperparameter tuning (Section IV-B).
//!
//! Every hyperparameter configuration in a (limited) space is evaluated
//! with `repeats` simulated tuning runs on each training space; the
//! aggregate score (Eq. 3) per configuration is recorded. For the paper's
//! Table III spaces this is e.g. 108 configs × 25 repeats × 12 spaces =
//! 32 400 optimization runs for the genetic algorithm — tractable only in
//! simulation mode.
//!
//! The spaces come schema-derived from the optimizer registry
//! ([`super::space`]), so every configuration a campaign evaluates is
//! schema-valid by construction — `optimizers::create` hard-rejects
//! anything else.

use super::space;
use crate::campaign::{Campaign, NullObserver, Observer};
use crate::error::{Context, Result};
use crate::methodology::SpaceEval;
use crate::optimizers::HyperParams;
use crate::util::compress;
use crate::util::json::{self, Json};
use std::path::Path;
use std::sync::Arc;

/// Score of one hyperparameter configuration.
#[derive(Clone, Debug)]
pub struct HyperResult {
    /// Index into the hyperparameter search space.
    pub config_idx: usize,
    /// Stable `k=v,k=v` key of the hyperparameters.
    pub hp_key: String,
    /// Aggregate performance score (Eq. 3) across the training spaces.
    pub score: f64,
}

/// Stable fingerprint of a hyperparameter space's structure (parameter
/// names and exact value grids, plus the enumerated size): persisted with
/// campaign results so a later schema/grid change invalidates stale
/// caches instead of silently misdecoding their `config_idx` values
/// against the new space. Now lives on the space itself
/// ([`crate::searchspace::SearchSpace::fingerprint`]) so kernel spaces
/// carry the same provenance; kept here as the established call site.
pub fn space_fingerprint(space: &crate::searchspace::SearchSpace) -> String {
    space.fingerprint()
}

/// The outcome of a hyperparameter tuning campaign.
#[derive(Clone, Debug)]
pub struct HyperTuningResults {
    pub algo: String,
    /// "limited" (Table III) or "extended" (Table IV).
    pub space_kind: String,
    /// [`space_fingerprint`] of the space the campaign ran on (empty in
    /// files written before fingerprinting existed — treated as stale).
    pub space_key: String,
    pub repeats: usize,
    pub seed: u64,
    /// One entry per evaluated configuration (exhaustive: all of them).
    pub results: Vec<HyperResult>,
    /// Real wall-clock seconds the campaign took.
    pub wallclock_seconds: f64,
    /// Simulated device-seconds the campaign *would* have cost live.
    pub simulated_seconds: f64,
}

impl HyperTuningResults {
    /// Total order on scores that demotes NaN (a failed evaluation) below
    /// every real score, so one NaN can never panic — or win — a campaign.
    fn nan_last(s: f64) -> f64 {
        if s.is_nan() {
            f64::NEG_INFINITY
        } else {
            s
        }
    }

    pub fn best(&self) -> &HyperResult {
        self.results
            .iter()
            .max_by(|a, b| Self::nan_last(a.score).total_cmp(&Self::nan_last(b.score)))
            // lint: allow(W03, reason = "results are non-empty after a sweep")
            .expect("no results")
    }

    pub fn worst(&self) -> &HyperResult {
        // NaN → +inf here: total_cmp orders a sign-negative NaN below
        // -inf, which would otherwise let a failed evaluation win "worst".
        let key = |s: f64| if s.is_nan() { f64::INFINITY } else { s };
        self.results
            .iter()
            .min_by(|a, b| key(a.score).total_cmp(&key(b.score)))
            // lint: allow(W03, reason = "results are non-empty after a sweep")
            .expect("no results")
    }

    /// The configuration whose score is closest to the mean — the paper's
    /// "most average-performing hyperparameter configuration".
    pub fn most_average(&self) -> &HyperResult {
        // Mean over real scores only: one NaN would otherwise poison the
        // mean and with it every distance below.
        let finite: Vec<f64> = self
            .results
            .iter()
            .map(|r| r.score)
            .filter(|s| !s.is_nan())
            .collect();
        let mean = if finite.is_empty() {
            0.0
        } else {
            crate::util::stats::mean(&finite)
        };
        self.results
            .iter()
            .min_by(|a, b| {
                // NaN distances sort last (total_cmp: NaN > +inf), so a
                // finite-scored config is always preferred when one exists.
                (a.score - mean)
                    .abs()
                    .total_cmp(&(b.score - mean).abs())
            })
            // lint: allow(W03, reason = "results are non-empty after a sweep")
            .expect("no results")
    }

    pub fn scores(&self) -> Vec<f64> {
        self.results.iter().map(|r| r.score).collect()
    }

    /// Hyperparameters of a result, reconstructed from its space.
    pub fn hyperparams(&self, r: &HyperResult) -> Result<HyperParams> {
        let sp = match self.space_kind.as_str() {
            "limited" => space::limited_space(&self.algo)?,
            _ => space::extended_space(&self.algo)?,
        };
        Ok(HyperParams::from_space_config(&sp, r.config_idx))
    }

    // ---- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("config_idx", r.config_idx.into())
                    .set("hp_key", r.hp_key.as_str().into())
                    .set("score", r.score.into());
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("schema", "tunetuner-hypertuning".into())
            .set("algo", self.algo.as_str().into())
            .set("space_kind", self.space_kind.as_str().into())
            .set("space_key", self.space_key.as_str().into())
            .set("repeats", self.repeats.into())
            .set("seed", (self.seed as f64).into())
            .set("wallclock_seconds", self.wallclock_seconds.into())
            .set("simulated_seconds", self.simulated_seconds.into())
            .set("results", Json::Arr(results));
        j
    }

    pub fn from_json(j: &Json) -> Result<HyperTuningResults> {
        let results = j
            .get("results")
            .and_then(|v| v.as_arr())
            .context("missing results")?
            .iter()
            .map(|r| {
                Ok(HyperResult {
                    config_idx: r
                        .get("config_idx")
                        .and_then(|v| v.as_usize())
                        .context("missing config_idx")?,
                    hp_key: r
                        .get("hp_key")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    score: r
                        .get("score")
                        .and_then(|v| v.as_f64())
                        .context("missing score")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(HyperTuningResults {
            algo: j
                .get("algo")
                .and_then(|v| v.as_str())
                .context("missing algo")?
                .to_string(),
            space_kind: j
                .get("space_kind")
                .and_then(|v| v.as_str())
                .unwrap_or("limited")
                .to_string(),
            space_key: j
                .get("space_key")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            repeats: j.get("repeats").and_then(|v| v.as_usize()).unwrap_or(0),
            seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            wallclock_seconds: j
                .get("wallclock_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            simulated_seconds: j
                .get("simulated_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            results,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        compress::write_string(path, &self.to_json().to_string())
    }

    pub fn load(path: &Path) -> Result<HyperTuningResults> {
        HyperTuningResults::from_json(&json::parse(&compress::read_string(path)?)?)
    }
}

/// Exhaustively evaluate every hyperparameter configuration of `algo`'s
/// space on the training spaces.
pub fn exhaustive_tuning(
    algo: &str,
    hp_space: &crate::searchspace::SearchSpace,
    space_kind: &str,
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
) -> Result<HyperTuningResults> {
    exhaustive_tuning_observed(
        algo,
        hp_space,
        space_kind,
        train,
        repeats,
        seed,
        Arc::new(NullObserver),
    )
}

/// [`exhaustive_tuning`] with campaign progress reported to `observer`
/// (one [`Observer::config_scored`] per evaluated configuration, plus the
/// per-campaign events).
pub fn exhaustive_tuning_observed(
    algo: &str,
    hp_space: &crate::searchspace::SearchSpace,
    space_kind: &str,
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    observer: Arc<dyn Observer>,
) -> Result<HyperTuningResults> {
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let t0 = std::time::Instant::now();
    // One campaign per configuration, all sharing the prepared spaces and
    // the persistent executor pool.
    let base = Campaign::new(algo)
        .space_evals(train.to_vec())
        .repeats(repeats)
        .seed(seed)
        .observer(Arc::clone(&observer));
    let mut results = Vec::with_capacity(hp_space.len());
    let mut simulated = 0.0;
    for idx in 0..hp_space.len() {
        let hp = HyperParams::from_space_config(hp_space, idx);
        let agg = base.with_hyperparams(&hp).run()?.aggregate;
        // Simulated cost: every run consumes its space's full budget.
        simulated +=
            train.iter().map(|s| s.budget_seconds).sum::<f64>() * repeats as f64;
        let hp_key = hp.key();
        observer.config_scored(idx, &hp_key, agg.score);
        results.push(HyperResult {
            config_idx: idx,
            hp_key,
            score: agg.score,
        });
        if idx % 10 == 9 {
            crate::log_debug!(
                "hypertuning {algo}: {}/{} configs",
                idx + 1,
                hp_space.len()
            );
        }
    }
    Ok(HyperTuningResults {
        algo: algo.to_string(),
        space_kind: space_kind.to_string(),
        space_key: space_fingerprint(hp_space),
        repeats,
        seed,
        results,
        wallclock_seconds: t0.elapsed().as_secs_f64(),
        simulated_seconds: simulated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::{A100, MI250X};
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::LiveRunner;
    use crate::runtime::Engine;
    use std::sync::Arc;

    fn train_spaces() -> Vec<SpaceEval> {
        let engine = Arc::new(Engine::native());
        [&A100, &MI250X]
            .iter()
            .map(|dev| {
                let kernel = kernels::kernel_by_name("synthetic").unwrap();
                let mut live = LiveRunner::new(
                    kernels::kernel_by_name("synthetic").unwrap(),
                    dev,
                    Arc::clone(&engine),
                    NoiseModel::default(),
                    42,
                );
                let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                SpaceEval::new(kernel.space_arc(), cache, 0.95, 10)
            })
            .collect()
    }

    #[test]
    fn exhaustive_dual_annealing_small() {
        let train = train_spaces();
        let hp_space = space::limited_space("dual_annealing").unwrap();
        let r = exhaustive_tuning("dual_annealing", &hp_space, "limited", &train, 5, 3)
            .unwrap();
        assert_eq!(r.results.len(), 8);
        // Scores differ across methods (the hyperparameter has signal).
        let scores = r.scores();
        let spread = crate::util::stats::max(&scores) - crate::util::stats::min(&scores);
        assert!(spread > 0.0, "no spread in {scores:?}");
        assert!(r.best().score >= r.most_average().score);
        assert!(r.most_average().score >= r.worst().score);
        assert!(r.simulated_seconds > r.wallclock_seconds * 10.0);
        assert_eq!(r.space_key, space_fingerprint(&hp_space));
    }

    #[test]
    fn space_fingerprint_stable_and_discriminating() {
        let pso = space_fingerprint(&space::limited_space("pso").unwrap());
        let pso2 = space_fingerprint(&space::limited_space("pso").unwrap());
        let sa = space_fingerprint(&space::limited_space("simulated_annealing").unwrap());
        let sa_ext = space_fingerprint(&space::extended_space("simulated_annealing").unwrap());
        assert_eq!(pso, pso2);
        assert_ne!(pso, sa);
        assert_ne!(sa, sa_ext);
    }

    #[test]
    fn persistence_roundtrip() {
        let r = HyperTuningResults {
            algo: "pso".into(),
            space_kind: "limited".into(),
            space_key: "fp-test".into(),
            repeats: 25,
            seed: 9,
            results: vec![
                HyperResult {
                    config_idx: 0,
                    hp_key: "c1=1".into(),
                    score: 0.25,
                },
                HyperResult {
                    config_idx: 1,
                    hp_key: "c1=2".into(),
                    score: -0.5,
                },
            ],
            wallclock_seconds: 12.0,
            simulated_seconds: 99999.0,
        };
        let dir = std::env::temp_dir().join(format!("tt_ht_{}", std::process::id()));
        let path = dir.join("pso.json.gz");
        r.save(&path).unwrap();
        let back = HyperTuningResults::load(&path).unwrap();
        assert_eq!(back.algo, "pso");
        assert_eq!(back.space_key, "fp-test");
        assert_eq!(back.results.len(), 2);
        assert_eq!(back.best().score, 0.25);
        assert_eq!(back.worst().hp_key, "c1=2");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn nan_score_does_not_panic_selection() {
        // Regression: partial_cmp().unwrap() used to panic the whole
        // campaign on a single NaN score.
        let r = HyperTuningResults {
            algo: "pso".into(),
            space_kind: "limited".into(),
            space_key: String::new(),
            repeats: 1,
            seed: 0,
            results: vec![
                HyperResult {
                    config_idx: 0,
                    hp_key: "a".into(),
                    score: f64::NAN,
                },
                HyperResult {
                    config_idx: 1,
                    hp_key: "b".into(),
                    score: 0.4,
                },
                HyperResult {
                    config_idx: 2,
                    hp_key: "c".into(),
                    score: -0.2,
                },
                HyperResult {
                    config_idx: 3,
                    hp_key: "d".into(),
                    // Sign-negative NaN: total_cmp orders it below -inf,
                    // so an unguarded min_by would select it as "worst".
                    score: -f64::NAN,
                },
            ],
            wallclock_seconds: 1.0,
            simulated_seconds: 1.0,
        };
        // NaN never wins "best"; worst/most_average pick real scores.
        assert_eq!(r.best().config_idx, 1);
        assert_eq!(r.worst().config_idx, 2);
        assert!(!r.most_average().score.is_nan());
    }

    #[test]
    fn hyperparams_reconstruction() {
        let hp_space = space::limited_space("simulated_annealing").unwrap();
        let train = train_spaces();
        let r = exhaustive_tuning(
            "simulated_annealing",
            &hp_space,
            "limited",
            &train[..1],
            2,
            1,
        )
        .unwrap();
        let hp = r.hyperparams(r.best()).unwrap();
        assert!(hp.f64("T", -1.0) > 0.0);
        assert_eq!(hp.key(), r.best().hp_key);
    }
}
