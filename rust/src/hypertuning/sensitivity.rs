//! Hyperparameter sensitivity screening (Section IV-A).
//!
//! For every hyperparameter of an exhaustively evaluated space, group the
//! configuration scores by that hyperparameter's value and test whether
//! the groups differ: the non-parametric Kruskal–Wallis H test plus a
//! mutual-information score. The paper used exactly this screen to drop
//! PSO's `W` ("no meaningful effect") — which is why PSO's schema
//! declares `w` typed and defaulted but with no Table III/IV grid.

use super::exhaustive::HyperTuningResults;
use crate::searchspace::SearchSpace;
use crate::util::stats;

/// Sensitivity report for one hyperparameter.
#[derive(Clone, Debug)]
pub struct ParamSensitivity {
    pub param: String,
    /// Kruskal–Wallis H statistic across value groups.
    pub h: f64,
    /// χ²-approximated p-value (small = the hyperparameter matters).
    pub p: f64,
    /// Mutual information between value group and score (nats).
    pub mutual_information: f64,
}

/// Screen every hyperparameter of a tuned space.
pub fn sensitivity(
    results: &HyperTuningResults,
    hp_space: &SearchSpace,
) -> Vec<ParamSensitivity> {
    let scores: Vec<f64> = results.results.iter().map(|r| r.score).collect();
    let mut out = Vec::new();
    for (d, param) in hp_space.params.iter().enumerate() {
        let mut groups: Vec<Vec<f64>> = vec![Vec::new(); param.cardinality()];
        let mut labels: Vec<usize> = Vec::with_capacity(scores.len());
        for r in &results.results {
            let v = hp_space.digit(r.config_idx, d) as usize;
            groups[v].push(r.score);
            labels.push(v);
        }
        let (h, p) = stats::kruskal_wallis(&groups);
        let mi = stats::mutual_information(&labels, &scores, param.cardinality().max(2));
        out.push(ParamSensitivity {
            param: param.name.clone(),
            h,
            p,
            mutual_information: mi,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hypertuning::exhaustive::{HyperResult, HyperTuningResults};
    use crate::searchspace::{SearchSpace, TunableParam};

    /// Synthetic results where param `a` fully determines the score and
    /// param `b` is pure noise: the screen must rank `a` >> `b`.
    #[test]
    fn detects_sensitive_and_insensitive_params() {
        let space = SearchSpace::build(
            "hp-test",
            vec![
                TunableParam::new("a", vec![0i64, 1, 2]),
                TunableParam::new("b", vec![0i64, 1, 2, 3]),
                // Filler dimension so the sample is large enough for the
                // MI estimate to stabilize (12 -> 240 configurations).
                TunableParam::int_range("c", 0, 19, 1),
            ],
            vec![],
        )
        .unwrap();
        let results: Vec<HyperResult> = (0..space.len())
            .map(|i| {
                let enc = space.encoded(i);
                // score driven by `a`; tiny deterministic jitter from i.
                let score = enc[0] as f64 * 0.3 + ((i * 7919) % 13) as f64 * 1e-4;
                HyperResult {
                    config_idx: i,
                    hp_key: space.key(i),
                    score,
                }
            })
            .collect();
        let r = HyperTuningResults {
            algo: "test".into(),
            space_kind: "limited".into(),
            space_key: String::new(),
            repeats: 1,
            seed: 0,
            results,
            wallclock_seconds: 1.0,
            simulated_seconds: 1.0,
        };
        let sens = sensitivity(&r, &space);
        let a = sens.iter().find(|s| s.param == "a").unwrap();
        let b = sens.iter().find(|s| s.param == "b").unwrap();
        assert!(a.p < 0.01, "a should be significant: {a:?}");
        assert!(b.p > 0.2, "b should be insignificant: {b:?}");
        assert!(
            a.mutual_information > 3.0 * b.mutual_information.max(1e-6),
            "MI a={} b={}",
            a.mutual_information,
            b.mutual_information
        );
    }
}
