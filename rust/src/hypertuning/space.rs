//! The hyperparameter search spaces of Tables III and IV — *derived* from
//! the optimizer registry.
//!
//! Hyperparameter spaces are ordinary [`SearchSpace`]s — the same engine
//! that enumerates kernel configurations enumerates hyperparameter
//! configurations, which is exactly what lets Kernel Tuner's optimizers be
//! reused as meta-strategies.
//!
//! The spaces are no longer hand-written tables: every optimizer declares
//! its hyperparameters as a typed schema
//! ([`crate::optimizers::HyperSchema`]) with `limited` (Table III) and
//! `extended` (Table IV) value grids, and this module assembles those
//! grids into search spaces. The registry is therefore the single source
//! of truth — a schema edit changes the tables, the validation, and the
//! docs together. The golden tests below pin the derived Table III
//! spaces byte-identical to the previous hand-written tables; the
//! Table IV float grids *intentionally* differ from the pre-registry
//! code where the old accumulating `float_range` misgenerated them
//! (most visibly simulated annealing's `T_min`, whose smallest value
//! came out as 0.0 instead of 0.0001) — the goldens encode the fixed,
//! index-generated semantics.

use crate::optimizers;
use crate::searchspace::{SearchSpace, TunableParam, Value};
use crate::bail;
use crate::error::Result;

/// The paper's Table III algorithms, in Table III order. Scoped to the
/// `Descriptor::paper` flag so extra optimizers can declare grids (and
/// get spaces via [`limited_space`]) without joining the paper drivers.
pub fn limited_algos() -> Vec<&'static str> {
    optimizers::paper_algorithms()
}

/// The paper's Table IV algorithms — the Table III set minus those with
/// no tunable numerical hyperparameters (dual annealing's single
/// categorical is excluded, as in the paper) — in Table IV order.
pub fn extended_algos() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = optimizers::registry()
        .iter()
        .filter(|d| d.paper && d.has_extended_space())
        .map(|d| d.name)
        .collect();
    names.sort_unstable();
    names
}

/// Assemble a search space from one grid (limited or extended) of an
/// optimizer's schema, preserving schema declaration order.
fn derive_space(
    algo: &str,
    kind: &str,
    grid: fn(&optimizers::HyperSchema) -> &[Value],
) -> Result<SearchSpace> {
    let desc = optimizers::descriptor(algo)?;
    let params: Vec<TunableParam> = desc
        .schema
        .iter()
        .filter(|s| !grid(s).is_empty())
        .map(|s| TunableParam {
            name: s.name.to_string(),
            values: grid(s).to_vec(),
        })
        .collect();
    if params.is_empty() {
        bail!("no {kind} hyperparameter space for {algo:?}");
    }
    SearchSpace::build(&format!("hp-{algo}-{kind}"), params, vec![])
}

/// Table III: the limited, exhaustively evaluated hyperparameter spaces,
/// derived from the registry's `limited` grids.
pub fn limited_space(algo: &str) -> Result<SearchSpace> {
    derive_space(algo, "limited", |s| &s.limited)
}

/// Table IV: the extended hyperparameter spaces for meta-strategy tuning,
/// derived from the registry's `extended` grids.
pub fn extended_space(algo: &str) -> Result<SearchSpace> {
    derive_space(algo, "extended", |s| &s.extended)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_lists_match_paper_tables() {
        assert_eq!(
            limited_algos(),
            vec!["dual_annealing", "genetic_algorithm", "pso", "simulated_annealing"]
        );
        assert_eq!(
            extended_algos(),
            vec!["genetic_algorithm", "pso", "simulated_annealing"]
        );
    }

    /// The schema-declared grid sizes (`tunetuner info`'s per-optimizer
    /// column) always agree with the derived spaces — the sizes are
    /// computed from the same declarations the spaces are built from.
    #[test]
    fn declared_grid_sizes_match_derived_spaces() {
        for d in crate::optimizers::hypertunable() {
            assert_eq!(
                d.limited_grid_size(),
                limited_space(d.name).unwrap().len(),
                "{}: limited",
                d.name
            );
            if d.has_extended_space() {
                assert_eq!(
                    d.extended_grid_size(),
                    extended_space(d.name).unwrap().len(),
                    "{}: extended",
                    d.name
                );
            } else {
                assert_eq!(d.extended_grid_size(), 0, "{}", d.name);
            }
        }
    }

    #[test]
    fn limited_space_sizes_match_table3() {
        // Table III cardinalities: DA 8, GA 4*3*3*3=108, PSO 3*3*3*3=81,
        // SA 3*3*3*3=81.
        assert_eq!(limited_space("dual_annealing").unwrap().len(), 8);
        assert_eq!(limited_space("genetic_algorithm").unwrap().len(), 108);
        assert_eq!(limited_space("pso").unwrap().len(), 81);
        assert_eq!(limited_space("simulated_annealing").unwrap().len(), 81);
    }

    #[test]
    fn extended_spaces_are_much_larger() {
        for algo in extended_algos() {
            let lim = limited_space(algo).unwrap().len();
            let ext = extended_space(algo).unwrap().len();
            assert!(ext > 50 * lim, "{algo}: {ext} vs {lim}");
        }
        // Table IV cardinalities.
        assert_eq!(
            extended_space("genetic_algorithm").unwrap().len(),
            4 * 25 * 20 * 20
        );
        assert_eq!(extended_space("pso").unwrap().len(), 25 * 20 * 11 * 7);
        assert_eq!(
            extended_space("simulated_annealing").unwrap().len(),
            20 * 100 * 3 * 10
        );
    }

    #[test]
    fn configs_convert_to_hyperparams() {
        use crate::optimizers::HyperParams;
        let s = limited_space("genetic_algorithm").unwrap();
        let hp = HyperParams::from_space_config(&s, 0);
        assert!(!hp.str("method", "").is_empty());
        assert!(hp.usize("popsize", 0) > 0);
        // Every config must be accepted by the optimizer factory (which
        // now schema-validates every key).
        for idx in [0, s.len() / 2, s.len() - 1] {
            let hp = HyperParams::from_space_config(&s, idx);
            assert!(crate::optimizers::create("genetic_algorithm", &hp).is_ok());
        }
    }

    #[test]
    fn every_derived_config_passes_schema_validation() {
        // The derived spaces and the schema validation must agree by
        // construction: exhaustively instantiate the small spaces and
        // sample the large ones.
        use crate::optimizers::HyperParams;
        for algo in limited_algos() {
            let s = limited_space(algo).unwrap();
            for idx in (0..s.len()).step_by(1 + s.len() / 64) {
                let hp = HyperParams::from_space_config(&s, idx);
                crate::optimizers::create(algo, &hp)
                    .unwrap_or_else(|e| panic!("{algo} limited config {idx}: {e:#}"));
            }
        }
        for algo in extended_algos() {
            let s = extended_space(algo).unwrap();
            for idx in (0..s.len()).step_by(1 + s.len() / 64) {
                let hp = HyperParams::from_space_config(&s, idx);
                crate::optimizers::create(algo, &hp)
                    .unwrap_or_else(|e| panic!("{algo} extended config {idx}: {e:#}"));
            }
        }
    }

    #[test]
    fn unknown_algo_rejected() {
        assert!(limited_space("nope").is_err());
        assert!(extended_space("dual_annealing").is_err());
        assert!(limited_space("mls").is_err());
    }

    /// Any optimizer that declares grids gets a derived space — including
    /// the registry extras (`greedy_ils`, `basin_hopping`) — while
    /// grid-less optimizers are rejected, and the `Descriptor::paper`
    /// flag keeps the extras out of the paper-replication sets.
    #[test]
    fn derived_spaces_exist_for_every_optimizer_with_grids() {
        use crate::optimizers::{self, HyperParams};
        for d in optimizers::registry() {
            if d.has_limited_space() {
                let s = limited_space(d.name).unwrap();
                assert!(s.len() > 1, "{}: degenerate limited space", d.name);
                // Every derived configuration passes schema validation.
                for idx in [0, s.len() / 2, s.len() - 1] {
                    let hp = HyperParams::from_space_config(&s, idx);
                    optimizers::create(d.name, &hp)
                        .unwrap_or_else(|e| panic!("{} config {idx}: {e:#}", d.name));
                }
            } else {
                assert!(limited_space(d.name).is_err(), "{}", d.name);
            }
            if d.has_extended_space() {
                assert!(extended_space(d.name).unwrap().len() > 1, "{}", d.name);
            } else {
                assert!(extended_space(d.name).is_err(), "{}", d.name);
            }
        }
        // The ROADMAP extras are hypertunable (3×3 limited grids)...
        assert_eq!(limited_space("greedy_ils").unwrap().len(), 9);
        assert_eq!(limited_space("basin_hopping").unwrap().len(), 9);
        // ...but stay out of the paper's Table III/IV algorithm lists.
        assert!(!limited_algos().contains(&"greedy_ils"));
        assert!(!limited_algos().contains(&"basin_hopping"));
    }

    // ---- golden tests: derived spaces == the paper's hand-written tables --

    fn floats(values: &[f64]) -> Vec<Value> {
        values.iter().map(|&v| Value::Float(v)).collect()
    }

    /// Independent float grid for the goldens: explicit index arithmetic,
    /// no shared helper with production code.
    fn grid(lo: f64, step: f64, n: usize) -> Vec<Value> {
        (0..n)
            .map(|i| Value::Float(((lo + i as f64 * step) * 1e9).round() / 1e9))
            .collect()
    }

    /// The hand-written Table III tables exactly as previously coded.
    fn golden_limited(algo: &str) -> SearchSpace {
        let params = match algo {
            "dual_annealing" => vec![TunableParam::new(
                "method",
                vec![
                    "COBYLA",
                    "L-BFGS-B",
                    "SLSQP",
                    "CG",
                    "Powell",
                    "Nelder-Mead",
                    "BFGS",
                    "trust-constr",
                ],
            )],
            "genetic_algorithm" => vec![
                TunableParam::new(
                    "method",
                    vec!["single_point", "two_point", "uniform", "disruptive_uniform"],
                ),
                TunableParam::new("popsize", vec![10i64, 20, 30]),
                TunableParam::new("maxiter", vec![50i64, 100, 150]),
                TunableParam::new("mutation_chance", vec![5i64, 10, 20]),
            ],
            "pso" => vec![
                TunableParam::new("popsize", vec![10i64, 20, 30]),
                TunableParam::new("maxiter", vec![50i64, 100, 150]),
                TunableParam {
                    name: "c1".into(),
                    values: floats(&[1.0, 2.0, 3.0]),
                },
                TunableParam {
                    name: "c2".into(),
                    values: floats(&[0.5, 1.0, 1.5]),
                },
            ],
            "simulated_annealing" => vec![
                TunableParam {
                    name: "T".into(),
                    values: floats(&[0.5, 1.0, 1.5]),
                },
                TunableParam {
                    name: "T_min".into(),
                    values: floats(&[0.0001, 0.001, 0.01]),
                },
                TunableParam {
                    name: "alpha".into(),
                    values: floats(&[0.9925, 0.995, 0.9975]),
                },
                TunableParam::new("maxiter", vec![1i64, 2, 3]),
            ],
            other => panic!("no golden for {other}"),
        };
        SearchSpace::build(&format!("hp-{algo}-limited"), params, vec![]).unwrap()
    }

    /// The hand-written Table IV tables, float ranges spelled out by
    /// explicit index (the drift-free semantics of the fixed
    /// `float_range`).
    fn golden_extended(algo: &str) -> SearchSpace {
        let params = match algo {
            "genetic_algorithm" => vec![
                TunableParam::new(
                    "method",
                    vec!["single_point", "two_point", "uniform", "disruptive_uniform"],
                ),
                TunableParam::int_range("popsize", 2, 50, 2),
                TunableParam::int_range("maxiter", 10, 200, 10),
                TunableParam::int_range("mutation_chance", 5, 100, 5),
            ],
            "pso" => vec![
                TunableParam::int_range("popsize", 2, 50, 2),
                TunableParam::int_range("maxiter", 10, 200, 10),
                TunableParam {
                    name: "c1".into(),
                    values: grid(1.0, 0.25, 11), // 1.0 ..= 3.5
                },
                TunableParam {
                    name: "c2".into(),
                    values: grid(0.5, 0.25, 7), // 0.5 ..= 2.0
                },
            ],
            "simulated_annealing" => vec![
                TunableParam {
                    name: "T".into(),
                    values: grid(0.1, 0.1, 20), // 0.1 ..= 2.0
                },
                TunableParam {
                    name: "T_min".into(),
                    values: grid(0.0001, 0.001, 100), // 0.0001 ..= 0.0991
                },
                TunableParam {
                    name: "alpha".into(),
                    values: floats(&[0.9925, 0.995, 0.9975]),
                },
                TunableParam::int_range("maxiter", 1, 10, 1),
            ],
            other => panic!("no golden for {other}"),
        };
        SearchSpace::build(&format!("hp-{algo}-extended"), params, vec![]).unwrap()
    }

    /// Byte-identical comparison: same name, parameters (names, value
    /// kinds and exact values) and full enumeration key stream.
    fn assert_spaces_identical(derived: &SearchSpace, golden: &SearchSpace) {
        assert_eq!(derived.name, golden.name);
        assert_eq!(derived.params.len(), golden.params.len(), "{}", derived.name);
        for (dp, gp) in derived.params.iter().zip(&golden.params) {
            assert_eq!(dp.name, gp.name, "{}", derived.name);
            assert_eq!(dp.values, gp.values, "{} / {}", derived.name, dp.name);
            // PartialEq on floats is value equality; pin the rendered keys
            // too so serialization output cannot drift either.
            for (dv, gv) in dp.values.iter().zip(&gp.values) {
                assert_eq!(dv.key(), gv.key(), "{} / {}", derived.name, dp.name);
            }
        }
        assert_eq!(derived.len(), golden.len(), "{}", derived.name);
        for i in (0..derived.len()).step_by(1 + derived.len() / 512) {
            assert_eq!(derived.key(i), golden.key(i), "{} config {i}", derived.name);
        }
    }

    #[test]
    fn derived_limited_spaces_match_golden_tables() {
        for algo in limited_algos() {
            assert_spaces_identical(&limited_space(algo).unwrap(), &golden_limited(algo));
        }
    }

    #[test]
    fn derived_extended_spaces_match_golden_tables() {
        for algo in extended_algos() {
            assert_spaces_identical(&extended_space(algo).unwrap(), &golden_extended(algo));
        }
    }
}
