//! The hyperparameter search spaces of Tables III and IV.
//!
//! Hyperparameter spaces are ordinary [`SearchSpace`]s — the same engine
//! that enumerates kernel configurations enumerates hyperparameter
//! configurations, which is exactly what lets Kernel Tuner's optimizers be
//! reused as meta-strategies.

use crate::searchspace::{SearchSpace, TunableParam, Value};
use anyhow::{bail, Result};

/// Algorithms with a limited (Table III) hyperparameter space.
pub const LIMITED_ALGOS: [&str; 4] = [
    "dual_annealing",
    "genetic_algorithm",
    "pso",
    "simulated_annealing",
];

/// Algorithms with an extended (Table IV) space — those with numerical
/// hyperparameters (dual annealing's single categorical is excluded, as in
/// the paper).
pub const EXTENDED_ALGOS: [&str; 3] = ["genetic_algorithm", "pso", "simulated_annealing"];

fn floats(values: &[f64]) -> Vec<Value> {
    values.iter().map(|&v| Value::Float(v)).collect()
}

fn float_range(lo: f64, hi: f64, step: f64) -> Vec<Value> {
    let mut out = Vec::new();
    let mut v = lo;
    while v <= hi + 1e-9 {
        // Round to the step grid to avoid drift.
        out.push(Value::Float((v / step).round() * step));
        v += step;
    }
    out
}

/// Table III: the limited, exhaustively evaluated hyperparameter spaces.
pub fn limited_space(algo: &str) -> Result<SearchSpace> {
    let params = match algo {
        "dual_annealing" => vec![TunableParam::new(
            "method",
            vec![
                "COBYLA",
                "L-BFGS-B",
                "SLSQP",
                "CG",
                "Powell",
                "Nelder-Mead",
                "BFGS",
                "trust-constr",
            ],
        )],
        "genetic_algorithm" => vec![
            TunableParam::new(
                "method",
                vec!["single_point", "two_point", "uniform", "disruptive_uniform"],
            ),
            TunableParam::new("popsize", vec![10i64, 20, 30]),
            TunableParam::new("maxiter", vec![50i64, 100, 150]),
            TunableParam::new("mutation_chance", vec![5i64, 10, 20]),
        ],
        "pso" => vec![
            TunableParam::new("popsize", vec![10i64, 20, 30]),
            TunableParam::new("maxiter", vec![50i64, 100, 150]),
            TunableParam {
                name: "c1".into(),
                values: floats(&[1.0, 2.0, 3.0]),
            },
            TunableParam {
                name: "c2".into(),
                values: floats(&[0.5, 1.0, 1.5]),
            },
        ],
        "simulated_annealing" => vec![
            TunableParam {
                name: "T".into(),
                values: floats(&[0.5, 1.0, 1.5]),
            },
            TunableParam {
                name: "T_min".into(),
                values: floats(&[0.0001, 0.001, 0.01]),
            },
            TunableParam {
                name: "alpha".into(),
                values: floats(&[0.9925, 0.995, 0.9975]),
            },
            TunableParam::new("maxiter", vec![1i64, 2, 3]),
        ],
        other => bail!("no limited hyperparameter space for {other:?}"),
    };
    SearchSpace::build(&format!("hp-{algo}-limited"), params, vec![])
}

/// Table IV: the extended hyperparameter spaces for meta-strategy tuning.
pub fn extended_space(algo: &str) -> Result<SearchSpace> {
    let params = match algo {
        "genetic_algorithm" => vec![
            TunableParam::new(
                "method",
                vec!["single_point", "two_point", "uniform", "disruptive_uniform"],
            ),
            TunableParam::int_range("popsize", 2, 50, 2),
            TunableParam::int_range("maxiter", 10, 200, 10),
            TunableParam::int_range("mutation_chance", 5, 100, 5),
        ],
        "pso" => vec![
            TunableParam::int_range("popsize", 2, 50, 2),
            TunableParam::int_range("maxiter", 10, 200, 10),
            TunableParam {
                name: "c1".into(),
                values: float_range(1.0, 3.5, 0.25),
            },
            TunableParam {
                name: "c2".into(),
                values: float_range(0.5, 2.0, 0.25),
            },
        ],
        "simulated_annealing" => vec![
            TunableParam {
                name: "T".into(),
                values: float_range(0.1, 2.0, 0.1),
            },
            TunableParam {
                name: "T_min".into(),
                values: float_range(0.0001, 0.1, 0.001),
            },
            TunableParam {
                name: "alpha".into(),
                values: floats(&[0.9925, 0.995, 0.9975]),
            },
            TunableParam::int_range("maxiter", 1, 10, 1),
        ],
        other => bail!("no extended hyperparameter space for {other:?}"),
    };
    SearchSpace::build(&format!("hp-{algo}-extended"), params, vec![])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn limited_space_sizes_match_table3() {
        // Table III cardinalities: DA 8, GA 4*3*3*3=108, PSO 3*3*3*3=81,
        // SA 3*3*3*3=81.
        assert_eq!(limited_space("dual_annealing").unwrap().len(), 8);
        assert_eq!(limited_space("genetic_algorithm").unwrap().len(), 108);
        assert_eq!(limited_space("pso").unwrap().len(), 81);
        assert_eq!(limited_space("simulated_annealing").unwrap().len(), 81);
    }

    #[test]
    fn extended_spaces_are_much_larger() {
        for algo in EXTENDED_ALGOS {
            let lim = limited_space(algo).unwrap().len();
            let ext = extended_space(algo).unwrap().len();
            assert!(ext > 50 * lim, "{algo}: {ext} vs {lim}");
        }
        // Table IV cardinalities.
        assert_eq!(
            extended_space("genetic_algorithm").unwrap().len(),
            4 * 25 * 20 * 20
        );
        assert_eq!(extended_space("pso").unwrap().len(), 25 * 20 * 11 * 7);
        assert_eq!(
            extended_space("simulated_annealing").unwrap().len(),
            20 * 100 * 3 * 10
        );
    }

    #[test]
    fn configs_convert_to_hyperparams() {
        use crate::optimizers::HyperParams;
        let s = limited_space("genetic_algorithm").unwrap();
        let hp = HyperParams::from_space_config(&s, 0);
        assert!(!hp.str("method", "").is_empty());
        assert!(hp.usize("popsize", 0) > 0);
        // Every config must be accepted by the optimizer factory.
        for idx in [0, s.len() / 2, s.len() - 1] {
            let hp = HyperParams::from_space_config(&s, idx);
            assert!(crate::optimizers::create("genetic_algorithm", &hp).is_ok());
        }
    }

    #[test]
    fn unknown_algo_rejected() {
        assert!(limited_space("nope").is_err());
        assert!(extended_space("dual_annealing").is_err());
    }
}
