//! "Tuning the tuner": hyperparameter optimization of the optimization
//! algorithms (Eq. 4).
//!
//! * [`space`] — the hyperparameter search spaces of Table III (limited,
//!   exhaustively enumerable) and Table IV (extended, for meta-strategy
//!   tuning), expressed with the *same* search-space engine the kernel
//!   tuner uses — the paper's machinery reuse. The spaces are derived
//!   from the typed hyperparameter schemas each optimizer declares in
//!   [`crate::optimizers::registry`], not hand-written.
//! * [`exhaustive`] — exhaustive hyperparameter tuning: every
//!   hyperparameter configuration evaluated with repeated simulated runs
//!   across the training spaces; results persisted for reuse.
//! * [`meta`] — meta-strategies: any registered optimizer driving the
//!   hyperparameter search, either live (running real simulations per
//!   hyperparameter configuration, as in the paper's 7-day extended
//!   tuning) or replayed from exhaustive results (Fig 6).
//! * [`sweep`] — the full-registry hypertuning sweep: every grid-bearing
//!   optimizer (paper four + extras) hypertuned and compared
//!   default-vs-best in one versioned `tunetuner-sweep` envelope
//!   (`tunetuner sweep` drives it from the CLI).
//! * [`strategy`] — the meta-strategy engine: a self-describing registry
//!   of budgeted hyperparameter searchers (`random`, `tpe`, `halving`,
//!   `portfolio`) proposing configurations to a memoized, cost-charged
//!   [`strategy::MetaCampaign`] whose full-repeat evaluations reproduce
//!   the exhaustive sweep's scores bitwise.
//! * [`metasweep`] — races the registered meta-strategies against the
//!   exhaustive sweep's optimum: per-strategy recovery/regret/cost in a
//!   versioned `tunetuner-metasweep` envelope (`tunetuner metasweep`
//!   drives it from the CLI).
//! * [`sensitivity`] — the Kruskal–Wallis + mutual-information screen used
//!   to drop insensitive hyperparameters (the paper's PSO `W`).

pub mod space;
pub mod exhaustive;
pub mod meta;
pub mod metasweep;
pub mod strategy;
pub mod sweep;
pub mod sensitivity;

pub use exhaustive::{
    exhaustive_tuning, exhaustive_tuning_observed, HyperResult, HyperTuningResults,
};
pub use meta::{meta_cache_from_results, MetaRunner};
pub use metasweep::{
    metasweep_registry, metasweep_registry_checkpointed, metasweep_registry_with,
    render_report as render_metasweep_report, MetaSweepConfig, MetaSweepResult, StrategyLeg,
    StrategyRun,
};
pub use space::{extended_algos, extended_space, limited_algos, limited_space};
pub use strategy::{
    halving_schedule, strategies, strategy_by_name, strategy_names, MetaBudget, MetaCampaign,
    MetaOutcome, MetaStrategy, Rung, StrategyDescriptor,
};
pub use sweep::{
    render_report as render_sweep_report, sweep_registry, sweep_registry_checkpointed,
    sweep_registry_with, Checkpoint, FailedLeg, OptimizerSweep, SweepResult,
};
