//! "Tuning the tuner": hyperparameter optimization of the optimization
//! algorithms (Eq. 4).
//!
//! * [`space`] — the hyperparameter search spaces of Table III (limited,
//!   exhaustively enumerable) and Table IV (extended, for meta-strategy
//!   tuning), expressed with the *same* search-space engine the kernel
//!   tuner uses — the paper's machinery reuse. The spaces are derived
//!   from the typed hyperparameter schemas each optimizer declares in
//!   [`crate::optimizers::registry`], not hand-written.
//! * [`exhaustive`] — exhaustive hyperparameter tuning: every
//!   hyperparameter configuration evaluated with repeated simulated runs
//!   across the training spaces; results persisted for reuse.
//! * [`meta`] — meta-strategies: any registered optimizer driving the
//!   hyperparameter search, either live (running real simulations per
//!   hyperparameter configuration, as in the paper's 7-day extended
//!   tuning) or replayed from exhaustive results (Fig 6).
//! * [`sweep`] — the full-registry hypertuning sweep: every grid-bearing
//!   optimizer (paper four + extras) hypertuned and compared
//!   default-vs-best in one versioned `tunetuner-sweep` envelope
//!   (`tunetuner sweep` drives it from the CLI).
//! * [`sensitivity`] — the Kruskal–Wallis + mutual-information screen used
//!   to drop insensitive hyperparameters (the paper's PSO `W`).

pub mod space;
pub mod exhaustive;
pub mod meta;
pub mod sweep;
pub mod sensitivity;

pub use exhaustive::{
    exhaustive_tuning, exhaustive_tuning_observed, HyperResult, HyperTuningResults,
};
pub use meta::{meta_cache_from_results, MetaRunner};
pub use space::{extended_algos, extended_space, limited_algos, limited_space};
pub use sweep::{
    render_report as render_sweep_report, sweep_registry, sweep_registry_with, OptimizerSweep,
    SweepResult,
};
