//! Meta-strategies: optimizing the hyperparameters with the optimizers
//! themselves (Section IV-C/D).
//!
//! Two modes:
//!
//! * **Live** ([`MetaRunner`]): each hyperparameter-configuration
//!   evaluation actually runs the repeated simulated tuning campaign and
//!   returns `1 - score` as the objective (minimized). The cost charged to
//!   the meta-budget is the measured wall-clock of the evaluation — this
//!   is the mode the paper's 7-day extended tuning uses.
//! * **Replay** ([`meta_cache_from_results`]): the exhaustive results are
//!   converted into an ordinary brute-force cache over the hyperparameter
//!   space, so meta-strategies can be compared with 100 repeats at lookup
//!   speed (Fig. 6) using the very same simulation-mode machinery.

use super::exhaustive::HyperTuningResults;
use crate::campaign::{Campaign, Observer};
use crate::dataset::cache::{CacheData, ConfigRecord};
use crate::error::{Result, TuneError};
use crate::methodology::SpaceEval;
use crate::optimizers::HyperParams;
use crate::runner::{EvalResult, Runner};
use crate::searchspace::SearchSpace;
use std::sync::Arc;

/// Live meta-evaluation: a Runner over a hyperparameter space whose
/// evaluations run full (simulated) tuning campaigns.
///
/// Holds one base [`Campaign`] (algorithm, shared training spaces,
/// repeats, seed) and clones it per hyperparameter configuration; the
/// campaigns all execute on the persistent executor pool, so a meta run
/// with ~150 hyperparameter evaluations re-uses one set of workers
/// instead of spawning a fresh `thread::scope` per evaluation.
pub struct MetaRunner {
    pub algo: String,
    hp_space: Arc<SearchSpace>,
    /// Base campaign; `repeats` and `seed` live here (snapshotted at
    /// construction), not as separate fields that could silently drift.
    campaign: Campaign,
    observer: Option<Arc<dyn Observer>>,
    /// (config_idx, score) history, in evaluation order.
    pub history: Vec<(usize, f64)>,
}

impl MetaRunner {
    pub fn new(
        algo: &str,
        hp_space: Arc<SearchSpace>,
        train: Vec<SpaceEval>,
        repeats: usize,
        seed: u64,
    ) -> MetaRunner {
        MetaRunner {
            algo: algo.to_string(),
            hp_space,
            campaign: Campaign::new(algo)
                .space_evals(train)
                .repeats(repeats)
                .seed(seed),
            observer: None,
            history: Vec::new(),
        }
    }

    /// Report campaign progress and per-configuration scores to
    /// `observer` ([`Observer::config_scored`] fires once per
    /// meta-evaluation).
    pub fn with_observer(mut self, observer: Arc<dyn Observer>) -> MetaRunner {
        self.campaign = self.campaign.observer(Arc::clone(&observer));
        self.observer = Some(observer);
        self
    }
}

impl Runner for MetaRunner {
    fn space(&self) -> &SearchSpace {
        &self.hp_space
    }

    fn evaluate(&mut self, config_idx: usize) -> EvalResult {
        // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
        let t0 = std::time::Instant::now();
        let hp = HyperParams::from_space_config(&self.hp_space, config_idx);
        let result = self.campaign.with_hyperparams(&hp).run();
        let elapsed = t0.elapsed().as_secs_f64();
        match result {
            Ok(r) => {
                let score = r.score();
                if let Some(obs) = &self.observer {
                    obs.config_scored(config_idx, &r.hp_key, score);
                }
                self.history.push((config_idx, score));
                EvalResult {
                    // Minimized objective: 1 - score (score <= 1).
                    value: 1.0 - score,
                    observations: vec![1.0 - score],
                    compile_time: 0.0,
                    run_time: elapsed,
                    overhead: 0.0,
                    valid: true,
                }
            }
            Err(e) => {
                crate::log_warn!("meta evaluation failed: {e:#}");
                EvalResult {
                    value: f64::INFINITY,
                    observations: vec![],
                    compile_time: 0.0,
                    run_time: elapsed,
                    overhead: 0.0,
                    valid: false,
                }
            }
        }
    }

    fn label(&self) -> String {
        format!("meta:{} over {}", self.algo, self.hp_space.name)
    }
}

/// Convert exhaustive hyperparameter results into a brute-force cache over
/// the hyperparameter space, so the meta-level tuning problem can be
/// replayed through the standard simulation mode (Fig. 6).
///
/// Every *successful* hyperparameter evaluation is charged the campaign's
/// average real evaluation cost, so the meta-time axis reads in real
/// seconds of hyperparameter tuning. The average deliberately runs over
/// successful evaluations only: a failed meta-evaluation errors out
/// before executing its tuning runs, so folding failures into the
/// denominator would skew the replayed per-evaluation cost downward.
///
/// Failed evaluations (non-finite objective) become ordinary *invalid*
/// records: infinite value, **no observations** (SimTable precomputes
/// `total_cost = compile + Σobs + overhead`, so a non-finite observation
/// would make that record's cost — and the memoized `mean_eval_cost` of
/// the whole replay cache — non-finite, corrupting the Fig. 6 meta-time
/// axis). An invalid record costs `compile + overhead` per the
/// invalid-cost semantics documented on [`CacheData::mean_eval_cost`],
/// with compile = 0 here: the failure consumed ~none of the measured
/// wallclock (all of which is attributed to the successes above), so a
/// replayed failure is charged only the framework overhead and the total
/// replayed time stays conserved against the real wallclock.
///
/// A results/space length mismatch is a typed
/// [`TuneError::InvalidInput`](crate::error::TuneError::InvalidInput)
/// (stale results must never be silently misdecoded against a changed
/// grid).
pub fn meta_cache_from_results(
    results: &HyperTuningResults,
    hp_space: &SearchSpace,
) -> Result<CacheData> {
    if results.results.len() != hp_space.len() {
        return Err(TuneError::InvalidInput(format!(
            "hypertuning results for {} carry {} configs but hyperparameter \
             space {} has {}",
            results.algo,
            results.results.len(),
            hp_space.name,
            hp_space.len()
        )));
    }
    let successes = results
        .results
        .iter()
        .filter(|r| (1.0 - r.score).is_finite())
        .count();
    let cost_per_eval = (results.wallclock_seconds / successes.max(1) as f64).max(1e-3);
    let records: Vec<ConfigRecord> = results
        .results
        .iter()
        .map(|r| {
            let value = 1.0 - r.score;
            if value.is_finite() {
                ConfigRecord {
                    key: hp_space.key(r.config_idx),
                    value,
                    observations: vec![value],
                    // Model the full evaluation cost as "compile" so the
                    // recorded run_time (= obs sum) stays a pure objective.
                    compile_time: cost_per_eval,
                    valid: true,
                }
            } else {
                // Failed meta-evaluation: the standard invalid-record
                // shape (INFINITY value normalizes a NaN objective too,
                // so replay comparisons never see a NaN). Zero compile:
                // the wallclock is already fully attributed to the
                // successful evaluations, so charging the per-success
                // average here would replay more meta-time than was
                // actually spent.
                ConfigRecord {
                    key: hp_space.key(r.config_idx),
                    value: f64::INFINITY,
                    observations: vec![],
                    compile_time: 0.0,
                    valid: false,
                }
            }
        })
        .collect();
    Ok(CacheData::new(
        format!("hp-{}", results.algo),
        "meta",
        format!(
            "hyperparameter space of {} ({} configs)",
            results.algo,
            hp_space.len()
        ),
        results.seed,
        1,
        results.wallclock_seconds,
        hp_space.params.iter().map(|p| p.name.clone()).collect(),
        records,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::A100;
    use crate::hypertuning::space::limited_space;
    use crate::kernels;
    use crate::optimizers;
    use crate::perfmodel::NoiseModel;
    use crate::runner::{Budget, LiveRunner, SimulationRunner, Tuning};
    use crate::runtime::Engine;
    use crate::util::rng::Rng;

    fn train() -> Vec<SpaceEval> {
        let engine = Arc::new(Engine::native());
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            engine,
            NoiseModel::default(),
            42,
        );
        let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
        vec![SpaceEval::new(kernel.space_arc(), cache, 0.95, 10)]
    }

    #[test]
    fn live_meta_runner_drives_optimizer() {
        let hp_space = Arc::new(limited_space("dual_annealing").unwrap());
        let mut meta = MetaRunner::new("dual_annealing", Arc::clone(&hp_space), train(), 3, 5);
        let mut tuning = Tuning::new(&mut meta, Budget::evals(4));
        let opt = optimizers::create("random_search", &HyperParams::new()).unwrap();
        let mut rng = Rng::new(1);
        opt.run(&mut tuning, &mut rng);
        let trace = tuning.finish();
        assert_eq!(trace.unique_evals, 4);
        assert!(meta.history.len() == 4);
        // Objective = 1 - score, so best (lowest) <= 1 - min score.
        let best = trace.best().unwrap();
        assert!(best <= 1.5);
    }

    /// A meta-tuning replay must be bit-reproducible: same seed, same
    /// hyperparameter-evaluation history (config indices AND scores),
    /// regardless of the thread scheduling inside `evaluate_algorithm`.
    #[test]
    fn meta_runner_replays_deterministically() {
        let hp_space = Arc::new(limited_space("simulated_annealing").unwrap());
        let run = || {
            let mut meta =
                MetaRunner::new("simulated_annealing", Arc::clone(&hp_space), train(), 2, 9);
            let mut tuning = Tuning::new(&mut meta, Budget::evals(5));
            let opt = optimizers::create("random_search", &HyperParams::new()).unwrap();
            let mut rng = Rng::new(3);
            opt.run(&mut tuning, &mut rng);
            drop(tuning);
            meta.history
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 5);
        for ((ia, sa), (ib, sb)) in a.iter().zip(&b) {
            assert_eq!(ia, ib);
            assert_eq!(sa.to_bits(), sb.to_bits(), "score drift at config {ia}");
        }
    }

    #[test]
    fn replay_cache_matches_results() {
        let hp_space = limited_space("dual_annealing").unwrap();
        let results = HyperTuningResults {
            algo: "dual_annealing".into(),
            space_kind: "limited".into(),
            space_key: String::new(),
            repeats: 25,
            seed: 1,
            results: (0..hp_space.len())
                .map(|i| crate::hypertuning::exhaustive::HyperResult {
                    config_idx: i,
                    hp_key: format!("m{i}"),
                    score: 0.1 * i as f64,
                })
                .collect(),
            wallclock_seconds: 80.0,
            simulated_seconds: 1e6,
        };
        let cache = meta_cache_from_results(&results, &hp_space).unwrap();
        assert_eq!(cache.records.len(), 8);
        // Best HP config (highest score) has the lowest objective.
        assert_eq!(cache.optimum_index(), 7);
        assert!((cache.records[0].value - 1.0).abs() < 1e-12);
        // Replay through the ordinary simulation machinery.
        let mut sim =
            SimulationRunner::new_unchecked(Arc::new(hp_space), Arc::new(cache));
        let r = sim.evaluate(7);
        assert!((r.value - (1.0 - 0.7)).abs() < 1e-12);
        assert!((r.compile_time - 10.0).abs() < 1e-12); // 80s / 8 configs
    }

    /// Regression: a failed meta-evaluation (non-finite objective) used
    /// to store its infinite value as an observation on a record already
    /// marked invalid. SimTable precomputes `total_cost = compile + Σobs
    /// + overhead`, so that single record made the memoized
    /// `mean_eval_cost` of the whole replay cache infinite, breaking the
    /// Fig. 6 meta-time axis. Invalid records must carry no observations
    /// and replay as invalid with a finite cost.
    #[test]
    fn failed_meta_eval_does_not_poison_replay_costs() {
        let hp_space = limited_space("dual_annealing").unwrap();
        let results = HyperTuningResults {
            algo: "dual_annealing".into(),
            space_kind: "limited".into(),
            space_key: String::new(),
            repeats: 25,
            seed: 1,
            results: (0..hp_space.len())
                .map(|i| crate::hypertuning::exhaustive::HyperResult {
                    config_idx: i,
                    hp_key: format!("m{i}"),
                    // Config 3 failed with an infinite objective
                    // (score = -inf => value = +inf); config 5 failed
                    // with a NaN score.
                    score: match i {
                        3 => f64::NEG_INFINITY,
                        5 => f64::NAN,
                        _ => 0.1 * i as f64,
                    },
                })
                .collect(),
            wallclock_seconds: 60.0,
            simulated_seconds: 1e6,
        };
        let cache = meta_cache_from_results(&results, &hp_space).unwrap();
        // Invalid records: infinite value, no observations, still valid=false.
        for idx in [3usize, 5] {
            assert!(!cache.records[idx].valid);
            assert!(cache.records[idx].value.is_infinite());
            assert!(
                cache.records[idx].observations.is_empty(),
                "invalid record {idx} must carry no observations"
            );
        }
        // cost_per_eval averages over the 6 *successful* evaluations
        // only: 60s / 6 = 10s (the old code spread it over all 8), and
        // failed evaluations are charged no compile at all, so the total
        // replayed compile time stays conserved against the wallclock.
        assert!((cache.records[0].compile_time - 10.0).abs() < 1e-12);
        assert_eq!(cache.records[3].compile_time, 0.0);
        assert_eq!(cache.records[5].compile_time, 0.0);
        let total_compile: f64 = cache.records.iter().map(|r| r.compile_time).sum();
        assert!((total_compile - 60.0).abs() < 1e-9, "{total_compile}");
        // The cost axis stays finite at every layer.
        assert!(cache.mean_eval_cost(0.1).is_finite());
        assert!(cache.sim_table().mean_eval_cost.is_finite());
        assert!(cache.sim_table().cost(3).is_finite());
        assert!(!cache.sim_table().is_valid(3));
        // Replay through the ordinary simulation machinery skips the
        // failed config as invalid: infinite value, finite cost.
        let hp_space = Arc::new(hp_space);
        let mut sim = SimulationRunner::new_unchecked(Arc::clone(&hp_space), Arc::new(cache));
        let r = sim.evaluate(3);
        assert!(!r.valid);
        assert!(r.value.is_infinite());
        assert!(r.total_cost().is_finite());
        let (v, c) = sim.evaluate_lite(5);
        assert!(v.is_infinite());
        assert!(c.is_finite());
        // A tuning run over the whole cache (one exhaustive batch) never
        // selects a failed config as its best.
        let mut tuning = Tuning::new(&mut sim, Budget::evals(hp_space.len()));
        let all: Vec<usize> = (0..hp_space.len()).collect();
        assert_eq!(tuning.eval_batch(&all).len(), hp_space.len());
        let trace = tuning.finish();
        assert!(trace.best().unwrap().is_finite());
        assert!((trace.best().unwrap() - (1.0 - 0.7)).abs() < 1e-12);
    }

    /// Regression: a results/space length mismatch used to panic via
    /// `assert_eq!`; it is now the library-wide typed error.
    #[test]
    fn results_space_mismatch_is_typed_error() {
        let hp_space = limited_space("dual_annealing").unwrap();
        let results = HyperTuningResults {
            algo: "dual_annealing".into(),
            space_kind: "limited".into(),
            space_key: String::new(),
            repeats: 1,
            seed: 1,
            results: vec![crate::hypertuning::exhaustive::HyperResult {
                config_idx: 0,
                hp_key: "m0".into(),
                score: 0.5,
            }],
            wallclock_seconds: 1.0,
            simulated_seconds: 1.0,
        };
        let err = meta_cache_from_results(&results, &hp_space).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err:#}");
        let msg = format!("{err:#}");
        assert!(msg.contains("1 configs") && msg.contains("has 8"), "{msg}");
    }
}
