//! Full-registry hypertuning sweep: hypertune *every* grid-bearing
//! optimizer, not just the paper's four.
//!
//! The paper's headline (94.8% mean improvement from even limited
//! hyperparameter tuning) is measured on its four Table III algorithms;
//! this module turns that measurement into a first-class subsystem over
//! the whole optimizer registry — the direction "Automated Algorithm
//! Design for Auto-Tuning Optimizers" pushes, where the optimizer
//! portfolio itself becomes the search space. For each optimizer whose
//! schema declares a `limited` grid (the paper four plus the registry
//! extras such as `greedy_ils` and `basin_hopping`) the sweep runs:
//!
//! 1. one reference [`Campaign`] with the schema-default hyperparameters
//!    on the training spaces, and
//! 2. the exhaustive limited-grid evaluation
//!    ([`super::exhaustive_tuning_observed`]) — one campaign per
//!    hyperparameter configuration, all sharing the prepared
//!    [`SpaceEval`]s (and with them the Arc-shared SimTable/T4B caches)
//!    on the persistent executor pool.
//!
//! Results aggregate into a versioned [`SweepResult`] envelope (schema
//! [`SWEEP_SCHEMA`]) carrying per-optimizer default/best scores, the best
//! hyperparameter key, the improvement percentage, and the space
//! fingerprints as provenance. [`render_report`] draws the paper-style
//! comparison table and per-grid score-distribution figure through the
//! existing [`Report`] sink, so hypertuned extras can be compared
//! head-to-head against the paper's set. `tunetuner sweep [--json]`
//! drives it from the CLI; progress streams through the
//! [`Observer::sweep_started`]-family events.
//!
//! ## Fault tolerance
//!
//! A sweep is hours of compute; one bad leg must not discard the rest.
//! [`sweep_registry_checkpointed`] adds two robustness layers over the
//! plain drivers:
//!
//! * **Quarantine** — a leg whose campaign exhausts its retry budget
//!   ([`TuneError::WorkerPanic`]) is recorded in the envelope's
//!   `failed_legs` (a [`FailedLeg`] per casualty) while every other leg
//!   completes; [`render_report`] draws the failure table and the CLI
//!   exits nonzero *after* saving the partial envelope. Any other error
//!   class stays fatal — a stale cache poisons every leg equally.
//! * **Checkpointing** — with a [`Checkpoint`] policy the partial
//!   envelope is atomically rewritten (via
//!   [`crate::util::fsio::atomic_write`]) every `every_legs` completed
//!   legs, so a crash loses at most that many legs of work.

use super::exhaustive::{self, HyperTuningResults};
use super::space;
use crate::campaign::{Campaign, Observer};
use crate::error::{Context, Result, TuneError};
use crate::faults::FaultPlan;
use crate::methodology::SpaceEval;
use crate::optimizers;
use crate::report::Report;
use crate::util::json::{self, Json};
use crate::util::table::{fmt_duration, Table};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Schema tag of the serialized sweep envelope.
pub const SWEEP_SCHEMA: &str = "tunetuner-sweep";

/// Version of the serialized sweep envelope; bump on breaking changes.
pub const SWEEP_SCHEMA_VERSION: u64 = 1;

/// The sweep outcome for one grid-bearing optimizer.
#[derive(Clone, Debug)]
pub struct OptimizerSweep {
    pub algo: String,
    /// Whether this optimizer is part of the paper's Table III set
    /// (`Descriptor::paper`) or a registry extra.
    pub paper: bool,
    /// Size of the limited hyperparameter grid.
    pub configs: usize,
    /// [`crate::searchspace::SearchSpace::fingerprint`] of the
    /// hyperparameter space the exhaustive results were computed on.
    pub space_key: String,
    /// Stable key of the schema-default hyperparameters.
    pub default_hp_key: String,
    /// Eq. 3 score of the schema-default configuration.
    pub default_score: f64,
    /// Stable key of the best hyperparameter configuration.
    pub best_hp_key: String,
    /// Index of the best configuration in the hyperparameter space.
    pub best_config_idx: usize,
    /// Eq. 3 score of the best configuration.
    pub best_score: f64,
    /// [`improvement_pct`] of best over default.
    pub improvement_pct: f64,
    /// Score of every hyperparameter configuration, in config-index
    /// order (the per-grid distribution behind the sweep figure).
    pub scores: Vec<f64>,
    /// Real seconds this optimizer's sweep leg took.
    pub wallclock_seconds: f64,
}

/// A leg that exhausted its retry budget and was quarantined instead of
/// aborting the sweep. Serialized into the envelope's `failed_legs` so a
/// partial artifact is explicit about what it is missing.
#[derive(Clone, Debug)]
pub struct FailedLeg {
    /// Leg identity: an optimizer name for the registry sweep, a
    /// `strategy/target` pair for the metasweep.
    pub leg: String,
    /// The captured failure (first panic payload, attempt count).
    pub error: String,
    /// Attempts performed before quarantine (initial run + retries).
    pub attempts: usize,
}

impl FailedLeg {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("leg", self.leg.as_str().into())
            .set("error", self.error.as_str().into())
            .set("attempts", self.attempts.into());
        j
    }

    pub fn from_json(j: &Json) -> FailedLeg {
        FailedLeg {
            leg: j
                .get("leg")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            error: j
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or_default()
                .to_string(),
            attempts: j.get("attempts").and_then(|v| v.as_usize()).unwrap_or(0),
        }
    }

    /// Parse an envelope's optional `failed_legs` array (absent in
    /// pre-fault-tolerance envelopes → empty).
    pub fn vec_from_json(j: &Json) -> Vec<FailedLeg> {
        j.get("failed_legs")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(FailedLeg::from_json)
            .collect()
    }
}

/// Incremental-checkpoint policy for the sweep drivers: after every
/// `every_legs` completed (or quarantined) legs, the partial envelope is
/// atomically rewritten at `path` — a crash or kill loses at most
/// `every_legs` legs of finished work. A failed checkpoint save is
/// logged and skipped (the sweep itself must not die to a flaky disk);
/// the final save at the call site still reports its error normally.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub path: PathBuf,
    pub every_legs: usize,
}

impl Checkpoint {
    pub fn new(path: impl Into<PathBuf>, every_legs: usize) -> Checkpoint {
        Checkpoint {
            path: path.into(),
            every_legs: every_legs.max(1),
        }
    }
}

/// One prepared training space's identity, recorded as provenance.
#[derive(Clone, Debug)]
pub struct SweptSpace {
    /// Display label (`kernel@device`).
    pub label: String,
    /// Structural fingerprint of the kernel search space.
    pub space_fingerprint: String,
}

/// The complete, serializable outcome of a full-registry sweep.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// Grid kind the sweep enumerated (currently always `"limited"` —
    /// only Table III-style grids are exhaustively tractable).
    pub space_kind: String,
    /// Tuning runs per (configuration, space).
    pub repeats: usize,
    pub seed: u64,
    /// The training spaces every campaign ran on, in space order.
    pub train: Vec<SweptSpace>,
    /// One entry per grid-bearing registry optimizer, in registration
    /// order ([`optimizers::hypertunable`]). Quarantined optimizers are
    /// absent here and present in [`failed_legs`](Self::failed_legs).
    pub optimizers: Vec<OptimizerSweep>,
    /// Legs that exhausted their retry budget and were quarantined
    /// (empty on a fully healthy sweep).
    pub failed_legs: Vec<FailedLeg>,
    /// Real seconds the whole sweep took.
    pub wallclock_seconds: f64,
}

/// Relative improvement of the hypertuned-best over the default
/// configuration, in percent — the fig5 convention: the score delta
/// relative to `|default|` when the default score is meaningfully
/// nonzero, and percentage points otherwise (a near-zero default would
/// make the ratio explode).
pub fn improvement_pct(default_score: f64, best_score: f64) -> f64 {
    let delta = best_score - default_score;
    if default_score.abs() > 1e-9 {
        delta / default_score.abs() * 100.0
    } else {
        delta * 100.0
    }
}

impl SweepResult {
    /// Mean [`improvement_pct`] across the swept optimizers — the
    /// sweep's analog of the paper's 94.8% headline.
    pub fn mean_improvement_pct(&self) -> f64 {
        if self.optimizers.is_empty() {
            return 0.0;
        }
        let pcts: Vec<f64> = self.optimizers.iter().map(|o| o.improvement_pct).collect();
        crate::util::stats::mean(&pcts)
    }

    // ---- lookups (the metasweep's regret reference) --------------------------

    /// The sweep entry for `algo`, if it was swept.
    pub fn entry(&self, algo: &str) -> Option<&OptimizerSweep> {
        self.optimizers.iter().find(|o| o.algo == algo)
    }

    /// Exhaustive-best Eq. 3 score of `algo`'s limited grid.
    pub fn best_score_for(&self, algo: &str) -> Option<f64> {
        self.entry(algo).map(|o| o.best_score)
    }

    /// Schema-default Eq. 3 score of `algo`.
    pub fn default_score_for(&self, algo: &str) -> Option<f64> {
        self.entry(algo).map(|o| o.default_score)
    }

    /// Regret of `score` against `algo`'s exhaustive optimum:
    /// `best_score - score`, i.e. 0 when the optimum was recovered and
    /// positive otherwise. `None` when `algo` was not swept.
    pub fn optimum_regret(&self, algo: &str, score: f64) -> Option<f64> {
        self.best_score_for(algo).map(|best| best - score)
    }

    /// Total exhaustive meta-evaluations the sweep performed (the sum of
    /// all grid sizes) — the cost baseline registry-wide strategies are
    /// measured against.
    pub fn total_configs(&self) -> usize {
        self.optimizers.iter().map(|o| o.configs).sum()
    }

    /// The best (optimizer, score) over every swept grid — the
    /// registry-wide optimum. NaN scores are demoted; ties break toward
    /// the earlier-registered optimizer. `None` on an empty sweep.
    pub fn overall_best(&self) -> Option<(&str, f64)> {
        self.optimizers
            .iter()
            .map(|o| (o.algo.as_str(), o.best_score))
            .reduce(|acc, cur| {
                if cur.1.is_nan() || (!acc.1.is_nan() && cur.1 <= acc.1) {
                    acc
                } else {
                    cur
                }
            })
    }

    // ---- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let train: Vec<Json> = self
            .train
            .iter()
            .map(|t| {
                let mut o = Json::obj();
                o.set("label", t.label.as_str().into())
                    .set("space_fingerprint", t.space_fingerprint.as_str().into());
                o
            })
            .collect();
        let opts: Vec<Json> = self
            .optimizers
            .iter()
            .map(|o| {
                let mut j = Json::obj();
                j.set("algo", o.algo.as_str().into())
                    .set("paper", o.paper.into())
                    .set("configs", o.configs.into())
                    .set("space_key", o.space_key.as_str().into())
                    .set("default_hp_key", o.default_hp_key.as_str().into())
                    .set("default_score", o.default_score.into())
                    .set("best_hp_key", o.best_hp_key.as_str().into())
                    .set("best_config_idx", o.best_config_idx.into())
                    .set("best_score", o.best_score.into())
                    .set("improvement_pct", o.improvement_pct.into())
                    .set(
                        "scores",
                        Json::Arr(o.scores.iter().map(|&s| s.into()).collect()),
                    )
                    .set("wallclock_seconds", o.wallclock_seconds.into());
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("schema", SWEEP_SCHEMA.into())
            .set("schema_version", (SWEEP_SCHEMA_VERSION as f64).into())
            .set("space_kind", self.space_kind.as_str().into())
            .set("repeats", self.repeats.into())
            // String, not number: JSON numbers are f64 and would corrupt
            // seeds >= 2^53 on the round-trip (same as CampaignResult).
            .set("seed", self.seed.to_string().as_str().into())
            .set("train", Json::Arr(train))
            .set("optimizers", Json::Arr(opts))
            .set(
                "failed_legs",
                Json::Arr(self.failed_legs.iter().map(|f| f.to_json()).collect()),
            )
            .set("wallclock_seconds", self.wallclock_seconds.into());
        j
    }

    /// Parse an envelope previously produced by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> Result<SweepResult> {
        if j.get("schema").and_then(|v| v.as_str()) != Some(SWEEP_SCHEMA) {
            crate::bail!("not a {SWEEP_SCHEMA} envelope");
        }
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        if version > SWEEP_SCHEMA_VERSION {
            crate::bail!(
                "sweep envelope version {version} is newer than this \
                 binary's {SWEEP_SCHEMA_VERSION}"
            );
        }
        let train = j
            .get("train")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|t| SweptSpace {
                label: t
                    .get("label")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                space_fingerprint: t
                    .get("space_fingerprint")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            })
            .collect();
        let mut optimizers_out = Vec::new();
        for o in j
            .get("optimizers")
            .and_then(|v| v.as_arr())
            .context("missing optimizers")?
        {
            let str_field = |k: &str| -> String {
                o.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string()
            };
            let num_field =
                |k: &str| -> f64 { o.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN) };
            optimizers_out.push(OptimizerSweep {
                algo: o
                    .get("algo")
                    .and_then(|v| v.as_str())
                    .context("optimizer entry missing algo")?
                    .to_string(),
                paper: o.get("paper").and_then(|v| v.as_bool()).unwrap_or(false),
                configs: o.get("configs").and_then(|v| v.as_usize()).unwrap_or(0),
                space_key: str_field("space_key"),
                default_hp_key: str_field("default_hp_key"),
                default_score: num_field("default_score"),
                best_hp_key: str_field("best_hp_key"),
                best_config_idx: o
                    .get("best_config_idx")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(0),
                best_score: num_field("best_score"),
                improvement_pct: num_field("improvement_pct"),
                // Positional, not filtered: a non-finite score serializes
                // as JSON null, and dropping it would shift every later
                // entry of this config-index-ordered array.
                scores: o
                    .get("scores")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(|x| x.as_f64().unwrap_or(f64::NAN))
                    .collect(),
                wallclock_seconds: num_field("wallclock_seconds"),
            });
        }
        Ok(SweepResult {
            space_kind: j
                .get("space_kind")
                .and_then(|v| v.as_str())
                .unwrap_or("limited")
                .to_string(),
            repeats: j.get("repeats").and_then(|v| v.as_usize()).unwrap_or(0),
            seed: match j.get("seed") {
                Some(Json::Str(s)) => s.parse().unwrap_or(0),
                Some(v) => v.as_f64().unwrap_or(0.0) as u64,
                None => 0,
            },
            train,
            optimizers: optimizers_out,
            failed_legs: FailedLeg::vec_from_json(j),
            wallclock_seconds: j
                .get("wallclock_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::compress::write_string(path, &self.to_json().to_string())
    }

    pub fn load(path: &Path) -> Result<SweepResult> {
        SweepResult::from_json(&json::parse(&crate::util::compress::read_string(path)?)?)
    }

    /// [`load`](Self::load) that treats a missing, corrupt, truncated or
    /// foreign file as "no prior": logs a warning and returns `None` so
    /// resume paths start fresh instead of dying on a half-written
    /// artifact.
    pub fn load_tolerant(path: &Path) -> Option<SweepResult> {
        if !path.exists() {
            return None;
        }
        match SweepResult::load(path) {
            Ok(r) => Some(r),
            Err(e) => {
                crate::log_warn!(
                    "ignoring unreadable prior sweep envelope {}: {e:#}",
                    path.display()
                );
                None
            }
        }
    }
}

/// Sweep every grid-bearing registry optimizer over `train`, computing
/// the exhaustive limited-grid results fresh (see [`sweep_registry_with`]
/// to supply persisted/memoized results instead, as the CLI's
/// [`crate::experiments::Ctx::registry_sweep`] does).
pub fn sweep_registry(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    observer: Arc<dyn Observer>,
) -> Result<SweepResult> {
    let obs = Arc::clone(&observer);
    sweep_registry_with(train, repeats, seed, observer, move |algo| {
        let hp_space = space::limited_space(algo)?;
        exhaustive::exhaustive_tuning_observed(
            algo,
            &hp_space,
            "limited",
            train,
            repeats,
            seed,
            Arc::clone(&obs),
        )
        .map(Arc::new)
    })
}

/// [`sweep_registry`] with the exhaustive per-optimizer results supplied
/// by `limited_results_for` (e.g. loaded from a results directory). The
/// supplied results are verified against the current schema-derived
/// space — a fingerprint or length mismatch is a typed
/// [`TuneError::StaleCache`], never a silently misdecoded sweep.
pub fn sweep_registry_with<F>(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    observer: Arc<dyn Observer>,
    limited_results_for: F,
) -> Result<SweepResult>
where
    F: FnMut(&str) -> Result<Arc<HyperTuningResults>>,
{
    sweep_registry_checkpointed(train, repeats, seed, observer, None, None, limited_results_for)
}

/// [`sweep_registry_with`] plus the fault-tolerance layers: an optional
/// incremental [`Checkpoint`] and an optional explicit [`FaultPlan`]
/// injected into the reference campaigns (chaos testing). Legs that
/// exhaust their campaign retry budget are quarantined into the
/// envelope's `failed_legs` — from whichever side of the leg the
/// [`TuneError::WorkerPanic`] arose, the reference campaign or the
/// results provider — while the remaining legs complete.
pub fn sweep_registry_checkpointed<F>(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    observer: Arc<dyn Observer>,
    checkpoint: Option<&Checkpoint>,
    faults: Option<Arc<FaultPlan>>,
    mut limited_results_for: F,
) -> Result<SweepResult>
where
    F: FnMut(&str) -> Result<Arc<HyperTuningResults>>,
{
    if train.is_empty() {
        return Err(TuneError::InvalidInput("sweep has no training spaces".into()));
    }
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let t0 = std::time::Instant::now();
    let algos = optimizers::hypertunable();
    observer.sweep_started(algos.len(), repeats);
    // One shared Arc of the prepared spaces: every default campaign (and,
    // through the SpaceEval clones inside exhaustive_tuning, every
    // per-configuration campaign) reuses the same Arc-shared brute-force
    // caches and their memoized SimTables.
    let train_arc: Arc<Vec<SpaceEval>> = Arc::new(train.to_vec());
    let swept_train: Vec<SweptSpace> = train
        .iter()
        .map(|se| SweptSpace {
            label: se.label.clone(),
            space_fingerprint: se.space.fingerprint(),
        })
        .collect();
    let mut optimizers_out: Vec<OptimizerSweep> = Vec::with_capacity(algos.len());
    let mut failed_legs: Vec<FailedLeg> = Vec::new();
    for (i, d) in algos.iter().enumerate() {
        let hp_space = space::limited_space(d.name)?;
        observer.sweep_optimizer_started(i, d.name, hp_space.len());
        // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
        let ot0 = std::time::Instant::now();
        let leg = (|| -> Result<OptimizerSweep> {
            // Reference leg: the schema-default hyperparameters, same
            // repeats/seed as every grid configuration gets.
            let default_result = Campaign::new(d.name)
                .spaces_arc(Arc::clone(&train_arc))
                .repeats(repeats)
                .seed(seed)
                .observer(Arc::clone(&observer))
                .faults(faults.clone())
                .run()?;
            let results = limited_results_for(d.name)?;
            let fingerprint = hp_space.fingerprint();
            if results.space_key != fingerprint {
                return Err(TuneError::StaleCache(format!(
                    "hypertuning results for {} were computed on space {:?} \
                     but the current schema derives {:?}",
                    d.name, results.space_key, fingerprint
                )));
            }
            if results.results.len() != hp_space.len() {
                return Err(TuneError::StaleCache(format!(
                    "hypertuning results for {} carry {} configs but its \
                     hyperparameter space has {}",
                    d.name,
                    results.results.len(),
                    hp_space.len()
                )));
            }
            // Per-config scores in config-index order (exhaustive results are
            // already ordered, but index-address them so any provider works —
            // with an out-of-space index a typed error, not a panic).
            let mut scores = vec![f64::NAN; hp_space.len()];
            for r in &results.results {
                if r.config_idx >= hp_space.len() {
                    return Err(TuneError::StaleCache(format!(
                        "hypertuning results for {} reference config {} outside \
                         its {}-config hyperparameter space",
                        d.name,
                        r.config_idx,
                        hp_space.len()
                    )));
                }
                scores[r.config_idx] = r.score;
            }
            let best = results.best();
            let default_score = default_result.score();
            Ok(OptimizerSweep {
                algo: d.name.to_string(),
                paper: d.paper,
                configs: hp_space.len(),
                space_key: results.space_key.clone(),
                default_hp_key: default_result.hp_key.clone(),
                default_score,
                best_hp_key: best.hp_key.clone(),
                best_config_idx: best.config_idx,
                best_score: best.score,
                improvement_pct: improvement_pct(default_score, best.score),
                scores,
                wallclock_seconds: ot0.elapsed().as_secs_f64(),
            })
        })();
        match leg {
            Ok(o) => {
                observer.sweep_optimizer_finished(i, d.name, o.default_score, o.best_score);
                optimizers_out.push(o);
            }
            // Quarantine: a panicked-out leg must not discard the rest of
            // the sweep. Every other error class (stale caches, schema
            // violations, I/O) poisons the whole sweep equally and stays
            // fatal.
            Err(TuneError::WorkerPanic {
                job,
                attempts,
                message,
            }) => {
                let error =
                    format!("tuning job {job} panicked after {attempts} attempt(s): {message}");
                observer.leg_failed(d.name, &error, attempts);
                failed_legs.push(FailedLeg {
                    leg: d.name.to_string(),
                    error,
                    attempts,
                });
            }
            Err(e) => return Err(e),
        }
        if let Some(cp) = checkpoint {
            let completed = optimizers_out.len() + failed_legs.len();
            if completed % cp.every_legs == 0 {
                let partial = SweepResult {
                    space_kind: "limited".to_string(),
                    repeats,
                    seed,
                    train: swept_train.clone(),
                    optimizers: optimizers_out.clone(),
                    failed_legs: failed_legs.clone(),
                    wallclock_seconds: t0.elapsed().as_secs_f64(),
                };
                // Best-effort: a flaky disk must not kill the sweep; the
                // final save at the call site reports its error normally.
                match partial.save(&cp.path) {
                    Ok(()) => observer
                        .checkpoint_saved(&cp.path.display().to_string(), completed),
                    Err(e) => crate::log_warn!(
                        "sweep checkpoint {} failed: {e:#}",
                        cp.path.display()
                    ),
                }
            }
        }
    }
    let result = SweepResult {
        space_kind: "limited".to_string(),
        repeats,
        seed,
        train: swept_train,
        optimizers: optimizers_out,
        failed_legs,
        wallclock_seconds: t0.elapsed().as_secs_f64(),
    };
    observer.sweep_finished(result.mean_improvement_pct(), result.wallclock_seconds);
    Ok(result)
}

/// Render the paper-style comparison artifacts through a [`Report`]
/// sink: the per-optimizer default-vs-hypertuned table (paper four and
/// extras side by side), the per-grid score-distribution violins, and
/// the mean-improvement summary line.
pub fn render_report(result: &SweepResult, report: &Report) -> Result<()> {
    let mut table = Table::new(
        &format!(
            "Registry hypertuning sweep: {} grids, {} repeats, seed {}, {} training spaces",
            result.space_kind,
            result.repeats,
            result.seed,
            result.train.len()
        ),
        &[
            "optimizer",
            "set",
            "configs",
            "default",
            "best",
            "delta",
            "improv %",
            "best hyperparameters",
        ],
    );
    for o in &result.optimizers {
        table.row(vec![
            o.algo.clone(),
            if o.paper { "paper" } else { "extra" }.to_string(),
            o.configs.to_string(),
            format!("{:+.3}", o.default_score),
            format!("{:+.3}", o.best_score),
            format!("{:+.3}", o.best_score - o.default_score),
            format!("{:+.1}", o.improvement_pct),
            o.best_hp_key.clone(),
        ]);
    }
    report.table(&table)?;
    let dists: Vec<(String, Vec<f64>)> = result
        .optimizers
        .iter()
        .map(|o| (o.algo.clone(), o.scores.iter().copied().filter(|s| s.is_finite()).collect()))
        .collect();
    report.violins(
        "Score distribution over each optimizer's limited hyperparameter grid",
        &dists,
    )?;
    render_failed_legs(&result.failed_legs, report)?;
    report.summary(&format!(
        "mean improvement of hypertuned-best over schema defaults: {:+.1}% \
         across {} optimizers (paper, 4 algos: 94.8%); sweep took {}{}\n",
        result.mean_improvement_pct(),
        result.optimizers.len(),
        fmt_duration(result.wallclock_seconds),
        if result.failed_legs.is_empty() {
            String::new()
        } else {
            format!("; {} leg(s) QUARANTINED", result.failed_legs.len())
        }
    ))?;
    Ok(())
}

/// Render the quarantined-legs table (shared by the sweep and metasweep
/// reports); a no-op when the sweep was fully healthy.
pub fn render_failed_legs(failed: &[FailedLeg], report: &Report) -> Result<()> {
    if failed.is_empty() {
        return Ok(());
    }
    let mut table = Table::new(
        &format!("Quarantined legs ({}): partial results", failed.len()),
        &["leg", "attempts", "error"],
    );
    for f in failed {
        table.row(vec![f.leg.clone(), f.attempts.to_string(), f.error.clone()]);
    }
    report.table_as("failures", &table)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::NullObserver;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::LiveRunner;
    use crate::runtime::Engine;
    use std::sync::OnceLock;

    fn train() -> &'static Vec<SpaceEval> {
        static TRAIN: OnceLock<Vec<SpaceEval>> = OnceLock::new();
        TRAIN.get_or_init(|| {
            let kernel = kernels::kernel_by_name("synthetic").unwrap();
            let mut live = LiveRunner::new(
                kernels::kernel_by_name("synthetic").unwrap(),
                &A100,
                Arc::new(Engine::native()),
                NoiseModel::default(),
                42,
            );
            let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
            vec![SpaceEval::new(kernel.space_arc(), cache, 0.95, 10)]
        })
    }

    /// One shared sweep for the read-only assertions (a full registry
    /// sweep is ~300 campaigns — run it once); the determinism golden
    /// below runs its own second, fresh sweep to compare against.
    fn run_sweep() -> &'static SweepResult {
        static RESULT: OnceLock<SweepResult> = OnceLock::new();
        RESULT.get_or_init(|| sweep_registry(train(), 1, 7, Arc::new(NullObserver)).unwrap())
    }

    /// Golden: the sweep covers exactly the grid-bearing registry set
    /// (the same property `derived_spaces_exist_for_every_optimizer_with_grids`
    /// pins at the space layer) — paper four plus extras — and two runs
    /// with the same seed produce bitwise-equal scores.
    #[test]
    fn sweep_covers_registry_and_is_deterministic() {
        let a = run_sweep();
        let names: Vec<&str> = a.optimizers.iter().map(|o| o.algo.as_str()).collect();
        assert_eq!(names, optimizers::hypertunable_names());
        // Paper four present and flagged; ROADMAP extras present as extras.
        for algo in crate::hypertuning::limited_algos() {
            let o = a.optimizers.iter().find(|o| o.algo == algo).unwrap();
            assert!(o.paper, "{algo} should carry the paper flag");
        }
        for extra in ["greedy_ils", "basin_hopping"] {
            let o = a.optimizers.iter().find(|o| o.algo == extra).unwrap();
            assert!(!o.paper, "{extra} must stay out of the paper set");
        }
        let b = sweep_registry(train(), 1, 7, Arc::new(NullObserver)).unwrap();
        assert_eq!(a.optimizers.len(), b.optimizers.len());
        for (oa, ob) in a.optimizers.iter().zip(&b.optimizers) {
            assert_eq!(oa.algo, ob.algo);
            assert_eq!(
                oa.default_score.to_bits(),
                ob.default_score.to_bits(),
                "{}: default score drift",
                oa.algo
            );
            assert_eq!(
                oa.best_score.to_bits(),
                ob.best_score.to_bits(),
                "{}: best score drift",
                oa.algo
            );
            assert_eq!(oa.best_config_idx, ob.best_config_idx, "{}", oa.algo);
            assert_eq!(oa.best_hp_key, ob.best_hp_key, "{}", oa.algo);
            assert_eq!(oa.scores.len(), oa.configs);
            for (sa, sb) in oa.scores.iter().zip(&ob.scores) {
                assert_eq!(sa.to_bits(), sb.to_bits(), "{}: grid score drift", oa.algo);
            }
        }
    }

    /// Per-optimizer invariants: the envelope's best is the max of its
    /// grid scores, beats (or ties) the default reference, and the
    /// improvement field matches the documented formula.
    #[test]
    fn sweep_envelope_is_internally_consistent() {
        let r = run_sweep();
        assert_eq!(r.space_kind, "limited");
        assert_eq!(r.repeats, 1);
        assert_eq!(r.train.len(), 1);
        assert_eq!(r.train[0].label, "synthetic@A100");
        assert!(!r.train[0].space_fingerprint.is_empty());
        for o in &r.optimizers {
            let grid_max = o.scores.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(o.best_score.to_bits(), grid_max.to_bits(), "{}", o.algo);
            assert_eq!(
                o.scores[o.best_config_idx].to_bits(),
                o.best_score.to_bits(),
                "{}",
                o.algo
            );
            assert!(o.default_score.is_finite(), "{}", o.algo);
            // Exhaustive best can never lose to a configuration drawn from
            // defaults *within the grid*; defaults may sit off-grid, so
            // only sanity-bound the improvement here.
            assert!(
                o.improvement_pct.is_finite(),
                "{}: improvement {}",
                o.algo,
                o.improvement_pct
            );
            assert_eq!(
                o.improvement_pct.to_bits(),
                improvement_pct(o.default_score, o.best_score).to_bits(),
                "{}",
                o.algo
            );
            assert!(!o.space_key.is_empty(), "{}", o.algo);
            assert!(!o.default_hp_key.is_empty(), "{}", o.algo);
        }
    }

    #[test]
    fn envelope_roundtrips_through_text() {
        let r = run_sweep();
        let text = r.to_json().to_pretty();
        let back = SweepResult::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.space_kind, r.space_kind);
        assert_eq!(back.repeats, r.repeats);
        assert_eq!(back.seed, r.seed);
        assert_eq!(back.train.len(), r.train.len());
        assert_eq!(back.train[0].space_fingerprint, r.train[0].space_fingerprint);
        assert_eq!(back.optimizers.len(), r.optimizers.len());
        for (b, o) in back.optimizers.iter().zip(&r.optimizers) {
            assert_eq!(b.algo, o.algo);
            assert_eq!(b.paper, o.paper);
            assert_eq!(b.configs, o.configs);
            assert_eq!(b.space_key, o.space_key);
            assert_eq!(b.best_hp_key, o.best_hp_key);
            assert_eq!(b.best_config_idx, o.best_config_idx);
            assert_eq!(b.default_score.to_bits(), o.default_score.to_bits());
            assert_eq!(b.best_score.to_bits(), o.best_score.to_bits());
            assert_eq!(b.scores.len(), o.scores.len());
        }
        // Mean improvement survives the round-trip bitwise.
        assert_eq!(
            back.mean_improvement_pct().to_bits(),
            r.mean_improvement_pct().to_bits()
        );
    }

    #[test]
    fn envelope_rejects_foreign_and_future_schemas() {
        let mut j = Json::obj();
        j.set("schema", "something-else".into());
        assert!(SweepResult::from_json(&j).is_err());
        let mut j = run_sweep().to_json();
        j.set("schema_version", 999.0.into());
        assert!(SweepResult::from_json(&j).is_err());
    }

    #[test]
    fn file_roundtrip_gz() {
        let r = run_sweep();
        let dir = std::env::temp_dir().join(format!("tt_sweep_{}", std::process::id()));
        let path = dir.join("sweep.json.gz");
        r.save(&path).unwrap();
        let back = SweepResult::load(&path).unwrap();
        assert_eq!(back.optimizers.len(), r.optimizers.len());
        assert_eq!(back.seed, r.seed);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Stale persisted results (fingerprint from another grid) must fail
    /// as a typed error instead of silently misdecoding config indices.
    #[test]
    fn stale_provider_results_are_typed_error() {
        let err = sweep_registry_with(train(), 1, 7, Arc::new(NullObserver), |algo| {
            let hp_space = space::limited_space(algo)?;
            Ok(Arc::new(HyperTuningResults {
                algo: algo.to_string(),
                space_kind: "limited".into(),
                space_key: "stale-fingerprint".into(),
                repeats: 1,
                seed: 7,
                results: (0..hp_space.len())
                    .map(|i| exhaustive::HyperResult {
                        config_idx: i,
                        hp_key: format!("c{i}"),
                        score: 0.0,
                    })
                    .collect(),
                wallclock_seconds: 1.0,
                simulated_seconds: 1.0,
            }))
        })
        .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err:#}");
    }

    /// A provider result with a config index outside the derived space
    /// (corrupt persisted file) is a typed error, not an index panic.
    #[test]
    fn out_of_space_config_idx_is_typed_error() {
        let err = sweep_registry_with(train(), 1, 7, Arc::new(NullObserver), |algo| {
            let hp_space = space::limited_space(algo)?;
            Ok(Arc::new(HyperTuningResults {
                algo: algo.to_string(),
                space_kind: "limited".into(),
                space_key: hp_space.fingerprint(),
                repeats: 1,
                seed: 7,
                results: (0..hp_space.len())
                    .map(|i| exhaustive::HyperResult {
                        // Right count, but the last index points past
                        // the end of the space.
                        config_idx: if i + 1 == hp_space.len() { hp_space.len() } else { i },
                        hp_key: format!("c{i}"),
                        score: 0.0,
                    })
                    .collect(),
                wallclock_seconds: 1.0,
                simulated_seconds: 1.0,
            }))
        })
        .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err:#}");
        assert!(format!("{err}").contains("outside"), "{err}");
    }

    #[test]
    fn empty_training_set_rejected() {
        let err = sweep_registry(&[], 1, 7, Arc::new(NullObserver)).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");
    }

    #[test]
    fn report_renders_table_violins_summary() {
        let r = run_sweep();
        let dir = std::env::temp_dir().join(format!("tt_sweeprep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let report = Report::new(&dir, "sweep");
        render_report(r, &report).unwrap();
        let table = std::fs::read_to_string(dir.join("sweep_table.txt")).unwrap();
        for o in &r.optimizers {
            assert!(table.contains(&o.algo), "table missing {}", o.algo);
        }
        assert!(table.contains("paper") && table.contains("extra"));
        assert!(dir.join("sweep_data.csv").exists());
        assert!(dir.join("sweep_violin.txt").exists());
        assert!(dir.join("sweep_dist.csv").exists());
        let summary = std::fs::read_to_string(dir.join("sweep_summary.txt")).unwrap();
        assert!(summary.contains("mean improvement"), "{summary}");
        // A healthy sweep writes no failure table.
        assert!(!dir.join("sweep_failures.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Sweep progress events fire from the driving thread in the
    /// documented strict order, and a provider returning
    /// correctly-fingerprinted results is accepted as-is (its scores
    /// flow straight into the envelope).
    #[test]
    fn sweep_events_are_strictly_ordered() {
        use std::sync::Mutex;

        #[derive(Default)]
        struct Collector(Mutex<Vec<String>>);
        impl Observer for Collector {
            fn sweep_started(&self, optimizers: usize, repeats: usize) {
                self.0.lock().unwrap().push(format!("started {optimizers} {repeats}"));
            }
            fn sweep_optimizer_started(&self, idx: usize, algo: &str, _configs: usize) {
                self.0.lock().unwrap().push(format!("opt_started {idx} {algo}"));
            }
            fn sweep_optimizer_finished(&self, idx: usize, algo: &str, _d: f64, _b: f64) {
                self.0.lock().unwrap().push(format!("opt_finished {idx} {algo}"));
            }
            fn sweep_finished(&self, _pct: f64, _w: f64) {
                self.0.lock().unwrap().push("finished".to_string());
            }
        }

        let collector = Arc::new(Collector::default());
        let result = sweep_registry_with(
            train(),
            1,
            7,
            Arc::clone(&collector) as Arc<dyn Observer>,
            |algo| {
                let hp_space = space::limited_space(algo)?;
                Ok(Arc::new(HyperTuningResults {
                    algo: algo.to_string(),
                    space_kind: "limited".into(),
                    space_key: hp_space.fingerprint(),
                    repeats: 1,
                    seed: 7,
                    results: (0..hp_space.len())
                        .map(|i| exhaustive::HyperResult {
                            config_idx: i,
                            hp_key: format!("c{i}"),
                            score: 0.01 * i as f64,
                        })
                        .collect(),
                    wallclock_seconds: 1.0,
                    simulated_seconds: 1.0,
                }))
            },
        )
        .unwrap();
        // Provider scores flow straight into the envelope: best is the
        // highest-index config of each grid.
        for o in &result.optimizers {
            assert_eq!(o.best_config_idx, o.configs - 1, "{}", o.algo);
            assert!((o.best_score - 0.01 * (o.configs - 1) as f64).abs() < 1e-12);
        }
        let events = collector.0.lock().unwrap().clone();
        let n = result.optimizers.len();
        assert_eq!(events[0], format!("started {n} 1"));
        assert_eq!(events.last().unwrap(), "finished");
        // Per optimizer: started immediately before finished, in sweep
        // (= registration) order.
        for (i, o) in result.optimizers.iter().enumerate() {
            assert_eq!(events[1 + 2 * i], format!("opt_started {i} {}", o.algo));
            assert_eq!(events[2 + 2 * i], format!("opt_finished {i} {}", o.algo));
        }
        assert_eq!(events.len(), 2 + 2 * n);
    }

    /// The lookup accessors the metasweep's regret computation rests on:
    /// per-algo best/default scores, zero regret at the optimum, and the
    /// registry-wide totals.
    #[test]
    fn lookup_accessors_agree_with_entries() {
        let r = run_sweep();
        for o in &r.optimizers {
            assert_eq!(
                r.best_score_for(&o.algo).unwrap().to_bits(),
                o.best_score.to_bits()
            );
            assert_eq!(
                r.default_score_for(&o.algo).unwrap().to_bits(),
                o.default_score.to_bits()
            );
            // Recovering the optimum exactly means zero regret (bitwise:
            // x - x is +0.0 for finite x); any worse score is positive.
            assert_eq!(r.optimum_regret(&o.algo, o.best_score), Some(0.0));
            assert!(r.optimum_regret(&o.algo, o.best_score - 0.5).unwrap() > 0.0);
        }
        assert!(r.entry("no_such_optimizer").is_none());
        assert!(r.best_score_for("no_such_optimizer").is_none());
        assert!(r.optimum_regret("no_such_optimizer", 0.0).is_none());
        assert_eq!(
            r.total_configs(),
            r.optimizers.iter().map(|o| o.configs).sum::<usize>()
        );
        let (best_algo, best_score) = r.overall_best().unwrap();
        let max = r
            .optimizers
            .iter()
            .map(|o| o.best_score)
            .fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(best_score.to_bits(), max.to_bits());
        assert_eq!(r.best_score_for(best_algo).unwrap().to_bits(), max.to_bits());
    }

    /// Synthetic exhaustive results keyed to the current schema spaces —
    /// the cheap provider the fault-tolerance tests sweep with.
    fn synthetic_provider(algo: &str) -> Result<Arc<HyperTuningResults>> {
        let hp_space = space::limited_space(algo)?;
        Ok(Arc::new(HyperTuningResults {
            algo: algo.to_string(),
            space_kind: "limited".into(),
            space_key: hp_space.fingerprint(),
            repeats: 1,
            seed: 7,
            results: (0..hp_space.len())
                .map(|i| exhaustive::HyperResult {
                    config_idx: i,
                    hp_key: format!("c{i}"),
                    score: 0.01 * i as f64,
                })
                .collect(),
            wallclock_seconds: 1.0,
            simulated_seconds: 1.0,
        }))
    }

    /// The tentpole quarantine property: a leg whose campaign panics on
    /// every attempt lands in `failed_legs` while every other optimizer
    /// completes, and the record survives the JSON roundtrip.
    #[test]
    fn panicked_leg_is_quarantined_while_others_complete() {
        use std::sync::Mutex;

        #[derive(Default)]
        struct FailureCollector(Mutex<Vec<String>>);
        impl Observer for FailureCollector {
            fn leg_failed(&self, leg: &str, error: &str, attempts: usize) {
                self.0
                    .lock()
                    .unwrap()
                    .push(format!("{leg} {attempts} {error}"));
            }
        }

        let victim = optimizers::hypertunable_names()[0];
        let plan = Arc::new(FaultPlan::parse(&format!("panic@{victim}.j0x*")).unwrap());
        let collector = Arc::new(FailureCollector::default());
        let r = sweep_registry_checkpointed(
            train(),
            1,
            7,
            Arc::clone(&collector) as Arc<dyn Observer>,
            None,
            Some(plan),
            synthetic_provider,
        )
        .unwrap();
        let all = optimizers::hypertunable_names();
        assert_eq!(r.failed_legs.len(), 1);
        assert_eq!(r.failed_legs[0].leg, victim);
        assert_eq!(r.failed_legs[0].attempts, 2, "default retry policy");
        assert!(
            r.failed_legs[0].error.contains("injected fault"),
            "{}",
            r.failed_legs[0].error
        );
        assert_eq!(r.optimizers.len(), all.len() - 1);
        assert!(r.entry(victim).is_none());
        let events = collector.0.lock().unwrap().clone();
        assert_eq!(events.len(), 1);
        assert!(events[0].starts_with(&format!("{victim} 2")), "{}", events[0]);
        // The quarantine record survives the envelope roundtrip.
        let back = SweepResult::from_json(&r.to_json()).unwrap();
        assert_eq!(back.failed_legs.len(), 1);
        assert_eq!(back.failed_legs[0].leg, victim);
        assert_eq!(back.failed_legs[0].attempts, 2);
    }

    /// With a checkpoint policy the partial envelope lands on disk every
    /// N legs; the surviving file is a loadable prefix of the final
    /// result — exactly the state a killed sweep resumes from.
    #[test]
    fn checkpoint_saves_loadable_partial_envelopes() {
        let dir = std::env::temp_dir().join(format!("tt_sweep_cp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sweep_checkpoint.json.gz");
        let cp = Checkpoint::new(&path, 2);
        let r = sweep_registry_checkpointed(
            train(),
            1,
            7,
            Arc::new(NullObserver),
            Some(&cp),
            None,
            synthetic_provider,
        )
        .unwrap();
        let cp_result = SweepResult::load(&path).unwrap();
        // The last checkpoint fired at the largest multiple of every_legs.
        let expect = r.optimizers.len() - r.optimizers.len() % 2;
        assert_eq!(cp_result.optimizers.len(), expect);
        for (a, b) in cp_result.optimizers.iter().zip(&r.optimizers) {
            assert_eq!(a.algo, b.algo);
            assert_eq!(a.default_score.to_bits(), b.default_score.to_bits());
            assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
        }
        assert_eq!(cp_result.seed, r.seed);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A sweep with quarantined legs renders the failure table and flags
    /// the summary; a healthy sweep writes no failures artifact.
    #[test]
    fn report_renders_failure_table_for_quarantined_legs() {
        let mut r = run_sweep().clone();
        r.failed_legs.push(FailedLeg {
            leg: "pso".into(),
            error: "tuning job 0 panicked after 2 attempt(s): boom".into(),
            attempts: 2,
        });
        let dir = std::env::temp_dir().join(format!("tt_sweepq_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let report = Report::new(&dir, "sweepq");
        render_report(&r, &report).unwrap();
        let failures = std::fs::read_to_string(dir.join("sweepq_failures.txt")).unwrap();
        assert!(failures.contains("pso") && failures.contains("boom"), "{failures}");
        let summary = std::fs::read_to_string(dir.join("sweepq_summary.txt")).unwrap();
        assert!(summary.contains("QUARANTINED"), "{summary}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn improvement_pct_formula() {
        assert!((improvement_pct(0.2, 0.4) - 100.0).abs() < 1e-9);
        assert!((improvement_pct(-0.2, 0.2) - 200.0).abs() < 1e-9);
        // Near-zero default: percentage points, not an exploding ratio.
        assert!((improvement_pct(0.0, 0.5) - 50.0).abs() < 1e-9);
    }
}
