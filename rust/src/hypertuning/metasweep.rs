//! Metasweep: race every registered meta-strategy against the
//! exhaustive sweep's optimum.
//!
//! The exhaustive sweep ([`super::sweep`]) is the golden reference: it
//! scores every configuration of every limited grid, so its best is the
//! true optimum and its cost is the ceiling (one full-repeat-equivalent
//! unit per configuration). The metasweep gives each registered
//! [`MetaStrategy`](super::strategy::MetaStrategy) a fraction of that
//! cost ([`DEFAULT_BUDGET_FRACTION`] unless overridden) and scores the
//! *methodology*: how much of the exhaustive best-vs-default improvement
//! does the strategy recover, at what fraction of the exhaustive
//! meta-evaluations, and with how much regret against the optimum?
//! Because a full-repeat meta-evaluation reproduces the exhaustive
//! campaign bitwise, regret is exact — the strategy's best is a member
//! of the reference score array, never an estimate.
//!
//! Results aggregate into a versioned [`MetaSweepResult`] envelope
//! (schema [`METASWEEP_SCHEMA`]) carrying per-(strategy, target) legs
//! with budgets, spent cost, best keys/scores, regret and recovery,
//! plus the training-space and hyperparameter-space fingerprints as
//! staleness provenance: [`metasweep_registry_with`] reuses a prior
//! envelope's legs only when seed, repeats, rung parameters, budgets
//! and every fingerprint still match. `tunetuner metasweep
//! [--strategy S] [--budget N] [--json]` drives it from the CLI;
//! progress streams through the [`Observer::meta_sweep_started`]-family
//! events.

use super::space;
use super::strategy::{self, MetaBudget, MetaCampaign};
use super::sweep::{improvement_pct, Checkpoint, FailedLeg, SweepResult, SweptSpace};
use crate::campaign::Observer;
use crate::error::{Context, Result, TuneError};
use crate::faults::FaultPlan;
use crate::methodology::SpaceEval;
use crate::optimizers;
use crate::report::Report;
use crate::util::json::{self, Json};
use crate::util::rng::{mix64, Rng};
use crate::util::table::{fmt_duration, Table};
use std::path::Path;
use std::sync::Arc;

/// Schema tag of the serialized metasweep envelope.
pub const METASWEEP_SCHEMA: &str = "tunetuner-metasweep";

/// Version of the serialized metasweep envelope; bump on breaking changes.
pub const METASWEEP_SCHEMA_VERSION: u64 = 1;

/// Fraction of the exhaustive sweep's cost a strategy may spend when no
/// explicit `--budget` override is given: the paper's "a quarter of the
/// grid" operating point the acceptance gates are phrased against.
pub const DEFAULT_BUDGET_FRACTION: f64 = 0.25;

/// Full-repeat evaluation floor granted to non-racing (surrogate)
/// strategies on tiny grids: a quarter of an 8-config grid would be two
/// evaluations, too few for any surrogate to act on.
const SMALL_GRID_FLOOR: f64 = 8.0;

/// How a metasweep is parameterized beyond (train, repeats, seed).
#[derive(Clone, Debug)]
pub struct MetaSweepConfig {
    /// Strategy names to race, in this order; empty means the whole
    /// registry ([`strategy::strategies`] order).
    pub strategies: Vec<String>,
    /// Per-leg budget override in full-repeat-equivalent units (per
    /// optimizer leg for per-optimizer strategies, total for the
    /// registry-wide portfolio leg). `None` uses the
    /// [`DEFAULT_BUDGET_FRACTION`] allocator.
    pub budget: Option<f64>,
    /// Racing rung growth factor (see [`MetaBudget::eta`]).
    pub eta: usize,
    /// Repeats of the cheapest racing rung.
    pub min_repeats: usize,
}

impl Default for MetaSweepConfig {
    fn default() -> MetaSweepConfig {
        MetaSweepConfig {
            strategies: Vec::new(),
            budget: None,
            eta: 4,
            min_repeats: 1,
        }
    }
}

/// One (strategy, target) leg of a metasweep.
#[derive(Clone, Debug)]
pub struct StrategyLeg {
    pub strategy: String,
    /// Optimizer name, or `"registry"` for registry-wide strategies.
    pub target: String,
    /// Optimizer of the best configuration (equals `target` except for
    /// registry-wide legs, where it is the race winner).
    pub algo: String,
    /// Fingerprint of the hyperparameter space the best configuration
    /// lives in (staleness provenance for resume).
    pub hp_space_key: String,
    /// Exhaustive meta-evaluations of the reference this leg is measured
    /// against: the grid size, or the sum of all grids for registry-wide
    /// legs.
    pub configs: usize,
    /// Budget granted, in full-repeat-equivalent units.
    pub budget_cost: f64,
    /// Cost actually charged.
    pub spent_cost: f64,
    /// Fresh (non-memoized) evaluations performed.
    pub evals: usize,
    pub best_config_idx: usize,
    pub best_hp_key: String,
    /// Best full-repeat Eq. 3 score the strategy found.
    pub best_score: f64,
    /// The reference default: the schema-default score of `target`, or
    /// the best default across the registry for registry-wide legs.
    pub default_score: f64,
    /// The exhaustive optimum this leg is chasing.
    pub exhaustive_best_score: f64,
    /// `exhaustive_best_score - best_score` — exact, not estimated,
    /// because full-repeat meta-evaluations match the reference bitwise.
    pub regret: f64,
    /// [`leg_recovery`] of this leg, clamped to `[0, 1]` for display.
    pub improvement_recovered: f64,
    /// `spent_cost / configs` — the leg's cost relative to exhaustive.
    pub cost_fraction: f64,
    /// Real seconds this leg took (0 when replayed from a prior
    /// envelope).
    pub wallclock_seconds: f64,
}

/// All legs of one strategy, in leg (= optimizer registration) order.
#[derive(Clone, Debug)]
pub struct StrategyRun {
    pub strategy: String,
    pub legs: Vec<StrategyLeg>,
    pub wallclock_seconds: f64,
}

impl StrategyRun {
    /// Mean [`improvement_pct`] of the strategy's bests over the
    /// reference defaults.
    pub fn mean_improvement_pct(&self) -> f64 {
        let pcts: Vec<f64> = self
            .legs
            .iter()
            .map(|l| improvement_pct(l.default_score, l.best_score))
            .collect();
        crate::util::stats::mean(&pcts)
    }

    /// Mean [`improvement_pct`] of the exhaustive optima over the same
    /// defaults — what a 100% recovery would score.
    pub fn exhaustive_mean_improvement_pct(&self) -> f64 {
        let pcts: Vec<f64> = self
            .legs
            .iter()
            .map(|l| improvement_pct(l.default_score, l.exhaustive_best_score))
            .collect();
        crate::util::stats::mean(&pcts)
    }

    /// Fraction of the exhaustive mean improvement this strategy
    /// recovered: the ratio of the two means above (so legs with large
    /// improvements dominate, and near-degenerate legs cannot blow the
    /// ratio up). When the exhaustive mean itself is not meaningfully
    /// positive there is nothing to recover: matching it counts as 1.0,
    /// falling short as 0.0.
    pub fn recovery(&self) -> f64 {
        if self.legs.is_empty() {
            return 0.0;
        }
        let got = self.mean_improvement_pct();
        let exh = self.exhaustive_mean_improvement_pct();
        if exh > 1e-9 {
            got / exh
        } else if got >= exh - 1e-9 {
            1.0
        } else {
            0.0
        }
    }

    /// Total cost spent relative to the exhaustive meta-evaluations of
    /// every target this strategy raced.
    pub fn cost_fraction(&self) -> f64 {
        let configs: usize = self.legs.iter().map(|l| l.configs).sum();
        if configs == 0 {
            return 0.0;
        }
        self.spent_cost() / configs as f64
    }

    /// Total cost charged across legs, in full-repeat-equivalent units.
    pub fn spent_cost(&self) -> f64 {
        self.legs.iter().map(|l| l.spent_cost).sum()
    }

    /// Total fresh evaluations across legs.
    pub fn evals(&self) -> usize {
        self.legs.iter().map(|l| l.evals).sum()
    }
}

/// Per-leg recovered-improvement fraction, clamped to `[0, 1]`:
/// `(best - default) / (exhaustive_best - default)`. A degenerate leg
/// (exhaustive best within `1e-12` of the default) counts as fully
/// recovered when the strategy matched it.
pub fn leg_recovery(default_score: f64, best_score: f64, exhaustive_best: f64) -> f64 {
    let exh = exhaustive_best - default_score;
    let got = best_score - default_score;
    if exh.abs() <= 1e-12 {
        if got >= -1e-12 {
            1.0
        } else {
            0.0
        }
    } else {
        (got / exh).clamp(0.0, 1.0)
    }
}

/// The complete, serializable outcome of a metasweep.
#[derive(Clone, Debug)]
pub struct MetaSweepResult {
    /// Grid kind the strategies searched (always `"limited"`, matching
    /// the reference sweep).
    pub space_kind: String,
    /// Full-budget repeat count — the exhaustive sweep's repeats, and
    /// the cost-unit denominator.
    pub repeats: usize,
    pub seed: u64,
    /// Racing rung growth factor the run used.
    pub eta: usize,
    /// Cheapest-rung repeats the run used.
    pub min_repeats: usize,
    /// The training spaces every campaign ran on, in space order.
    pub train: Vec<SweptSpace>,
    /// The reference sweep's mean improvement (provenance: which
    /// exhaustive result the regrets were computed against).
    pub reference_mean_improvement_pct: f64,
    /// One run per raced strategy, in race order. Quarantined legs are
    /// absent from their run and present in
    /// [`failed_legs`](Self::failed_legs).
    pub strategies: Vec<StrategyRun>,
    /// `strategy/target` legs that exhausted their campaign retry budget
    /// and were quarantined (empty on a fully healthy metasweep).
    pub failed_legs: Vec<FailedLeg>,
    /// Real seconds the whole metasweep took.
    pub wallclock_seconds: f64,
}

impl MetaSweepResult {
    /// The run for `strategy`, if it was raced.
    pub fn run(&self, strategy: &str) -> Option<&StrategyRun> {
        self.strategies.iter().find(|s| s.strategy == strategy)
    }

    // ---- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let train: Vec<Json> = self
            .train
            .iter()
            .map(|t| {
                let mut o = Json::obj();
                o.set("label", t.label.as_str().into())
                    .set("space_fingerprint", t.space_fingerprint.as_str().into());
                o
            })
            .collect();
        let runs: Vec<Json> = self
            .strategies
            .iter()
            .map(|s| {
                let legs: Vec<Json> = s
                    .legs
                    .iter()
                    .map(|l| {
                        let mut j = Json::obj();
                        j.set("strategy", l.strategy.as_str().into())
                            .set("target", l.target.as_str().into())
                            .set("algo", l.algo.as_str().into())
                            .set("hp_space_key", l.hp_space_key.as_str().into())
                            .set("configs", l.configs.into())
                            .set("budget_cost", l.budget_cost.into())
                            .set("spent_cost", l.spent_cost.into())
                            .set("evals", l.evals.into())
                            .set("best_config_idx", l.best_config_idx.into())
                            .set("best_hp_key", l.best_hp_key.as_str().into())
                            .set("best_score", l.best_score.into())
                            .set("default_score", l.default_score.into())
                            .set("exhaustive_best_score", l.exhaustive_best_score.into())
                            .set("regret", l.regret.into())
                            .set("improvement_recovered", l.improvement_recovered.into())
                            .set("cost_fraction", l.cost_fraction.into())
                            .set("wallclock_seconds", l.wallclock_seconds.into());
                        j
                    })
                    .collect();
                let mut j = Json::obj();
                j.set("strategy", s.strategy.as_str().into())
                    .set("legs", Json::Arr(legs))
                    .set("wallclock_seconds", s.wallclock_seconds.into());
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("schema", METASWEEP_SCHEMA.into())
            .set("schema_version", (METASWEEP_SCHEMA_VERSION as f64).into())
            .set("space_kind", self.space_kind.as_str().into())
            .set("repeats", self.repeats.into())
            // String, not number: JSON numbers are f64 and would corrupt
            // seeds >= 2^53 on the round-trip (same as SweepResult).
            .set("seed", self.seed.to_string().as_str().into())
            .set("eta", self.eta.into())
            .set("min_repeats", self.min_repeats.into())
            .set("train", Json::Arr(train))
            .set(
                "reference_mean_improvement_pct",
                self.reference_mean_improvement_pct.into(),
            )
            .set("strategies", Json::Arr(runs))
            .set(
                "failed_legs",
                Json::Arr(self.failed_legs.iter().map(|f| f.to_json()).collect()),
            )
            .set("wallclock_seconds", self.wallclock_seconds.into());
        j
    }

    /// Parse an envelope previously produced by [`to_json`](Self::to_json).
    pub fn from_json(j: &Json) -> Result<MetaSweepResult> {
        if j.get("schema").and_then(|v| v.as_str()) != Some(METASWEEP_SCHEMA) {
            crate::bail!("not a {METASWEEP_SCHEMA} envelope");
        }
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0) as u64;
        if version > METASWEEP_SCHEMA_VERSION {
            crate::bail!(
                "metasweep envelope version {version} is newer than this \
                 binary's {METASWEEP_SCHEMA_VERSION}"
            );
        }
        let train = j
            .get("train")
            .and_then(|v| v.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|t| SweptSpace {
                label: t
                    .get("label")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                space_fingerprint: t
                    .get("space_fingerprint")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            })
            .collect();
        let mut runs = Vec::new();
        for s in j
            .get("strategies")
            .and_then(|v| v.as_arr())
            .context("missing strategies")?
        {
            let mut legs = Vec::new();
            for l in s.get("legs").and_then(|v| v.as_arr()).unwrap_or(&[]) {
                let str_field = |k: &str| -> String {
                    l.get(k).and_then(|v| v.as_str()).unwrap_or_default().to_string()
                };
                let num_field =
                    |k: &str| -> f64 { l.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN) };
                legs.push(StrategyLeg {
                    strategy: str_field("strategy"),
                    target: l
                        .get("target")
                        .and_then(|v| v.as_str())
                        .context("leg missing target")?
                        .to_string(),
                    algo: str_field("algo"),
                    hp_space_key: str_field("hp_space_key"),
                    configs: l.get("configs").and_then(|v| v.as_usize()).unwrap_or(0),
                    budget_cost: num_field("budget_cost"),
                    spent_cost: num_field("spent_cost"),
                    evals: l.get("evals").and_then(|v| v.as_usize()).unwrap_or(0),
                    best_config_idx: l
                        .get("best_config_idx")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(0),
                    best_hp_key: str_field("best_hp_key"),
                    best_score: num_field("best_score"),
                    default_score: num_field("default_score"),
                    exhaustive_best_score: num_field("exhaustive_best_score"),
                    regret: num_field("regret"),
                    improvement_recovered: num_field("improvement_recovered"),
                    cost_fraction: num_field("cost_fraction"),
                    wallclock_seconds: num_field("wallclock_seconds"),
                });
            }
            runs.push(StrategyRun {
                strategy: s
                    .get("strategy")
                    .and_then(|v| v.as_str())
                    .context("run missing strategy")?
                    .to_string(),
                legs,
                wallclock_seconds: s
                    .get("wallclock_seconds")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
            });
        }
        Ok(MetaSweepResult {
            space_kind: j
                .get("space_kind")
                .and_then(|v| v.as_str())
                .unwrap_or("limited")
                .to_string(),
            repeats: j.get("repeats").and_then(|v| v.as_usize()).unwrap_or(0),
            seed: match j.get("seed") {
                Some(Json::Str(s)) => s.parse().unwrap_or(0),
                Some(v) => v.as_f64().unwrap_or(0.0) as u64,
                None => 0,
            },
            eta: j.get("eta").and_then(|v| v.as_usize()).unwrap_or(4),
            min_repeats: j.get("min_repeats").and_then(|v| v.as_usize()).unwrap_or(1),
            train,
            reference_mean_improvement_pct: j
                .get("reference_mean_improvement_pct")
                .and_then(|v| v.as_f64())
                .unwrap_or(f64::NAN),
            strategies: runs,
            failed_legs: FailedLeg::vec_from_json(j),
            wallclock_seconds: j
                .get("wallclock_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        crate::util::compress::write_string(path, &self.to_json().to_string())
    }

    pub fn load(path: &Path) -> Result<MetaSweepResult> {
        MetaSweepResult::from_json(&json::parse(&crate::util::compress::read_string(path)?)?)
    }

    /// [`load`](Self::load) that treats a missing, corrupt, truncated or
    /// foreign file as "no prior": logs a warning and returns `None` so
    /// resume paths start fresh instead of dying on a half-written
    /// artifact (which [`crate::util::fsio::atomic_write`] makes rare
    /// but a foreign file can still produce).
    pub fn load_tolerant(path: &Path) -> Option<MetaSweepResult> {
        if !path.exists() {
            return None;
        }
        match MetaSweepResult::load(path) {
            Ok(r) => Some(r),
            Err(e) => {
                crate::log_warn!(
                    "ignoring unreadable prior metasweep envelope {}: {e:#}",
                    path.display()
                );
                None
            }
        }
    }
}

/// Per-optimizer leg budgets, in full-repeat-equivalent units.
///
/// Racing strategies spend mostly cheap low-repeat rungs, so their
/// budget scales purely with grid size: `DEFAULT_BUDGET_FRACTION * g`
/// per grid. Full-repeat (surrogate) strategies additionally get a
/// [`SMALL_GRID_FLOOR`] on tiny grids, with the excess shaved
/// proportionally from the over-floor legs so the total still fits
/// `DEFAULT_BUDGET_FRACTION * sum(g)`. If even the floors alone exceed
/// that cap (a registry of only tiny grids), the floors are granted
/// as-is — a surrogate with two evaluations is noise, not a strategy.
pub(crate) fn allocate_budgets(grids: &[usize], racing: bool) -> Vec<f64> {
    let prop: Vec<f64> = grids
        .iter()
        .map(|&g| g as f64 * DEFAULT_BUDGET_FRACTION)
        .collect();
    if racing {
        return prop;
    }
    let cap: f64 = prop.iter().sum();
    let floors: Vec<f64> = grids.iter().map(|&g| (g as f64).min(SMALL_GRID_FLOOR)).collect();
    let mut want: Vec<f64> = prop
        .iter()
        .zip(&floors)
        .map(|(&p, &f)| p.max(f))
        .collect();
    let total: f64 = want.iter().sum();
    let excess = total - cap;
    if excess <= 1e-9 {
        return want;
    }
    let slack: f64 = want.iter().zip(&floors).map(|(&w, &f)| w - f).sum();
    if slack <= excess + 1e-9 {
        return floors;
    }
    for (w, &f) in want.iter_mut().zip(&floors) {
        *w -= (*w - f) / slack * excess;
    }
    want
}

/// Everything the driver needs about one per-optimizer target.
struct LegTarget {
    algo: &'static str,
    hp_space: Arc<crate::searchspace::SearchSpace>,
    default_score: f64,
    exhaustive_best: f64,
}

/// Race the configured meta-strategies over `train`, measuring each
/// against `reference` (a [`SweepResult`] from the same train/repeats/
/// seed). See [`metasweep_registry_with`] for resuming from a prior
/// envelope.
pub fn metasweep_registry(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    reference: &SweepResult,
    config: &MetaSweepConfig,
    observer: Arc<dyn Observer>,
) -> Result<MetaSweepResult> {
    metasweep_registry_with(train, repeats, seed, reference, config, None, observer)
}

/// [`metasweep_registry`] resuming from `prior`: a leg is replayed (not
/// re-run) when the prior envelope was produced under the same seed,
/// repeats, rung parameters and budgets, and every fingerprint —
/// training spaces, the leg's hyperparameter space, and the reference
/// scores it was measured against — still matches. Anything stale is
/// simply re-run; a prior from a different setup is ignored wholesale.
/// Because an incremental checkpoint envelope is just a prefix of the
/// final one, this same filter is the crash-resume path: feed the
/// checkpoint back as `prior` and the finished legs replay bit-for-bit
/// while the lost tail re-runs.
pub fn metasweep_registry_with(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    reference: &SweepResult,
    config: &MetaSweepConfig,
    prior: Option<&MetaSweepResult>,
    observer: Arc<dyn Observer>,
) -> Result<MetaSweepResult> {
    metasweep_registry_checkpointed(
        train, repeats, seed, reference, config, prior, None, None, observer,
    )
}

/// [`metasweep_registry_with`] plus the fault-tolerance layers: an
/// optional incremental [`Checkpoint`] (the partial envelope is
/// atomically rewritten every `every_legs` completed legs) and an
/// optional explicit [`FaultPlan`] injected into every meta-evaluation
/// campaign (chaos testing). A leg whose campaign exhausts its retry
/// budget ([`TuneError::WorkerPanic`]) is quarantined into the
/// envelope's `failed_legs` while the remaining legs complete; any
/// other error class stays fatal.
#[allow(clippy::too_many_arguments)]
pub fn metasweep_registry_checkpointed(
    train: &[SpaceEval],
    repeats: usize,
    seed: u64,
    reference: &SweepResult,
    config: &MetaSweepConfig,
    prior: Option<&MetaSweepResult>,
    checkpoint: Option<&Checkpoint>,
    faults: Option<Arc<FaultPlan>>,
    observer: Arc<dyn Observer>,
) -> Result<MetaSweepResult> {
    if train.is_empty() {
        return Err(TuneError::InvalidInput(
            "metasweep has no training spaces".into(),
        ));
    }
    if repeats == 0 {
        return Err(TuneError::InvalidInput("metasweep needs repeats >= 1".into()));
    }
    if reference.repeats != repeats || reference.seed != seed {
        return Err(TuneError::InvalidInput(format!(
            "reference sweep ran at {} repeats / seed {} but the metasweep \
             wants {repeats} / {seed}: scores would not be comparable",
            reference.repeats, reference.seed
        )));
    }
    if reference.train.len() != train.len() {
        return Err(TuneError::StaleCache(format!(
            "reference sweep saw {} training spaces, metasweep has {}",
            reference.train.len(),
            train.len()
        )));
    }
    for (rt, se) in reference.train.iter().zip(train) {
        if rt.space_fingerprint != se.space.fingerprint() {
            return Err(TuneError::StaleCache(format!(
                "training space {:?} changed since the reference sweep \
                 (fingerprint {:?} vs {:?})",
                se.label,
                se.space.fingerprint(),
                rt.space_fingerprint
            )));
        }
    }
    // Resolve strategies up front: unknown or duplicate names are input
    // errors before any campaign runs.
    let descs: Vec<&'static strategy::StrategyDescriptor> = if config.strategies.is_empty() {
        strategy::strategies().iter().collect()
    } else {
        config
            .strategies
            .iter()
            .map(|n| strategy::strategy_by_name(n))
            .collect::<Result<Vec<_>>>()?
    };
    for (i, d) in descs.iter().enumerate() {
        if descs[..i].iter().any(|o| o.name == d.name) {
            return Err(TuneError::InvalidInput(format!(
                "meta-strategy {:?} listed twice",
                d.name
            )));
        }
    }
    // Per-optimizer targets, verified against the reference: a missing
    // entry or a drifted hyperparameter grid is stale, not comparable.
    let mut targets = Vec::new();
    for d in optimizers::hypertunable() {
        let entry = reference.entry(d.name).ok_or_else(|| {
            TuneError::StaleCache(format!(
                "reference sweep has no entry for {:?}; re-run `tunetuner sweep`",
                d.name
            ))
        })?;
        let hp_space = Arc::new(space::limited_space(d.name)?);
        if entry.space_key != hp_space.fingerprint() {
            return Err(TuneError::StaleCache(format!(
                "reference sweep for {} was computed on hyperparameter space \
                 {:?} but the current schema derives {:?}",
                d.name,
                entry.space_key,
                hp_space.fingerprint()
            )));
        }
        if entry.configs != hp_space.len() {
            return Err(TuneError::StaleCache(format!(
                "reference sweep for {} carries {} configs but its \
                 hyperparameter space has {}",
                d.name,
                entry.configs,
                hp_space.len()
            )));
        }
        targets.push(LegTarget {
            algo: d.name,
            hp_space,
            default_score: entry.default_score,
            exhaustive_best: entry.best_score,
        });
    }
    // A prior envelope is usable only if produced under identical
    // determinism inputs; otherwise ignore it wholesale.
    let prior = prior.filter(|p| {
        p.repeats == repeats
            && p.seed == seed
            && p.eta == config.eta
            && p.min_repeats == config.min_repeats
            && p.train.len() == train.len()
            && p.train
                .iter()
                .zip(train)
                .all(|(pt, se)| pt.space_fingerprint == se.space.fingerprint())
    });
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let t0 = std::time::Instant::now();
    let train_arc: Arc<Vec<SpaceEval>> = Arc::new(train.to_vec());
    observer.meta_sweep_started(descs.len(), repeats);
    let registry_configs = reference.total_configs();
    let swept_train: Vec<SweptSpace> = train
        .iter()
        .map(|se| SweptSpace {
            label: se.label.clone(),
            space_fingerprint: se.space.fingerprint(),
        })
        .collect();
    let reference_pct = reference.mean_improvement_pct();
    let mut runs: Vec<StrategyRun> = Vec::with_capacity(descs.len());
    let mut failed_legs: Vec<FailedLeg> = Vec::new();
    // Successes + quarantines, for the checkpoint cadence.
    let mut completed = 0usize;
    // Assemble and best-effort-save a partial envelope: a checkpoint that
    // cannot be written must not kill a sweep that is otherwise healthy.
    let save_checkpoint =
        |strategies: Vec<StrategyRun>, failed: Vec<FailedLeg>, done: usize| {
            let Some(cp) = checkpoint else { return };
            let partial = MetaSweepResult {
                space_kind: "limited".to_string(),
                repeats,
                seed,
                eta: config.eta,
                min_repeats: config.min_repeats,
                train: swept_train.clone(),
                reference_mean_improvement_pct: reference_pct,
                strategies,
                failed_legs: failed,
                wallclock_seconds: t0.elapsed().as_secs_f64(),
            };
            match partial.save(&cp.path) {
                Ok(()) => observer.checkpoint_saved(&cp.path.display().to_string(), done),
                Err(e) => crate::log_warn!(
                    "metasweep checkpoint {} failed: {e:#}",
                    cp.path.display()
                ),
            }
        };
    for desc in &descs {
        // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
        let st0 = std::time::Instant::now();
        let mut legs = Vec::new();
        // (target, leg args) pairs this strategy will run, in leg order.
        let specs: Vec<LegSpec> = if desc.per_optimizer {
            let grids: Vec<usize> = targets.iter().map(|t| t.hp_space.len()).collect();
            let budgets: Vec<f64> = match config.budget {
                Some(b) => vec![b; targets.len()],
                None => allocate_budgets(&grids, desc.racing),
            };
            targets
                .iter()
                .enumerate()
                .map(|(i, target)| LegSpec {
                    target: target.algo,
                    algo: target.algo,
                    hp_space: Some(Arc::clone(&target.hp_space)),
                    configs: target.hp_space.len(),
                    budget_cost: budgets[i],
                    default_score: target.default_score,
                    exhaustive_best: target.exhaustive_best,
                    leg_idx: i as u64,
                })
                .collect()
        } else {
            // Registry-wide leg: measured against the whole sweep — the
            // best default any optimizer gets for free, the best score
            // any grid reaches, and the sum of all grids as cost.
            vec![LegSpec {
                target: "registry",
                algo: "",
                hp_space: None,
                configs: registry_configs,
                budget_cost: config
                    .budget
                    .unwrap_or(DEFAULT_BUDGET_FRACTION * registry_configs as f64),
                default_score: best_finite(targets.iter().map(|t| t.default_score)),
                exhaustive_best: best_finite(targets.iter().map(|t| t.exhaustive_best)),
                leg_idx: 0,
            }]
        };
        for spec in specs {
            match run_leg(
                desc,
                spec.target,
                spec.algo,
                spec.hp_space,
                spec.configs,
                spec.budget_cost,
                spec.default_score,
                spec.exhaustive_best,
                spec.leg_idx,
                &train_arc,
                repeats,
                seed,
                config,
                prior,
                faults.clone(),
                &observer,
            ) {
                Ok(leg) => legs.push(leg),
                // A leg whose campaign exhausted its retries is
                // quarantined so the remaining legs still complete; any
                // other error class (stale cache, invalid input, IO)
                // would poison every leg equally and stays fatal.
                Err(TuneError::WorkerPanic {
                    job,
                    attempts,
                    message,
                }) => {
                    let leg_id = format!("{}/{}", desc.name, spec.target);
                    let error = format!(
                        "tuning job {job} panicked after {attempts} attempt(s): {message}"
                    );
                    observer.leg_failed(&leg_id, &error, attempts);
                    failed_legs.push(FailedLeg {
                        leg: leg_id,
                        error,
                        attempts,
                    });
                }
                Err(e) => return Err(e),
            }
            completed += 1;
            if checkpoint.is_some_and(|cp| completed % cp.every_legs == 0) {
                let mut snapshot = runs.clone();
                snapshot.push(StrategyRun {
                    strategy: desc.name.to_string(),
                    legs: legs.clone(),
                    wallclock_seconds: st0.elapsed().as_secs_f64(),
                });
                save_checkpoint(snapshot, failed_legs.clone(), completed);
            }
        }
        runs.push(StrategyRun {
            strategy: desc.name.to_string(),
            legs,
            wallclock_seconds: st0.elapsed().as_secs_f64(),
        });
    }
    let result = MetaSweepResult {
        space_kind: "limited".to_string(),
        repeats,
        seed,
        eta: config.eta,
        min_repeats: config.min_repeats,
        train: swept_train,
        reference_mean_improvement_pct: reference_pct,
        strategies: runs,
        failed_legs,
        wallclock_seconds: t0.elapsed().as_secs_f64(),
    };
    observer.meta_sweep_finished(result.wallclock_seconds);
    Ok(result)
}

/// The per-leg arguments the driver feeds [`run_leg`], precomputed so
/// per-optimizer and registry-wide strategies share one quarantine /
/// checkpoint loop.
struct LegSpec {
    target: &'static str,
    algo: &'static str,
    hp_space: Option<Arc<crate::searchspace::SearchSpace>>,
    configs: usize,
    budget_cost: f64,
    default_score: f64,
    exhaustive_best: f64,
    leg_idx: u64,
}

/// Best finite value of an iterator (NaN demoted), or NaN when empty /
/// all-NaN.
fn best_finite(values: impl Iterator<Item = f64>) -> f64 {
    values.fold(f64::NAN, |acc, v| {
        if v.is_nan() || (!acc.is_nan() && v <= acc) {
            acc
        } else {
            v
        }
    })
}

/// Run (or replay from `prior`) one (strategy, target) leg.
#[allow(clippy::too_many_arguments)]
fn run_leg(
    desc: &strategy::StrategyDescriptor,
    target: &str,
    algo: &str,
    hp_space: Option<Arc<crate::searchspace::SearchSpace>>,
    configs: usize,
    budget_cost: f64,
    default_score: f64,
    exhaustive_best: f64,
    leg_idx: u64,
    train_arc: &Arc<Vec<SpaceEval>>,
    repeats: usize,
    seed: u64,
    config: &MetaSweepConfig,
    prior: Option<&MetaSweepResult>,
    faults: Option<Arc<FaultPlan>>,
    observer: &Arc<dyn Observer>,
) -> Result<StrategyLeg> {
    observer.meta_leg_started(desc.name, target, configs, budget_cost);
    if let Some(leg) = prior
        .and_then(|p| p.run(desc.name))
        .and_then(|r| r.legs.iter().find(|l| l.target == target))
        .filter(|l| {
            l.budget_cost.to_bits() == budget_cost.to_bits()
                && l.configs == configs
                && l.default_score.to_bits() == default_score.to_bits()
                && l.exhaustive_best_score.to_bits() == exhaustive_best.to_bits()
                && leg_space_key(hp_space.as_deref(), &l.algo)
                    .is_some_and(|k| k == l.hp_space_key)
        })
    {
        let leg = leg.clone();
        observer.meta_leg_finished(desc.name, target, leg.best_score, leg.spent_cost, leg.evals);
        return Ok(leg);
    }
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let lt0 = std::time::Instant::now();
    let mut mc = MetaCampaign::new(
        algo,
        hp_space.clone(),
        Arc::clone(train_arc),
        repeats,
        seed,
        MetaBudget {
            max_cost: budget_cost,
            max_wallclock: None,
            eta: config.eta,
            min_repeats: config.min_repeats,
        },
        Arc::clone(observer),
        desc.name,
        target,
    )?;
    mc.set_faults(faults);
    let mut rng = Rng::new(mix64(seed, desc.tag)).fork(leg_idx);
    let outcome = (desc.build)().run(&mut mc, &mut rng)?;
    let hp_space_key = leg_space_key(hp_space.as_deref(), &outcome.algo).ok_or_else(|| {
        TuneError::InvalidInput(format!(
            "strategy {:?} returned unknown optimizer {:?}",
            desc.name, outcome.algo
        ))
    })?;
    observer.meta_leg_finished(desc.name, target, outcome.best_score, mc.spent(), mc.evals());
    Ok(StrategyLeg {
        strategy: desc.name.to_string(),
        target: target.to_string(),
        algo: outcome.algo.clone(),
        hp_space_key,
        configs,
        budget_cost,
        spent_cost: mc.spent(),
        evals: mc.evals(),
        best_config_idx: outcome.best_config_idx,
        best_hp_key: outcome.best_hp_key,
        best_score: outcome.best_score,
        default_score,
        exhaustive_best_score: exhaustive_best,
        regret: exhaustive_best - outcome.best_score,
        improvement_recovered: leg_recovery(default_score, outcome.best_score, exhaustive_best),
        cost_fraction: if configs == 0 {
            0.0
        } else {
            mc.spent() / configs as f64
        },
        wallclock_seconds: lt0.elapsed().as_secs_f64(),
    })
}

/// Fingerprint of the hyperparameter space a leg's best configuration
/// lives in: the leg's own space for per-optimizer legs, the winner's
/// derived limited space for registry-wide legs. `None` when `algo`
/// has no limited grid (a registry-wide strategy misbehaving).
fn leg_space_key(
    hp_space: Option<&crate::searchspace::SearchSpace>,
    algo: &str,
) -> Option<String> {
    match hp_space {
        Some(s) => Some(s.fingerprint()),
        None => space::limited_space(algo).ok().map(|s| s.fingerprint()),
    }
}

/// Render the paper-style strategy-vs-exhaustive artifacts through a
/// [`Report`] sink: the per-leg table and the per-strategy recovery/
/// cost summary.
pub fn render_report(result: &MetaSweepResult, report: &Report) -> Result<()> {
    let mut table = Table::new(
        &format!(
            "Metasweep: {} strategies vs the exhaustive {} sweep, {} repeats, seed {}",
            result.strategies.len(),
            result.space_kind,
            result.repeats,
            result.seed
        ),
        &[
            "strategy",
            "target",
            "configs",
            "spent",
            "evals",
            "best",
            "exh best",
            "recov %",
            "cost %",
            "best hyperparameters",
        ],
    );
    for s in &result.strategies {
        for l in &s.legs {
            table.row(vec![
                l.strategy.clone(),
                if l.target == l.algo || l.algo.is_empty() {
                    l.target.clone()
                } else {
                    format!("{} -> {}", l.target, l.algo)
                },
                l.configs.to_string(),
                format!("{:.1}", l.spent_cost),
                l.evals.to_string(),
                format!("{:+.3}", l.best_score),
                format!("{:+.3}", l.exhaustive_best_score),
                format!("{:.1}", l.improvement_recovered * 100.0),
                format!("{:.1}", l.cost_fraction * 100.0),
                l.best_hp_key.clone(),
            ]);
        }
    }
    report.table(&table)?;
    super::sweep::render_failed_legs(&result.failed_legs, report)?;
    let mut lines = String::new();
    for s in &result.strategies {
        lines.push_str(&format!(
            "{}: recovered {:.1}% of the exhaustive improvement ({:+.1}% of \
             {:+.1}%) at {:.1}% of its meta-evaluations ({:.1} units, {} evals)\n",
            s.strategy,
            s.recovery() * 100.0,
            s.mean_improvement_pct(),
            s.exhaustive_mean_improvement_pct(),
            s.cost_fraction() * 100.0,
            s.spent_cost(),
            s.evals(),
        ));
    }
    lines.push_str(&format!(
        "reference: exhaustive sweep mean improvement {:+.1}%; metasweep took {}\n",
        result.reference_mean_improvement_pct,
        fmt_duration(result.wallclock_seconds)
    ));
    if !result.failed_legs.is_empty() {
        lines.push_str(&format!(
            "{} leg(s) QUARANTINED: partial results\n",
            result.failed_legs.len()
        ));
    }
    report.summary(&lines)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::NullObserver;
    use crate::dataset::bruteforce;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::LiveRunner;
    use crate::runtime::Engine;
    use std::sync::{Mutex, OnceLock};

    /// Full-budget repeats of the shared fixture: 8 gives the halving
    /// ladder [1, 8] a whole-grid cheap rung within the 25% budget.
    const REPEATS: usize = 8;
    const SEED: u64 = 7;

    fn train() -> &'static Vec<SpaceEval> {
        static TRAIN: OnceLock<Vec<SpaceEval>> = OnceLock::new();
        TRAIN.get_or_init(|| {
            let kernel = kernels::kernel_by_name("synthetic").unwrap();
            let mut live = LiveRunner::new(
                kernels::kernel_by_name("synthetic").unwrap(),
                &A100,
                std::sync::Arc::new(Engine::native()),
                NoiseModel::default(),
                42,
            );
            let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
            vec![SpaceEval::new(kernel.space_arc(), cache, 0.95, 10)]
        })
    }

    /// The exhaustive reference every assertion compares against — one
    /// full-registry sweep at the fixture repeats (~300 campaigns).
    fn reference() -> &'static SweepResult {
        static REF: OnceLock<SweepResult> = OnceLock::new();
        REF.get_or_init(|| {
            super::super::sweep::sweep_registry(train(), REPEATS, SEED, Arc::new(NullObserver))
                .unwrap()
        })
    }

    fn config() -> MetaSweepConfig {
        MetaSweepConfig {
            eta: 8,
            ..MetaSweepConfig::default()
        }
    }

    /// One shared metasweep of every registered strategy for the
    /// read-only assertions; the determinism test runs its own second,
    /// fresh metasweep (with a collecting observer) to compare against.
    fn run_metasweep() -> &'static MetaSweepResult {
        static RESULT: OnceLock<MetaSweepResult> = OnceLock::new();
        RESULT.get_or_init(|| {
            metasweep_registry(
                train(),
                REPEATS,
                SEED,
                reference(),
                &config(),
                Arc::new(NullObserver),
            )
            .unwrap()
        })
    }

    /// Event collector: ordering trace plus every fresh meta-evaluation
    /// (strategy, target, hp key, repeats) for the rung-monotonicity
    /// assertion.
    #[derive(Default)]
    struct MetaCollector {
        events: Mutex<Vec<String>>,
        evals: Mutex<Vec<(String, String, String, usize)>>,
    }

    impl Observer for MetaCollector {
        fn meta_sweep_started(&self, strategies: usize, repeats: usize) {
            self.events
                .lock()
                .unwrap()
                .push(format!("sweep_started {strategies} {repeats}"));
        }
        fn meta_leg_started(&self, strategy: &str, target: &str, _c: usize, _b: f64) {
            self.events
                .lock()
                .unwrap()
                .push(format!("leg_started {strategy} {target}"));
        }
        fn meta_eval_scored(
            &self,
            strategy: &str,
            target: &str,
            _eval: usize,
            hp_key: &str,
            repeats: usize,
            _score: f64,
        ) {
            self.events
                .lock()
                .unwrap()
                .push(format!("eval {strategy} {target}"));
            self.evals.lock().unwrap().push((
                strategy.to_string(),
                target.to_string(),
                hp_key.to_string(),
                repeats,
            ));
        }
        fn meta_leg_finished(&self, strategy: &str, target: &str, _b: f64, _s: f64, _e: usize) {
            self.events
                .lock()
                .unwrap()
                .push(format!("leg_finished {strategy} {target}"));
        }
        fn meta_sweep_finished(&self, _wallclock: f64) {
            self.events.lock().unwrap().push("sweep_finished".to_string());
        }
    }

    fn assert_bitwise_equal(a: &MetaSweepResult, b: &MetaSweepResult) {
        assert_eq!(a.repeats, b.repeats);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.eta, b.eta);
        assert_eq!(a.min_repeats, b.min_repeats);
        assert_eq!(a.failed_legs.len(), b.failed_legs.len());
        for (fa, fb) in a.failed_legs.iter().zip(&b.failed_legs) {
            assert_eq!(fa.leg, fb.leg);
            assert_eq!(fa.error, fb.error);
            assert_eq!(fa.attempts, fb.attempts);
        }
        assert_eq!(a.strategies.len(), b.strategies.len());
        for (ra, rb) in a.strategies.iter().zip(&b.strategies) {
            assert_eq!(ra.strategy, rb.strategy);
            assert_legs_bitwise_equal(&ra.legs, &rb.legs);
        }
    }

    /// Every wallclock-independent field of two leg sequences, bitwise.
    fn assert_legs_bitwise_equal(a: &[StrategyLeg], b: &[StrategyLeg]) {
        assert_eq!(a.len(), b.len());
        for (la, lb) in a.iter().zip(b) {
            let tag = format!("{}/{}", la.strategy, la.target);
            assert_eq!(la.target, lb.target, "{tag}");
            assert_eq!(la.algo, lb.algo, "{tag}");
            assert_eq!(la.hp_space_key, lb.hp_space_key, "{tag}");
            assert_eq!(la.configs, lb.configs, "{tag}");
            assert_eq!(la.budget_cost.to_bits(), lb.budget_cost.to_bits(), "{tag}");
            assert_eq!(la.spent_cost.to_bits(), lb.spent_cost.to_bits(), "{tag}");
            assert_eq!(la.evals, lb.evals, "{tag}");
            assert_eq!(la.best_config_idx, lb.best_config_idx, "{tag}");
            assert_eq!(la.best_hp_key, lb.best_hp_key, "{tag}");
            assert_eq!(la.best_score.to_bits(), lb.best_score.to_bits(), "{tag}");
            assert_eq!(
                la.default_score.to_bits(),
                lb.default_score.to_bits(),
                "{tag}"
            );
            assert_eq!(
                la.exhaustive_best_score.to_bits(),
                lb.exhaustive_best_score.to_bits(),
                "{tag}"
            );
            assert_eq!(la.regret.to_bits(), lb.regret.to_bits(), "{tag}");
            assert_eq!(
                la.improvement_recovered.to_bits(),
                lb.improvement_recovered.to_bits(),
                "{tag}"
            );
            assert_eq!(la.cost_fraction.to_bits(), lb.cost_fraction.to_bits(), "{tag}");
        }
    }

    /// Same seed, bitwise-identical envelope — for *every* registered
    /// strategy at once. The second (collected) run doubles as the event
    /// fixture: strict sweep/leg/eval ordering, and halving's rung
    /// monotonicity (no configuration ever re-evaluated at fewer
    /// repeats than a previous rung gave it).
    #[test]
    fn metasweep_is_bitwise_deterministic_and_events_are_ordered() {
        let a = run_metasweep();
        let collector = Arc::new(MetaCollector::default());
        let b = metasweep_registry(
            train(),
            REPEATS,
            SEED,
            reference(),
            &config(),
            Arc::clone(&collector) as Arc<dyn Observer>,
        )
        .unwrap();
        assert_bitwise_equal(a, &b);

        let events = collector.events.lock().unwrap().clone();
        let n_strategies = strategy::strategies().len();
        assert_eq!(events[0], format!("sweep_started {n_strategies} {REPEATS}"));
        assert_eq!(events.last().unwrap(), "sweep_finished");
        // Legs bracket their evals: inside a leg only its own
        // (strategy, target) evaluations may fire.
        let mut open: Option<String> = None;
        for e in &events[1..events.len() - 1] {
            if let Some(rest) = e.strip_prefix("leg_started ") {
                assert!(open.is_none(), "nested leg: {e}");
                open = Some(rest.to_string());
            } else if let Some(rest) = e.strip_prefix("leg_finished ") {
                assert_eq!(open.as_deref(), Some(rest), "unbalanced {e}");
                open = None;
            } else if let Some(rest) = e.strip_prefix("eval ") {
                assert_eq!(open.as_deref(), Some(rest), "stray {e}");
            } else {
                panic!("unexpected event {e}");
            }
        }
        assert!(open.is_none());

        // Halving monotonicity (the behavioral half of the schedule
        // proptest): per (target, hp config), repeats strictly increase
        // across re-evaluations — a survivor is only ever promoted.
        let evals = collector.evals.lock().unwrap().clone();
        let mut last: std::collections::HashMap<(String, String), usize> =
            std::collections::HashMap::new();
        let mut halving_evals = 0usize;
        for (strategy, target, hp_key, repeats) in evals {
            if strategy != "halving" {
                continue;
            }
            halving_evals += 1;
            if let Some(&prev) = last.get(&(target.clone(), hp_key.clone())) {
                assert!(
                    repeats > prev,
                    "halving re-evaluated {target}/{hp_key} at {repeats} <= {prev} repeats"
                );
            }
            last.insert((target, hp_key), repeats);
        }
        assert!(halving_evals > 0);
    }

    /// The acceptance gate: the surrogate (tpe) and racing (halving)
    /// strategies each recover >= 90% of the exhaustive sweep's
    /// best-vs-default improvement at <= 25% of its meta-evaluations.
    #[test]
    fn tpe_and_halving_hit_90pct_recovery_at_quarter_cost() {
        let r = run_metasweep();
        for name in ["tpe", "halving"] {
            let run = r.run(name).unwrap();
            let detail: Vec<String> = run
                .legs
                .iter()
                .map(|l| {
                    format!(
                        "{}: rec {:.3} cost {:.3} (best {:+.4} exh {:+.4} def {:+.4})",
                        l.target,
                        l.improvement_recovered,
                        l.cost_fraction,
                        l.best_score,
                        l.exhaustive_best_score,
                        l.default_score
                    )
                })
                .collect();
            assert!(
                run.recovery() >= 0.90,
                "{name}: recovered only {:.1}% of the exhaustive improvement\n{}",
                run.recovery() * 100.0,
                detail.join("\n")
            );
            assert!(
                run.cost_fraction() <= DEFAULT_BUDGET_FRACTION + 1e-9,
                "{name}: spent {:.1}% of the exhaustive meta-evaluations\n{}",
                run.cost_fraction() * 100.0,
                detail.join("\n")
            );
            assert!(run.evals() > 0, "{name}");
        }
    }

    /// Per-leg invariants, including the bitwise-membership property:
    /// a per-optimizer leg's best score IS an entry of the reference
    /// grid's score array (same campaign, same seed), so regret is
    /// exact and never negative.
    #[test]
    fn legs_are_internally_consistent_and_bitwise_members_of_the_reference() {
        let r = run_metasweep();
        assert_eq!(r.space_kind, "limited");
        assert_eq!(r.repeats, REPEATS);
        assert_eq!(r.train.len(), 1);
        assert!(!r.train[0].space_fingerprint.is_empty());
        let names = strategy::strategy_names();
        assert_eq!(
            r.strategies.iter().map(|s| s.strategy.as_str()).collect::<Vec<_>>(),
            names
        );
        for s in &r.strategies {
            let desc = strategy::strategy_by_name(&s.strategy).unwrap();
            if desc.per_optimizer {
                assert_eq!(
                    s.legs.iter().map(|l| l.target.as_str()).collect::<Vec<_>>(),
                    optimizers::hypertunable_names()
                );
            } else {
                assert_eq!(s.legs.len(), 1);
                assert_eq!(s.legs[0].target, "registry");
                assert!(
                    optimizers::hypertunable_names().contains(&s.legs[0].algo.as_str()),
                    "{}",
                    s.legs[0].algo
                );
                assert_eq!(s.legs[0].configs, reference().total_configs());
            }
            for l in &s.legs {
                let tag = format!("{}/{}", l.strategy, l.target);
                assert!(l.spent_cost <= l.budget_cost + 1e-9, "{tag}: over budget");
                assert!(l.evals > 0, "{tag}");
                assert!(l.best_score.is_finite(), "{tag}");
                assert!(l.regret >= 0.0, "{tag}: beat the exhaustive optimum?");
                assert_eq!(
                    l.regret.to_bits(),
                    (l.exhaustive_best_score - l.best_score).to_bits(),
                    "{tag}"
                );
                assert!(
                    (0.0..=1.0).contains(&l.improvement_recovered),
                    "{tag}: {}",
                    l.improvement_recovered
                );
                let entry = reference().entry(&l.algo).unwrap();
                assert_eq!(l.hp_space_key, entry.space_key, "{tag}");
                // The membership property: full-repeat meta-evaluations
                // reproduce the exhaustive campaigns bitwise.
                assert_eq!(
                    l.best_score.to_bits(),
                    entry.scores[l.best_config_idx].to_bits(),
                    "{tag}: best is not a bitwise member of the reference grid"
                );
                if desc.per_optimizer {
                    assert_eq!(l.algo, l.target, "{tag}");
                    assert_eq!(
                        l.default_score.to_bits(),
                        entry.default_score.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(
                        l.exhaustive_best_score.to_bits(),
                        entry.best_score.to_bits(),
                        "{tag}"
                    );
                    assert_eq!(l.configs, entry.configs, "{tag}");
                }
            }
        }
    }

    /// Resume: a prior envelope produced under identical inputs replays
    /// every leg (sentinel wallclocks survive untouched); a stale prior
    /// (different eta) is ignored and everything re-runs.
    #[test]
    fn resume_replays_matching_legs_and_ignores_stale_priors() {
        let mut prior = run_metasweep().clone();
        for s in &mut prior.strategies {
            for l in &mut s.legs {
                l.wallclock_seconds = 12345.0;
            }
        }
        let resumed = metasweep_registry_with(
            train(),
            REPEATS,
            SEED,
            reference(),
            &config(),
            Some(&prior),
            Arc::new(NullObserver),
        )
        .unwrap();
        assert_bitwise_equal(run_metasweep(), &resumed);
        for s in &resumed.strategies {
            for l in &s.legs {
                assert_eq!(
                    l.wallclock_seconds, 12345.0,
                    "{}/{} was re-run instead of replayed",
                    l.strategy, l.target
                );
            }
        }
        // Same prior under a different eta: determinism inputs changed,
        // so the prior must NOT be replayed. Restrict to the cheapest
        // strategy (random ignores eta) to keep the re-run small.
        let cheap = MetaSweepConfig {
            strategies: vec!["random".into()],
            budget: Some(1.0),
            eta: 5,
            ..config()
        };
        let rerun = metasweep_registry_with(
            train(),
            REPEATS,
            SEED,
            reference(),
            &cheap,
            Some(&prior),
            Arc::new(NullObserver),
        )
        .unwrap();
        for s in &rerun.strategies {
            for l in &s.legs {
                assert_ne!(l.wallclock_seconds, 12345.0, "{}/{}", l.strategy, l.target);
            }
        }
    }

    #[test]
    fn envelope_roundtrips_through_text_and_gz() {
        let r = run_metasweep();
        let text = r.to_json().to_pretty();
        let back = MetaSweepResult::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_bitwise_equal(r, &back);
        assert_eq!(back.space_kind, r.space_kind);
        assert_eq!(back.train[0].label, r.train[0].label);
        assert_eq!(back.train[0].space_fingerprint, r.train[0].space_fingerprint);
        assert_eq!(
            back.reference_mean_improvement_pct.to_bits(),
            r.reference_mean_improvement_pct.to_bits()
        );
        for (bs, rs) in back.strategies.iter().zip(&r.strategies) {
            assert_eq!(bs.recovery().to_bits(), rs.recovery().to_bits());
            assert_eq!(bs.cost_fraction().to_bits(), rs.cost_fraction().to_bits());
        }
        let dir = std::env::temp_dir().join(format!("tt_metasweep_{}", std::process::id()));
        let path = dir.join("metasweep.json.gz");
        r.save(&path).unwrap();
        let loaded = MetaSweepResult::load(&path).unwrap();
        assert_bitwise_equal(r, &loaded);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn envelope_rejects_foreign_and_future_schemas() {
        let mut j = Json::obj();
        j.set("schema", "something-else".into());
        assert!(MetaSweepResult::from_json(&j).is_err());
        let mut j = run_metasweep().to_json();
        j.set("schema_version", 999.0.into());
        assert!(MetaSweepResult::from_json(&j).is_err());
    }

    /// Mismatched or stale references fail typed before any campaign
    /// runs: wrong repeats/seed is an input error, a drifted training
    /// space or hyperparameter grid is a stale cache.
    #[test]
    fn stale_or_mismatched_references_are_typed_errors() {
        let obs: Arc<dyn Observer> = Arc::new(NullObserver);
        let err = metasweep_registry(
            train(),
            REPEATS + 1,
            SEED,
            reference(),
            &config(),
            Arc::clone(&obs),
        )
        .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");
        let err = metasweep_registry(
            train(),
            REPEATS,
            SEED + 1,
            reference(),
            &config(),
            Arc::clone(&obs),
        )
        .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");

        let mut tampered = reference().clone();
        tampered.train[0].space_fingerprint = "stale-fingerprint".into();
        let err =
            metasweep_registry(train(), REPEATS, SEED, &tampered, &config(), Arc::clone(&obs))
                .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err}");

        let mut tampered = reference().clone();
        tampered.optimizers[0].space_key = "stale-grid".into();
        let err =
            metasweep_registry(train(), REPEATS, SEED, &tampered, &config(), Arc::clone(&obs))
                .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err}");

        let mut tampered = reference().clone();
        tampered.optimizers.remove(0);
        let err =
            metasweep_registry(train(), REPEATS, SEED, &tampered, &config(), Arc::clone(&obs))
                .unwrap_err();
        assert!(matches!(err, TuneError::StaleCache(_)), "{err}");

        let bad = MetaSweepConfig {
            strategies: vec!["nope".into()],
            ..config()
        };
        let err = metasweep_registry(train(), REPEATS, SEED, reference(), &bad, Arc::clone(&obs))
            .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");

        let dup = MetaSweepConfig {
            strategies: vec!["random".into(), "random".into()],
            ..config()
        };
        let err = metasweep_registry(train(), REPEATS, SEED, reference(), &dup, Arc::clone(&obs))
            .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");

        let err = metasweep_registry(&[], REPEATS, SEED, reference(), &config(), obs).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err}");
    }

    #[test]
    fn report_renders_table_and_summary() {
        let r = run_metasweep();
        let dir = std::env::temp_dir().join(format!("tt_metasweeprep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let report = Report::new(&dir, "metasweep");
        render_report(r, &report).unwrap();
        let table = std::fs::read_to_string(dir.join("metasweep_table.txt")).unwrap();
        for name in strategy::strategy_names() {
            assert!(table.contains(name), "table missing {name}");
        }
        assert!(table.contains("registry"));
        let summary = std::fs::read_to_string(dir.join("metasweep_summary.txt")).unwrap();
        assert!(summary.contains("recovered"), "{summary}");
        assert!(summary.contains("exhaustive sweep mean improvement"), "{summary}");
        assert!(!summary.contains("QUARANTINED"), "{summary}");
        assert!(!dir.join("metasweep_failures.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- fault tolerance -----------------------------------------------------

    /// Chaos: an always-panicking job quarantines exactly the victim
    /// leg while every other leg completes bitwise clean, and the
    /// quarantine record survives the envelope round-trip.
    #[test]
    fn panicked_leg_is_quarantined_while_other_legs_complete() {
        #[derive(Default)]
        struct FailureCollector(Mutex<Vec<(String, String, usize)>>);
        impl Observer for FailureCollector {
            fn leg_failed(&self, leg: &str, error: &str, attempts: usize) {
                self.0
                    .lock()
                    .unwrap()
                    .push((leg.to_string(), error.to_string(), attempts));
            }
        }
        let victim = optimizers::hypertunable_names()[0];
        let plan = Arc::new(FaultPlan::parse(&format!("panic@{victim}.j0x*")).unwrap());
        let cfg = MetaSweepConfig {
            strategies: vec!["random".into()],
            ..config()
        };
        let collector = Arc::new(FailureCollector::default());
        let r = metasweep_registry_checkpointed(
            train(),
            REPEATS,
            SEED,
            reference(),
            &cfg,
            None,
            None,
            Some(plan),
            Arc::clone(&collector) as Arc<dyn Observer>,
        )
        .unwrap();
        assert_eq!(r.failed_legs.len(), 1);
        let f = &r.failed_legs[0];
        assert_eq!(f.leg, format!("random/{victim}"));
        assert_eq!(f.attempts, 2);
        assert!(f.error.contains("injected fault"), "{}", f.error);
        let events = collector.0.lock().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].0, f.leg);
        // Every surviving leg is bitwise identical to the healthy run:
        // budgets and leg RNG streams depend only on (seed, strategy,
        // leg index), never on what happened to other legs.
        let healthy = run_metasweep().run("random").unwrap();
        let expected: Vec<StrategyLeg> = healthy
            .legs
            .iter()
            .filter(|l| l.target != victim)
            .cloned()
            .collect();
        assert_legs_bitwise_equal(&r.strategies[0].legs, &expected);
        let back =
            MetaSweepResult::from_json(&json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_bitwise_equal(&r, &back);
    }

    /// A transient fault (panics exactly once) is retried into a
    /// bitwise-identical envelope: the retry re-derives the job's RNG
    /// stream, so nothing is quarantined and nothing drifts.
    #[test]
    fn transient_fault_retries_to_bitwise_identical_legs() {
        let victim = optimizers::hypertunable_names()[0];
        let plan = Arc::new(FaultPlan::parse(&format!("panic@{victim}.j0")).unwrap());
        let cfg = MetaSweepConfig {
            strategies: vec!["random".into()],
            ..config()
        };
        let r = metasweep_registry_checkpointed(
            train(),
            REPEATS,
            SEED,
            reference(),
            &cfg,
            None,
            None,
            Some(plan),
            Arc::new(NullObserver),
        )
        .unwrap();
        assert!(r.failed_legs.is_empty());
        assert_legs_bitwise_equal(
            &r.strategies[0].legs,
            &run_metasweep().run("random").unwrap().legs,
        );
    }

    /// The crash-recovery acceptance path: snapshot the incremental
    /// checkpoint mid-metasweep (atomic_write guarantees any instant's
    /// file equals what a SIGKILL would leave behind), then resume a
    /// fresh metasweep from the snapshot. The finished legs replay
    /// without a single fresh meta-evaluation and the merged envelope
    /// is bitwise identical to the uninterrupted run.
    #[test]
    fn killed_metasweep_resumes_bitwise_identical_from_checkpoint() {
        struct Snatcher {
            at: usize,
            src: std::path::PathBuf,
            dst: std::path::PathBuf,
        }
        impl Observer for Snatcher {
            fn checkpoint_saved(&self, _path: &str, completed: usize) {
                if completed == self.at {
                    std::fs::copy(&self.src, &self.dst).unwrap();
                }
            }
        }
        let dir = std::env::temp_dir().join(format!("tt_metackpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cp = Checkpoint::new(dir.join("metasweep.ckpt.json"), 1);
        let snatched = dir.join("killed.json");
        let cfg = MetaSweepConfig {
            strategies: vec!["random".into()],
            ..config()
        };
        let obs = Arc::new(Snatcher {
            at: 2,
            src: cp.path.clone(),
            dst: snatched.clone(),
        });
        let full = metasweep_registry_checkpointed(
            train(),
            REPEATS,
            SEED,
            reference(),
            &cfg,
            None,
            Some(&cp),
            None,
            obs,
        )
        .unwrap();
        // The snapshot is a valid, partial envelope: exactly the legs
        // that had finished when the "kill" hit.
        let prior = MetaSweepResult::load(&snatched).unwrap();
        assert_eq!(prior.strategies.len(), 1);
        assert_eq!(prior.strategies[0].legs.len(), 2);
        // Resume: the finished legs replay (zero fresh evaluations),
        // the lost tail re-runs, and the merge matches bitwise.
        let collector = Arc::new(MetaCollector::default());
        let resumed = metasweep_registry_with(
            train(),
            REPEATS,
            SEED,
            reference(),
            &cfg,
            Some(&prior),
            Arc::clone(&collector) as Arc<dyn Observer>,
        )
        .unwrap();
        assert_bitwise_equal(&full, &resumed);
        let evals = collector.evals.lock().unwrap();
        let replayed: Vec<&str> = prior.strategies[0]
            .legs
            .iter()
            .map(|l| l.target.as_str())
            .collect();
        assert!(
            evals
                .iter()
                .all(|(_, target, _, _)| !replayed.contains(&target.as_str())),
            "a replayed leg re-ran fresh meta-evaluations"
        );
        assert!(!evals.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A corrupt or missing prior envelope is "no prior", not an abort.
    #[test]
    fn load_tolerant_ignores_corrupt_and_missing_envelopes() {
        let dir = std::env::temp_dir().join(format!("tt_metatol_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(MetaSweepResult::load_tolerant(&dir.join("absent.json")).is_none());
        let garbled = dir.join("garbled.json");
        let body = b"{\"schema\": \"tunetuner-metasweep\", \"strateg";
        crate::util::fsio::atomic_write(&garbled, body).unwrap();
        assert!(MetaSweepResult::load_tolerant(&garbled).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- pure-function units -------------------------------------------------

    /// Golden: the registry's actual grid sizes under the default 25%
    /// fraction. Racing budgets are purely proportional; surrogate
    /// budgets keep an 8-eval floor on the tiny grids, paid for by
    /// shaving the large ones — and the total never exceeds the cap.
    #[test]
    fn budget_allocator_respects_floors_and_cap() {
        let grids = [8usize, 108, 81, 81, 9, 9];
        let cap: f64 = grids.iter().map(|&g| g as f64 * DEFAULT_BUDGET_FRACTION).sum();
        let racing = allocate_budgets(&grids, true);
        for (b, &g) in racing.iter().zip(&grids) {
            assert!((b - g as f64 * DEFAULT_BUDGET_FRACTION).abs() < 1e-12);
        }
        let floored = allocate_budgets(&grids, false);
        assert_eq!(floored.len(), grids.len());
        for (b, &g) in floored.iter().zip(&grids) {
            assert!(*b >= (g as f64).min(8.0) - 1e-9, "grid {g}: budget {b}");
            assert!(*b <= g as f64 + 1e-9, "grid {g}: budget {b}");
        }
        let total: f64 = floored.iter().sum();
        assert!(total <= cap + 1e-6, "total {total} > cap {cap}");
        // The tiny grids sit exactly on their floors; the big grids keep
        // more than the floor but less than pure proportionality.
        assert!((floored[0] - 8.0).abs() < 1e-9);
        assert!(floored[1] < 27.0 && floored[1] > 8.0);
    }

    #[test]
    fn leg_recovery_clamps_and_handles_degenerate_legs() {
        assert!((leg_recovery(0.2, 0.3, 0.4) - 0.5).abs() < 1e-12);
        assert!((leg_recovery(0.2, 0.4, 0.4) - 1.0).abs() < 1e-12);
        // Worse than the default clamps to 0, not negative.
        assert_eq!(leg_recovery(0.2, 0.1, 0.4), 0.0);
        // Degenerate: nothing to recover — matching the default is 1.0.
        assert_eq!(leg_recovery(0.2, 0.2, 0.2), 1.0);
        assert_eq!(leg_recovery(0.2, 0.1, 0.2), 0.0);
    }

    #[test]
    fn best_finite_demotes_nan() {
        assert_eq!(best_finite([f64::NAN, 0.3, 0.1].into_iter()), 0.3);
        assert!(best_finite(std::iter::empty()).is_nan());
        assert!(best_finite([f64::NAN].into_iter()).is_nan());
    }
}
