//! Tree-structured Parzen estimator over the mixed float/int/categorical
//! grids.
//!
//! The limited hyperparameter spaces are small Cartesian grids (8–108
//! configurations), so the classic TPE loop simplifies: split the
//! history into good/bad halves by score, estimate per-dimension Parzen
//! weights over the *grid positions* (an ordinal kernel: full weight at
//! an observed value, half weight one grid step away, plus a uniform
//! prior), and pick the unseen configuration maximizing
//! `sum_d log(l_d(v) / g_d(v))` — the expected-improvement proxy —
//! scored over the whole grid rather than a sampled candidate set.
//! Every evaluation runs at full repeats, so the final best is
//! exhaustive-comparable. An epsilon of random exploration guards
//! against a misled surrogate on rugged landscapes.

use super::{sort_scored_desc, MetaCampaign, MetaOutcome, MetaStrategy};
use crate::error::{Result, TuneError};
use crate::optimizers::HyperParams;
use crate::util::rng::Rng;

/// Uniform prior weight added to every grid position of both densities.
const PRIOR: f64 = 0.3;
/// Kernel weight one ordinal step away from an observation.
const NEIGHBOR: f64 = 0.5;
/// Fraction of post-startup proposals drawn uniformly at random.
const EPSILON: f64 = 0.25;

pub struct Tpe;

/// Per-dimension Parzen weights for one half (good or bad) of the
/// history: `w[d][v]` over the grid positions of dimension `d`.
fn parzen_weights(dims: &[usize], members: &[(usize, Vec<u16>)]) -> Vec<Vec<f64>> {
    let mut w: Vec<Vec<f64>> = dims.iter().map(|&k| vec![PRIOR; k]).collect();
    for (_, enc) in members {
        for (d, &v) in enc.iter().enumerate() {
            let v = v as usize;
            w[d][v] += 1.0;
            if v > 0 {
                w[d][v - 1] += NEIGHBOR;
            }
            if v + 1 < dims[d] {
                w[d][v + 1] += NEIGHBOR;
            }
        }
    }
    for wd in &mut w {
        let total: f64 = wd.iter().sum();
        for x in wd.iter_mut() {
            *x /= total;
        }
    }
    w
}

impl MetaStrategy for Tpe {
    fn run(&self, mc: &mut MetaCampaign, rng: &mut Rng) -> Result<MetaOutcome> {
        let space = mc
            .hp_space
            .clone()
            .ok_or_else(|| TuneError::InvalidInput("tpe needs an hp space".into()))?;
        let n = space.len();
        let dims: Vec<usize> = space.dims().to_vec();
        let full = mc.full_repeats;
        let budget_evals = (mc.remaining() + 1e-9).floor() as usize;
        if budget_evals == 0 {
            return Err(TuneError::InvalidInput(format!(
                "tpe budget {} cannot afford one full-repeat evaluation",
                mc.budget.max_cost
            )));
        }
        let n_startup = (budget_evals / 4).clamp(2, 16).min(n);
        let mut seen = vec![false; n];
        // History as (config, score): digit encodings for the Parzen
        // weights are looked up from the space on demand.
        let mut history: Vec<(usize, f64)> = Vec::new();
        let mut random_unseen = |seen: &[bool], rng: &mut Rng| -> Option<usize> {
            let unseen = n - seen.iter().filter(|&&s| s).count();
            if unseen == 0 {
                return None;
            }
            let mut pick = rng.below(unseen);
            for (idx, &s) in seen.iter().enumerate() {
                if !s {
                    if pick == 0 {
                        return Some(idx);
                    }
                    pick -= 1;
                }
            }
            None
        };
        while mc.affords(full) {
            let cfg = if history.len() < n_startup || rng.chance(EPSILON) {
                match random_unseen(&seen, rng) {
                    Some(c) => c,
                    None => break, // whole grid evaluated
                }
            } else {
                // Good half: top quarter (at least 2); bad half: the rest.
                let mut ranked = history.clone();
                sort_scored_desc(&mut ranked);
                let split = (ranked.len() / 4).max(2).min(ranked.len() - 1);
                let member = |pairs: &[(usize, f64)]| -> Vec<(usize, Vec<u16>)> {
                    pairs
                        .iter()
                        .map(|&(c, _)| (c, space.encoded_vec(c)))
                        .collect()
                };
                let good = parzen_weights(&dims, &member(&ranked[..split]));
                let bad = parzen_weights(&dims, &member(&ranked[split..]));
                // Argmax of the acquisition over every unseen config —
                // the grids are small enough to score exhaustively.
                let mut best: Option<(usize, f64)> = None;
                for idx in 0..n {
                    if seen[idx] {
                        continue;
                    }
                    let mut acq = 0.0;
                    for (d, &k) in dims.iter().enumerate() {
                        let v = space.digit(idx, d) as usize;
                        debug_assert!(v < k);
                        acq += (good[d][v] / bad[d][v]).ln();
                    }
                    let better = match best {
                        Some((_, b)) => acq > b,
                        None => true,
                    };
                    if better {
                        best = Some((idx, acq));
                    }
                }
                match best {
                    Some((idx, _)) => idx,
                    None => break,
                }
            };
            let Some(score) = mc.evaluate(cfg, full)? else {
                break;
            };
            seen[cfg] = true;
            history.push((cfg, score));
        }
        let mut ranked = history.clone();
        if ranked.is_empty() {
            return Err(TuneError::InvalidInput(format!(
                "tpe budget {} cannot afford one full-repeat evaluation",
                mc.budget.max_cost
            )));
        }
        sort_scored_desc(&mut ranked);
        let (best_config_idx, best_score) = ranked[0];
        Ok(MetaOutcome {
            algo: mc.algo.clone(),
            best_config_idx,
            best_hp_key: HyperParams::from_space_config(&space, best_config_idx).key(),
            best_score,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parzen_weights_normalize_and_smooth_neighbors() {
        let dims = vec![4usize, 2];
        let members = vec![(0usize, vec![1u16, 0u16])];
        let w = parzen_weights(&dims, &members);
        for wd in &w {
            let sum: f64 = wd.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
        // Dim 0: observation at 1 -> heaviest there, neighbors 0 and 2
        // share the kernel tail, position 3 keeps only the prior.
        assert!(w[0][1] > w[0][0]);
        assert!(w[0][0] > w[0][3]);
        assert!((w[0][0] - w[0][2]).abs() < 1e-12);
        // Dim 1: observation at 0 of 2 -> both positions touched, 0 heavier.
        assert!(w[1][0] > w[1][1]);
    }
}
