//! Meta-strategies: searching a hyperparameter space *without*
//! enumerating it.
//!
//! The exhaustive sweep ([`super::sweep`]) scores every configuration of
//! every limited grid — the golden reference, but also the cost ceiling.
//! This module is the paper's answer to that ceiling: a [`MetaStrategy`]
//! proposes hyperparameter configurations, receives Eq. 3 methodology
//! scores from replayed campaigns, and spends a [`MetaBudget`] measured
//! in *full-repeat-equivalent evaluations* — the unit in which the
//! exhaustive sweep costs exactly `grid_size`.
//!
//! * [`MetaCampaign`] — the evaluation substrate: one memoized,
//!   budget-charged entry point that turns (algorithm, hyperparameters,
//!   repeats) into a [`Campaign`](crate::campaign::Campaign) on the
//!   shared training [`SpaceEval`]s (and with them the Arc-shared
//!   SimTable caches on the persistent executor pool). A full-repeat
//!   evaluation reproduces the exhaustive sweep's score for the same
//!   configuration *bitwise* — both run the identical campaign — so a
//!   meta-strategy's best is always a member of the exhaustive score
//!   array and regret-vs-optimum is exact, not estimated.
//! * [`strategies`] — the self-describing registry, mirroring
//!   [`crate::optimizers::registry`]: `random` (baseline), `tpe`
//!   (tree-structured Parzen surrogate), `halving` (successive-halving
//!   racing over cheap low-repeat rungs), `portfolio` (bandit race over
//!   the whole optimizer registry).
//!
//! Determinism: every strategy draws from an [`Rng`] derived as
//! `mix64(sweep_seed, descriptor.tag)` forked per leg, and evaluation
//! scores come from seeded campaigns — same seed in, bitwise-identical
//! envelope out (pinned by the metasweep tests).

use crate::campaign::{Campaign, Observer};
use crate::error::{Result, TuneError};
use crate::methodology::SpaceEval;
use crate::optimizers::HyperParams;
use crate::searchspace::SearchSpace;
use crate::util::hash::FastMap;
use crate::util::rng::Rng;
use std::sync::Arc;

pub mod halving;
pub mod portfolio;
pub mod random;
pub mod tpe;

pub use halving::{halving_schedule, Rung};

/// Budget of one meta-strategy leg, in full-repeat-equivalent
/// evaluations: an evaluation at `r` repeats costs `r / full_repeats`
/// units, so the exhaustive grid costs exactly `grid_size` units and a
/// budget of `0.25 * grid_size` is "25% of the exhaustive sweep".
#[derive(Clone, Copy, Debug)]
pub struct MetaBudget {
    /// Hard cost ceiling; [`MetaCampaign::evaluate`] refuses (returns
    /// `Ok(None)`) any fresh evaluation that would exceed it.
    pub max_cost: f64,
    /// Optional wall-clock ceiling in seconds. `None` (the default
    /// everywhere determinism matters) never cuts a leg short — a
    /// wall-clock cut would make envelopes machine-dependent.
    pub max_wallclock: Option<f64>,
    /// Rung growth factor of the racing schedule (successive halving
    /// keeps the top `1/eta` per rung and multiplies repeats by `eta`).
    pub eta: usize,
    /// Repeats of the cheapest rung.
    pub min_repeats: usize,
}

impl MetaBudget {
    pub fn new(max_cost: f64) -> MetaBudget {
        MetaBudget {
            max_cost,
            max_wallclock: None,
            eta: 4,
            min_repeats: 1,
        }
    }
}

/// What a strategy found: the best configuration it evaluated *at full
/// repeats* (so the score is exhaustive-comparable).
#[derive(Clone, Debug)]
pub struct MetaOutcome {
    /// Optimizer the best configuration belongs to (differs from the
    /// leg's primary algorithm only for registry-wide strategies).
    pub algo: String,
    /// Index in that optimizer's limited hyperparameter space.
    pub best_config_idx: usize,
    pub best_hp_key: String,
    pub best_score: f64,
}

/// The evaluation substrate handed to a [`MetaStrategy`]: memoized,
/// budget-charged campaign evaluations over the shared training spaces.
pub struct MetaCampaign {
    /// Primary optimizer of this leg (`""` for registry-wide legs).
    pub algo: String,
    /// The hyperparameter space being searched (`None` for registry-wide
    /// legs, which derive spaces themselves).
    pub hp_space: Option<Arc<SearchSpace>>,
    pub train: Arc<Vec<SpaceEval>>,
    /// Repeats of a full-budget evaluation — the exhaustive sweep's
    /// repeat count, and the denominator of the cost unit.
    pub full_repeats: usize,
    pub seed: u64,
    pub budget: MetaBudget,
    observer: Arc<dyn Observer>,
    strategy: String,
    target: String,
    spent: f64,
    evals: usize,
    started: std::time::Instant,
    memo: FastMap<(String, String, usize), f64>,
    /// Explicit fault plan threaded into every campaign this
    /// meta-campaign launches (chaos testing); `None` everywhere else.
    faults: Option<Arc<crate::faults::FaultPlan>>,
}

impl MetaCampaign {
    pub fn new(
        algo: &str,
        hp_space: Option<Arc<SearchSpace>>,
        train: Arc<Vec<SpaceEval>>,
        full_repeats: usize,
        seed: u64,
        budget: MetaBudget,
        observer: Arc<dyn Observer>,
        strategy: &str,
        target: &str,
    ) -> Result<MetaCampaign> {
        if train.is_empty() {
            return Err(TuneError::InvalidInput(
                "meta-campaign has no training spaces".into(),
            ));
        }
        if full_repeats == 0 {
            return Err(TuneError::InvalidInput(
                "meta-campaign needs full_repeats >= 1".into(),
            ));
        }
        Ok(MetaCampaign {
            algo: algo.to_string(),
            hp_space,
            train,
            full_repeats,
            seed,
            budget,
            observer,
            strategy: strategy.to_string(),
            target: target.to_string(),
            spent: 0.0,
            evals: 0,
            // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
            started: std::time::Instant::now(),
            memo: FastMap::default(),
            faults: None,
        })
    }

    /// Inject a deterministic [`FaultPlan`](crate::faults::FaultPlan)
    /// into every campaign this meta-campaign launches. Faults corrupt
    /// individual tuning jobs, not the meta-level bookkeeping, so a
    /// plan that never fires leaves the envelope bitwise unchanged.
    pub fn set_faults(&mut self, faults: Option<Arc<crate::faults::FaultPlan>>) {
        self.faults = faults;
    }

    /// Cost already charged, in full-repeat-equivalent evaluations.
    pub fn spent(&self) -> f64 {
        self.spent
    }

    /// Budget still available.
    pub fn remaining(&self) -> f64 {
        (self.budget.max_cost - self.spent).max(0.0)
    }

    /// Fresh (non-memoized) evaluations performed so far.
    pub fn evals(&self) -> usize {
        self.evals
    }

    fn cost_of(&self, repeats: usize) -> f64 {
        repeats as f64 / self.full_repeats as f64
    }

    /// Whether a fresh evaluation at `repeats` fits the remaining budget.
    pub fn affords(&self, repeats: usize) -> bool {
        if let Some(limit) = self.budget.max_wallclock {
            if self.started.elapsed().as_secs_f64() > limit {
                return false;
            }
        }
        self.spent + self.cost_of(repeats) <= self.budget.max_cost + 1e-9
    }

    /// Evaluate configuration `config_idx` of the leg's own
    /// hyperparameter space at `repeats` repeats. Returns `Ok(None)` when
    /// the budget cannot afford the evaluation (strategies treat that as
    /// "stop"); memoized repeats are free and always served.
    pub fn evaluate(&mut self, config_idx: usize, repeats: usize) -> Result<Option<f64>> {
        let Some(space) = self.hp_space.clone() else {
            return Err(TuneError::InvalidInput(format!(
                "meta-campaign for {:?} has no hyperparameter space",
                self.target
            )));
        };
        let algo = self.algo.clone();
        let hp = HyperParams::from_space_config(&space, config_idx);
        self.evaluate_in(&algo, &hp, repeats)
    }

    /// Evaluate `algo` with its schema defaults (registry-wide racing).
    pub fn evaluate_default(&mut self, algo: &str, repeats: usize) -> Result<Option<f64>> {
        self.evaluate_in(algo, &HyperParams::new(), repeats)
    }

    /// Evaluate an explicit (algorithm, hyperparameters) pair. The memo
    /// key is `(algo, hp.key(), repeats)` — a rung promotion to higher
    /// repeats is a fresh charge, a re-proposal at the same repeats is
    /// free.
    pub fn evaluate_in(
        &mut self,
        algo: &str,
        hp: &HyperParams,
        repeats: usize,
    ) -> Result<Option<f64>> {
        if repeats == 0 || repeats > self.full_repeats {
            return Err(TuneError::InvalidInput(format!(
                "meta-evaluation at {repeats} repeats outside 1..={}",
                self.full_repeats
            )));
        }
        let key = (algo.to_string(), hp.key(), repeats);
        if let Some(&score) = self.memo.get(&key) {
            return Ok(Some(score));
        }
        if !self.affords(repeats) {
            return Ok(None);
        }
        // Same constructor chain as the exhaustive grid's per-config
        // campaigns: at full repeats the score matches the sweep bitwise.
        let result = Campaign::new(algo)
            .hyperparams(hp.clone())
            .spaces_arc(Arc::clone(&self.train))
            .repeats(repeats)
            .seed(self.seed)
            .observer(Arc::clone(&self.observer))
            .faults(self.faults.clone())
            .run()?;
        let score = result.score();
        self.spent += self.cost_of(repeats);
        self.evals += 1;
        self.observer.meta_eval_scored(
            &self.strategy,
            &self.target,
            self.evals,
            &result.hp_key,
            repeats,
            score,
        );
        self.memo.insert(key, score);
        Ok(Some(score))
    }
}

/// A meta-strategy: searches a hyperparameter space through a
/// [`MetaCampaign`], returning the best full-repeat configuration found.
/// Implementations must be deterministic given (`mc` state, `rng`).
pub trait MetaStrategy: Send + Sync {
    fn run(&self, mc: &mut MetaCampaign, rng: &mut Rng) -> Result<MetaOutcome>;
}

/// A registered meta-strategy: name, one-line summary, and shape flags
/// the sweep driver uses for budget allocation.
pub struct StrategyDescriptor {
    pub name: &'static str,
    pub summary: &'static str,
    /// Stable RNG tag: the per-strategy stream is
    /// `Rng::new(mix64(seed, tag))`. Never reuse or renumber — envelopes
    /// are pinned bitwise against it.
    pub tag: u64,
    /// `true`: one leg per grid-bearing optimizer (random/tpe/halving).
    /// `false`: a single registry-wide leg (portfolio).
    pub per_optimizer: bool,
    /// `true` for racing strategies whose evaluations are mostly cheap
    /// low-repeat rungs: their budget scales purely with grid size. Full-
    /// repeat strategies instead get a small-grid floor (see
    /// [`super::metasweep`]'s allocator).
    pub racing: bool,
    pub build: fn() -> Box<dyn MetaStrategy>,
}

/// The meta-strategy registry, in presentation order. Like
/// [`crate::optimizers::registry`] this is the single registration
/// point: [`strategy_names`], [`strategy_by_name`], the metasweep driver
/// and `tunetuner metasweep --strategy` all follow it.
pub fn strategies() -> &'static [StrategyDescriptor] {
    &[
        StrategyDescriptor {
            name: "random",
            summary: "uniform random search at full repeats (baseline)",
            tag: 1,
            per_optimizer: true,
            racing: false,
            build: || Box::new(random::RandomSearch),
        },
        StrategyDescriptor {
            name: "tpe",
            summary: "tree-structured Parzen surrogate over the mixed grids",
            tag: 2,
            per_optimizer: true,
            racing: false,
            build: || Box::new(tpe::Tpe),
        },
        StrategyDescriptor {
            name: "halving",
            summary: "successive-halving racing over low-repeat replay rungs",
            tag: 3,
            per_optimizer: true,
            racing: true,
            build: || Box::new(halving::Halving),
        },
        StrategyDescriptor {
            name: "portfolio",
            summary: "races every registry optimizer, then tunes the winner",
            tag: 4,
            per_optimizer: false,
            racing: true,
            build: || Box::new(portfolio::Portfolio),
        },
    ]
}

pub fn strategy_names() -> Vec<&'static str> {
    strategies().iter().map(|s| s.name).collect()
}

pub fn strategy_by_name(name: &str) -> Result<&'static StrategyDescriptor> {
    strategies().iter().find(|s| s.name == name).ok_or_else(|| {
        TuneError::InvalidInput(format!(
            "unknown meta-strategy {name:?}; registered: {}",
            strategy_names().join(", ")
        ))
    })
}

/// NaN-safe descending sort of `(config, score)` pairs: finite scores
/// first (higher better), NaN demoted, config index as the deterministic
/// tiebreak. Shared by the racing strategies' promotion steps.
pub(crate) fn sort_scored_desc(scored: &mut [(usize, f64)]) {
    scored.sort_by(|a, b| {
        let an = a.1.is_nan();
        let bn = b.1.is_nan();
        match (an, bn) {
            (true, true) => a.0.cmp(&b.0),
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            (false, false) => b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)),
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_self_consistent() {
        let names = strategy_names();
        assert_eq!(names, vec!["random", "tpe", "halving", "portfolio"]);
        for d in strategies() {
            assert!(!d.summary.is_empty(), "{}", d.name);
            assert!(strategy_by_name(d.name).unwrap().tag == d.tag);
            // Tags are the seed derivation — they must stay unique.
            assert_eq!(
                strategies().iter().filter(|o| o.tag == d.tag).count(),
                1,
                "{}: duplicate tag",
                d.name
            );
            let _ = (d.build)();
        }
        assert!(strategy_by_name("nope").is_err());
    }

    #[test]
    fn sort_scored_demotes_nan_and_breaks_ties_by_index() {
        let mut v = vec![
            (3, f64::NAN),
            (2, 0.5),
            (0, 0.7),
            (4, 0.5),
            (1, f64::NAN),
        ];
        sort_scored_desc(&mut v);
        let order: Vec<usize> = v.iter().map(|x| x.0).collect();
        assert_eq!(order, vec![0, 2, 4, 1, 3]);
    }
}
