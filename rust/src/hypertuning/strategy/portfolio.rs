//! Bandit-style portfolio scheduler: race the whole optimizer registry,
//! then spend the remaining budget tuning the winner.
//!
//! The cheap first slice of "Automated Algorithm Design for Auto-Tuning
//! Optimizers" (PAPERS.md, arXiv 2510.17899): instead of treating the
//! optimizer as fixed and tuning its hyperparameters, treat the
//! *optimizer choice itself* as the first decision. Phase 1 races every
//! grid-bearing optimizer at its schema defaults through a
//! successive-halving ladder of repeat counts; phase 2 random-searches
//! the winner's limited grid at full repeats, so the reported best is
//! exhaustive-comparable against the whole sweep's optimum.

use super::{sort_scored_desc, MetaCampaign, MetaOutcome, MetaStrategy};
use crate::error::{Result, TuneError};
use crate::hypertuning::space;
use crate::optimizers::{self, HyperParams};
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct Portfolio;

impl MetaStrategy for Portfolio {
    fn run(&self, mc: &mut MetaCampaign, rng: &mut Rng) -> Result<MetaOutcome> {
        let full = mc.full_repeats;
        let eta = mc.budget.eta.max(2);
        let names: Vec<&'static str> = optimizers::hypertunable_names();
        // Phase 1: successive-halving race over schema defaults. Pool
        // entries are (registry index, name); the index doubles as the
        // deterministic tiebreak.
        let mut pool: Vec<(usize, &'static str)> = names.iter().copied().enumerate().collect();
        let mut repeats = mc.budget.min_repeats.clamp(1, full);
        'race: while pool.len() > 1 {
            let mut scored: Vec<(usize, f64)> = Vec::with_capacity(pool.len());
            for &(i, algo) in &pool {
                match mc.evaluate_default(algo, repeats)? {
                    Some(score) => scored.push((i, score)),
                    None => break 'race, // budget gone: rank what we have
                }
            }
            sort_scored_desc(&mut scored);
            let keep = if repeats >= full {
                1
            } else {
                (scored.len() + eta - 1) / eta
            };
            pool = scored
                .iter()
                .take(keep.max(1))
                .map(|&(i, _)| (i, names[i]))
                .collect();
            repeats = (repeats * eta).min(full);
        }
        let Some(&(_, winner)) = pool.first() else {
            return Err(TuneError::InvalidInput(
                "portfolio race eliminated every optimizer".into(),
            ));
        };
        // Phase 2: random search of the winner's limited grid at full
        // repeats with everything left in the budget.
        let hp_space = Arc::new(space::limited_space(winner)?);
        let n = hp_space.len();
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut scored: Vec<(usize, f64)> = Vec::new();
        for cfg in order {
            if !mc.affords(full) {
                break;
            }
            let hp = HyperParams::from_space_config(&hp_space, cfg);
            match mc.evaluate_in(winner, &hp, full)? {
                Some(score) => scored.push((cfg, score)),
                None => break,
            }
        }
        if scored.is_empty() {
            return Err(TuneError::InvalidInput(format!(
                "portfolio budget {} spent before tuning winner {winner:?}",
                mc.budget.max_cost
            )));
        }
        sort_scored_desc(&mut scored);
        let (best_config_idx, best_score) = scored[0];
        Ok(MetaOutcome {
            algo: winner.to_string(),
            best_config_idx,
            best_hp_key: HyperParams::from_space_config(&hp_space, best_config_idx).key(),
            best_score,
        })
    }
}
