//! Uniform random search at full repeats — the baseline every other
//! strategy must beat (the paper's random-sampling reference).

use super::{sort_scored_desc, MetaCampaign, MetaOutcome, MetaStrategy};
use crate::error::{Result, TuneError};
use crate::optimizers::HyperParams;
use crate::util::rng::Rng;

pub struct RandomSearch;

impl MetaStrategy for RandomSearch {
    fn run(&self, mc: &mut MetaCampaign, rng: &mut Rng) -> Result<MetaOutcome> {
        let space = mc
            .hp_space
            .clone()
            .ok_or_else(|| TuneError::InvalidInput("random search needs an hp space".into()))?;
        let n = space.len();
        let full = mc.full_repeats;
        // Sample without replacement: a repeated proposal would be served
        // from the memo for free and waste nothing, but distinct draws
        // maximize coverage per unit budget.
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut scored: Vec<(usize, f64)> = Vec::new();
        for cfg in order {
            if !mc.affords(full) {
                break;
            }
            match mc.evaluate(cfg, full)? {
                Some(score) => scored.push((cfg, score)),
                None => break,
            }
        }
        if scored.is_empty() {
            return Err(TuneError::InvalidInput(format!(
                "random search budget {} cannot afford one full-repeat evaluation",
                mc.budget.max_cost
            )));
        }
        sort_scored_desc(&mut scored);
        let (best_config_idx, best_score) = scored[0];
        Ok(MetaOutcome {
            algo: mc.algo.clone(),
            best_config_idx,
            // Same rendering the exhaustive results carry (stable
            // HyperParams key, not the space's positional key).
            best_hp_key: HyperParams::from_space_config(&space, best_config_idx).key(),
            best_score,
        })
    }
}
