//! Successive-halving / Hyperband racing over low-repeat replay rungs.
//!
//! The simulator makes a 1-repeat campaign nearly free, so the cheapest
//! rung can afford to score the *entire* grid: rung 0 runs every sampled
//! configuration at `min_repeats`, each subsequent rung keeps the top
//! `1/eta` and multiplies repeats by `eta`, and the final rung always
//! runs at `full_repeats` — so the winner's score is bitwise-comparable
//! to the exhaustive sweep. The schedule itself is a pure function
//! ([`halving_schedule`]), pinned by a golden test and a repeat-
//! monotonicity proptest.

use super::{sort_scored_desc, MetaCampaign, MetaOutcome, MetaStrategy};
use crate::error::{Result, TuneError};
use crate::optimizers::HyperParams;
use crate::util::rng::Rng;

/// One racing rung: how many configurations survive into it and at how
/// many repeats each is (re)evaluated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rung {
    pub n: usize,
    pub repeats: usize,
}

/// Compute the successive-halving schedule for a grid of `grid`
/// configurations under `budget_cost` full-repeat-equivalent units.
///
/// The repeat ladder starts at `min_repeats` and multiplies by `eta`
/// until it reaches `full_repeats` (always included, so the last rung is
/// exhaustive-comparable). The starting cohort is the largest `n0 <=
/// grid` whose total cost — `sum_i max(1, n0 / eta^i) * r_i /
/// full_repeats` — fits the budget; survivors shrink by `eta` per rung.
/// Degenerate budgets still yield a schedule with `n0 = 1` (one config
/// raced up the ladder), so callers never receive an empty plan.
pub fn halving_schedule(
    grid: usize,
    full_repeats: usize,
    budget_cost: f64,
    eta: usize,
    min_repeats: usize,
) -> Vec<Rung> {
    let grid = grid.max(1);
    let full = full_repeats.max(1);
    let eta = eta.max(2);
    let min_r = min_repeats.clamp(1, full);
    // Repeat ladder: min_r, min_r*eta, ... capped at (and ending with) full.
    let mut ladder = Vec::new();
    let mut r = min_r;
    loop {
        ladder.push(r);
        if r >= full {
            break;
        }
        r = (r * eta).min(full);
    }
    let cohort = |n0: usize, i: usize| -> usize {
        let mut n = n0;
        for _ in 0..i {
            n /= eta;
        }
        n.max(1)
    };
    let cost = |n0: usize| -> f64 {
        ladder
            .iter()
            .enumerate()
            .map(|(i, &r)| cohort(n0, i) as f64 * r as f64 / full as f64)
            .sum()
    };
    // Largest affordable starting cohort (monotone in n0 -> binary search).
    let (mut lo, mut hi) = (1usize, grid);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if cost(mid) <= budget_cost + 1e-9 {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    ladder
        .iter()
        .enumerate()
        .map(|(i, &r)| Rung {
            n: cohort(lo, i),
            repeats: r,
        })
        .collect()
}

pub struct Halving;

impl MetaStrategy for Halving {
    fn run(&self, mc: &mut MetaCampaign, rng: &mut Rng) -> Result<MetaOutcome> {
        let space = mc
            .hp_space
            .clone()
            .ok_or_else(|| TuneError::InvalidInput("halving needs an hp space".into()))?;
        let n = space.len();
        let schedule = halving_schedule(
            n,
            mc.full_repeats,
            mc.remaining(),
            mc.budget.eta,
            mc.budget.min_repeats,
        );
        // Starting cohort: the whole grid when affordable, else a uniform
        // sample without replacement.
        let mut pool: Vec<usize> = if schedule[0].n >= n {
            (0..n).collect()
        } else {
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            order.truncate(schedule[0].n);
            order.sort_unstable();
            order
        };
        let mut best_full: Option<(usize, f64)> = None;
        'rungs: for rung in &schedule {
            pool.truncate(rung.n);
            let mut scored: Vec<(usize, f64)> = Vec::with_capacity(pool.len());
            for &cfg in &pool {
                match mc.evaluate(cfg, rung.repeats)? {
                    Some(score) => scored.push((cfg, score)),
                    // Budget exhausted mid-rung (only possible when the
                    // leg started with part of its budget already spent):
                    // race ends with the best full-repeat result so far.
                    None => break 'rungs,
                }
            }
            sort_scored_desc(&mut scored);
            if rung.repeats == mc.full_repeats {
                if let Some(&(cfg, score)) = scored.first() {
                    let better = match best_full {
                        Some((bc, bs)) => {
                            score > bs || (score == bs && cfg < bc) || bs.is_nan()
                        }
                        None => true,
                    };
                    if better {
                        best_full = Some((cfg, score));
                    }
                }
            }
            pool = scored.into_iter().map(|(cfg, _)| cfg).collect();
        }
        let Some((best_config_idx, best_score)) = best_full else {
            return Err(TuneError::InvalidInput(format!(
                "halving budget {} never reached a full-repeat rung",
                mc.budget.max_cost
            )));
        };
        Ok(MetaOutcome {
            algo: mc.algo.clone(),
            best_config_idx,
            best_hp_key: HyperParams::from_space_config(&space, best_config_idx).key(),
            best_score,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden: the exact rung/promotion schedule for the acceptance-test
    /// shape — a 108-config grid (GA's Table III), 8 full repeats, a 25%
    /// budget (27 units) and eta 8: the whole grid at 1 repeat, then the
    /// top 13 at the full 8.
    #[test]
    fn golden_schedule_ga_quarter_budget() {
        assert_eq!(
            halving_schedule(108, 8, 27.0, 8, 1),
            vec![Rung { n: 108, repeats: 1 }, Rung { n: 13, repeats: 8 }]
        );
        // cost: 108 * 1/8 + 13 * 8/8 = 26.5 <= 27.
    }

    /// Golden: the multi-rung Hyperband shape — 81 configs, 16 full
    /// repeats, eta 4 gives the [1, 4, 16] ladder with 4x shrinkage.
    #[test]
    fn golden_schedule_multi_rung() {
        assert_eq!(
            halving_schedule(81, 16, 20.0, 4, 1),
            vec![
                Rung { n: 81, repeats: 1 },
                Rung { n: 20, repeats: 4 },
                Rung { n: 5, repeats: 16 },
            ]
        );
    }

    #[test]
    fn schedule_always_ends_at_full_repeats() {
        for &(grid, full, budget, eta, min_r) in &[
            (8usize, 8usize, 2.0f64, 8usize, 1usize),
            (108, 8, 27.0, 8, 1),
            (81, 16, 20.0, 4, 1),
            (9, 4, 0.1, 2, 1), // degenerate budget: n0 = 1
            (300, 25, 75.0, 3, 2),
        ] {
            let s = halving_schedule(grid, full, budget, eta, min_r);
            assert!(!s.is_empty());
            assert_eq!(s.last().unwrap().repeats, full, "{s:?}");
            assert!(s[0].n <= grid, "{s:?}");
            for w in s.windows(2) {
                assert!(w[1].repeats > w[0].repeats, "{s:?}");
                assert!(w[1].n <= w[0].n, "{s:?}");
            }
        }
    }

    #[test]
    fn schedule_cost_fits_budget_or_is_minimal() {
        let cost = |s: &[Rung], full: usize| -> f64 {
            s.iter().map(|r| r.n as f64 * r.repeats as f64 / full as f64).sum()
        };
        let s = halving_schedule(108, 8, 27.0, 8, 1);
        assert!(cost(&s, 8) <= 27.0 + 1e-9);
        // A budget below even the minimal ladder still yields the n0=1
        // plan rather than an empty schedule.
        let s = halving_schedule(50, 4, 0.01, 2, 1);
        assert!(s.iter().all(|r| r.n == 1), "{s:?}");
    }
}
