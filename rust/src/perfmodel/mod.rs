//! The simulated device performance model.
//!
//! * [`contract`] — the Rust mirror of `python/compile/contract.py`: the
//!   feature/device vector layout shared with the L1 Pallas kernel.
//! * [`analytical`] — the model itself in Rust f32: the test oracle for
//!   the AOT HLO artifacts, and the `native` backend when PJRT is not
//!   wanted (e.g. unit tests, CI without artifacts).
//! * [`noise`] — the measurement-noise model: deterministic heteroscedastic
//!   observation noise seeded per (space, config, repeat).

pub mod contract;
pub mod analytical;
pub mod noise;

pub use analytical::{predict_time, Features};
pub use noise::NoiseModel;
