//! The device model in Rust f32 — the oracle for the AOT HLO artifacts.
//!
//! The arithmetic here must stay in lockstep with
//! `python/compile/kernels/ref.py` (jnp oracle) and
//! `python/compile/kernels/perfmodel.py` (Pallas). All three use the same
//! f32 operation sequence, so they agree to ~1 ulp; the integration test
//! `runtime_matches_oracle` asserts it against the PJRT execution.

use super::contract::*;

/// Per-configuration feature vector (see contract for the layout).
pub type Features = [f32; NUM_FEATURES];

/// Evaluate the device model for one configuration. Mirrors
/// `ref.predict_times` row-wise.
pub fn predict_time(f: &Features, d: &[f32; NUM_DEVICE]) -> f32 {
    let flops = f[F_FLOPS];
    let bytes_rw = f[F_BYTES];
    let tpb = f[F_TPB];
    let regs = f[F_REGS];
    let smem = f[F_SMEM];
    let blocks = f[F_BLOCKS];
    let vecw = f[F_VECW];
    let unroll = f[F_UNROLL];
    let coal = f[F_COAL];
    let cache = f[F_CACHE];
    let hash_a = f[F_HASH_A];
    let hash_b = f[F_HASH_B];

    let num_sm = d[D_NUM_SM];
    let peak = d[D_PEAK_GFLOPS] * 1.0e9;
    let bandwidth = d[D_BW_GBS] * 1.0e9;
    let max_threads = d[D_MAX_THREADS];
    let smem_sm = d[D_SMEM_SM];
    let regs_sm = d[D_REGS_SM];
    let max_blocks = d[D_MAX_BLOCKS];
    let warp = d[D_WARP];
    let rug_seed = d[D_RUG_SEED];
    let rug_amp = d[D_RUG_AMP];

    // Occupancy: resident blocks per SM under each resource limit.
    let occ_threads = (max_threads / tpb.max(1.0)).floor();
    let occ_smem = (smem_sm / smem.max(1.0)).floor();
    let occ_regs = (regs_sm / (regs * tpb).max(1.0)).floor();
    let occ_blocks = occ_threads.min(occ_smem).min(occ_regs.min(max_blocks));

    let warp_ok = (tpb / warp).floor() * warp == tpb;
    let valid = occ_blocks >= 1.0 && tpb <= MAX_TPB && tpb >= warp && warp_ok;
    if !valid {
        return INVALID_TIME;
    }

    let occupancy = (occ_blocks * tpb / max_threads).min(1.0);

    let vec_bonus = 1.0 - 0.08 * (vecw.max(1.0).log2() - 1.5).abs();
    let unroll_curve = 1.0 - 0.05 * (unroll.max(1.0).log2() - 2.0).abs();
    let eff_compute = ((0.45 + 0.55 * occupancy) * vec_bonus * unroll_curve)
        .clamp(0.05, 1.0);
    let eff_memory = ((0.55 + 0.45 * occupancy.sqrt())
        * (0.6 + 0.4 * coal)
        * (1.0 + 0.15 * cache))
        .clamp(0.05, 1.05);

    let t_compute = flops / (peak * eff_compute);
    let t_memory = bytes_rw / (bandwidth * eff_memory);

    let resident = (occ_blocks * num_sm).max(1.0);
    let waves = (blocks / resident).ceil();
    let wave_penalty = waves * resident / blocks.max(1.0);

    let u = hash_a * (1.0 - rug_seed) + hash_b * rug_seed;
    let rugged = 1.0 + rug_amp * (2.0 * u - 1.0);

    t_compute.max(t_memory) * wave_penalty * rugged + LAUNCH_OVERHEAD * waves
}

/// Batched evaluation (native backend / oracle).
pub fn predict_times(features: &[Features], d: &[f32; NUM_DEVICE]) -> Vec<f32> {
    features.iter().map(|f| predict_time(f, d)).collect()
}

/// The warmup-drift triple the L2 `measure_batch` graph emits:
/// `(time, t_cold, t_hot)`; see `python/compile/model.py`.
pub fn measure_triple(f: &Features, d: &[f32; NUM_DEVICE]) -> (f32, f32, f32) {
    let t = predict_time(f, d);
    let drift = 1.02 + 0.04 * f[F_HASH_B];
    (t, t * drift, t * 0.995)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;

    fn feat(tpb: f32) -> Features {
        let mut f = [0f32; NUM_FEATURES];
        f[F_FLOPS] = 1e11;
        f[F_BYTES] = 1e9;
        f[F_TPB] = tpb;
        f[F_REGS] = 32.0;
        f[F_SMEM] = 4096.0;
        f[F_BLOCKS] = 4096.0;
        f[F_VECW] = 4.0;
        f[F_UNROLL] = 4.0;
        f[F_COAL] = 0.8;
        f[F_CACHE] = 0.5;
        f[F_HASH_A] = 0.3;
        f[F_HASH_B] = 0.7;
        f
    }

    #[test]
    fn valid_config_positive_time() {
        let t = predict_time(&feat(256.0), &A100.to_vector());
        assert!(t > 0.0 && t < 1.0, "t={t}");
    }

    #[test]
    fn invalid_configs_sentinel() {
        let d = A100.to_vector();
        assert_eq!(predict_time(&feat(2048.0), &d), INVALID_TIME); // > MAX_TPB
        assert_eq!(predict_time(&feat(100.0), &d), INVALID_TIME); // not warp-divisible
        let mut f = feat(256.0);
        f[F_SMEM] = 1e9; // no resident blocks
        assert_eq!(predict_time(&f, &d), INVALID_TIME);
    }

    #[test]
    fn roofline_monotonicity() {
        let d = A100.to_vector();
        let mut lo = feat(256.0);
        let mut hi = feat(256.0);
        lo[F_FLOPS] = 1e11;
        hi[F_FLOPS] = 2e11;
        assert!(predict_time(&hi, &d) >= predict_time(&lo, &d));
        lo[F_BYTES] = 1e10;
        hi[F_BYTES] = 4e10;
        assert!(predict_time(&hi, &d) >= predict_time(&lo, &d));
    }

    #[test]
    fn ruggedness_bounds() {
        let d = A100.to_vector();
        let mut smooth_d = d;
        smooth_d[D_RUG_AMP] = 0.0;
        for ha in [0.0, 0.25, 0.5, 0.99] {
            let mut f = feat(256.0);
            f[F_HASH_A] = ha;
            let rough = predict_time(&f, &d);
            let smooth = predict_time(&f, &smooth_d);
            let ratio = rough / smooth;
            assert!(ratio <= 1.0 + d[D_RUG_AMP] + 0.05);
            assert!(ratio >= 1.0 - d[D_RUG_AMP] - 0.05);
        }
    }

    #[test]
    fn measure_triple_ordering() {
        let (t, cold, hot) = measure_triple(&feat(256.0), &A100.to_vector());
        assert!(cold >= t);
        assert!(hot <= t);
        assert!(cold / t <= 1.06 + 1e-6);
    }

    #[test]
    fn wave_quantization_steps() {
        // Crossing a wave boundary must not make time *decrease*.
        let d = A100.to_vector();
        let mut f = feat(256.0);
        f[F_BYTES] = 0.0;
        // resident = occ_blocks * 108; pick blocks below and above a multiple
        let t_below = {
            f[F_BLOCKS] = 800.0;
            predict_time(&f, &d)
        };
        let t_above = {
            f[F_BLOCKS] = 900.0;
            predict_time(&f, &d)
        };
        // per-block normalized time should be higher right above a boundary
        assert!(t_above > 0.0 && t_below > 0.0);
    }
}
