//! Rust mirror of `python/compile/contract.py`.
//!
//! The runtime validates `artifacts/contract.json` against these constants
//! at load time, so a drift between the Python and Rust sides fails fast
//! instead of silently mis-indexing feature columns.

// ---- feature vector (per kernel configuration) -----------------------------
pub const F_FLOPS: usize = 0;
pub const F_BYTES: usize = 1;
pub const F_TPB: usize = 2;
pub const F_REGS: usize = 3;
pub const F_SMEM: usize = 4;
pub const F_BLOCKS: usize = 5;
pub const F_VECW: usize = 6;
pub const F_UNROLL: usize = 7;
pub const F_COAL: usize = 8;
pub const F_CACHE: usize = 9;
pub const F_HASH_A: usize = 10;
pub const F_HASH_B: usize = 11;
pub const NUM_FEATURES: usize = 12;

// ---- device vector -----------------------------------------------------------
pub const D_NUM_SM: usize = 0;
pub const D_PEAK_GFLOPS: usize = 1;
pub const D_BW_GBS: usize = 2;
pub const D_MAX_THREADS: usize = 3;
pub const D_SMEM_SM: usize = 4;
pub const D_REGS_SM: usize = 5;
pub const D_MAX_BLOCKS: usize = 6;
pub const D_WARP: usize = 7;
pub const D_RUG_SEED: usize = 8;
pub const D_RUG_AMP: usize = 9;
pub const NUM_DEVICE: usize = 10;

// ---- model constants -----------------------------------------------------------
/// Sentinel for configurations that fail to launch ("compile error").
pub const INVALID_TIME: f32 = 1.0e9;
/// Fixed per-wave launch overhead in seconds.
pub const LAUNCH_OVERHEAD: f32 = 3.0e-6;
/// Hardware limit on threads per block.
pub const MAX_TPB: f32 = 1024.0;

/// AOT artifact batch sizes (one HLO per size), ascending.
pub const BATCH_SIZES: [usize; 4] = [256, 1024, 4096, 16384];
pub const CONTRACT_VERSION: u64 = 1;

/// Validate a parsed `artifacts/contract.json` against this mirror.
pub fn validate_contract(json: &crate::util::json::Json) -> crate::error::Result<()> {
    use crate::bail;
    use crate::error::Context;
    let get = |k: &str| {
        json.get(k)
            .with_context(|| format!("contract.json missing {k:?}"))
    };
    if get("version")?.as_f64() != Some(CONTRACT_VERSION as f64) {
        bail!("contract version mismatch");
    }
    if get("num_features")?.as_usize() != Some(NUM_FEATURES) {
        bail!("num_features mismatch");
    }
    if get("num_device")?.as_usize() != Some(NUM_DEVICE) {
        bail!("num_device mismatch");
    }
    if get("invalid_time")?.as_f64() != Some(INVALID_TIME as f64) {
        bail!("invalid_time mismatch");
    }
    let idx = get("indices")?
        .as_obj()
        .context("indices must be an object")?;
    let expect = [
        ("F_FLOPS", F_FLOPS),
        ("F_BYTES", F_BYTES),
        ("F_TPB", F_TPB),
        ("F_REGS", F_REGS),
        ("F_SMEM", F_SMEM),
        ("F_BLOCKS", F_BLOCKS),
        ("F_VECW", F_VECW),
        ("F_UNROLL", F_UNROLL),
        ("F_COAL", F_COAL),
        ("F_CACHE", F_CACHE),
        ("F_HASH_A", F_HASH_A),
        ("F_HASH_B", F_HASH_B),
        ("D_NUM_SM", D_NUM_SM),
        ("D_PEAK_GFLOPS", D_PEAK_GFLOPS),
        ("D_BW_GBS", D_BW_GBS),
        ("D_MAX_THREADS", D_MAX_THREADS),
        ("D_SMEM_SM", D_SMEM_SM),
        ("D_REGS_SM", D_REGS_SM),
        ("D_MAX_BLOCKS", D_MAX_BLOCKS),
        ("D_WARP", D_WARP),
        ("D_RUG_SEED", D_RUG_SEED),
        ("D_RUG_AMP", D_RUG_AMP),
    ];
    for (name, want) in expect {
        match idx.get(name).and_then(|v| v.as_usize()) {
            Some(got) if got == want => {}
            other => bail!("index {name} mismatch: expected {want}, got {other:?}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn validates_generated_contract_shape() {
        // Build a contract.json equivalent in Rust and validate it.
        let mut indices = json::Json::obj();
        for (name, v) in [
            ("F_FLOPS", F_FLOPS),
            ("F_BYTES", F_BYTES),
            ("F_TPB", F_TPB),
            ("F_REGS", F_REGS),
            ("F_SMEM", F_SMEM),
            ("F_BLOCKS", F_BLOCKS),
            ("F_VECW", F_VECW),
            ("F_UNROLL", F_UNROLL),
            ("F_COAL", F_COAL),
            ("F_CACHE", F_CACHE),
            ("F_HASH_A", F_HASH_A),
            ("F_HASH_B", F_HASH_B),
            ("D_NUM_SM", D_NUM_SM),
            ("D_PEAK_GFLOPS", D_PEAK_GFLOPS),
            ("D_BW_GBS", D_BW_GBS),
            ("D_MAX_THREADS", D_MAX_THREADS),
            ("D_SMEM_SM", D_SMEM_SM),
            ("D_REGS_SM", D_REGS_SM),
            ("D_MAX_BLOCKS", D_MAX_BLOCKS),
            ("D_WARP", D_WARP),
            ("D_RUG_SEED", D_RUG_SEED),
            ("D_RUG_AMP", D_RUG_AMP),
        ] {
            indices.set(name, v.into());
        }
        let mut c = json::Json::obj();
        c.set("version", (CONTRACT_VERSION as usize).into())
            .set("num_features", NUM_FEATURES.into())
            .set("num_device", NUM_DEVICE.into())
            .set("invalid_time", (INVALID_TIME as f64).into())
            .set("indices", indices);
        validate_contract(&c).unwrap();

        // Tampered index must fail.
        let mut bad = c.clone();
        if let json::Json::Obj(m) = &mut bad {
            if let Some(json::Json::Obj(idx)) = m.get_mut("indices") {
                idx.insert("F_TPB".into(), json::Json::Num(9.0));
            }
        }
        assert!(validate_contract(&bad).is_err());
    }
}
