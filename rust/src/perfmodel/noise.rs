//! Measurement-noise model.
//!
//! Real auto-tuning measurements are noisy — the paper runs every kernel
//! configuration 32 times and stores both the raw and averaged values.
//! We reproduce that: every observation draws deterministic multiplicative
//! log-normal noise (plus rare scheduling outliers) from a stream seeded
//! by (space seed, config index, repeat), so the brute-forced dataset is
//! bit-reproducible while behaving like real measurements.

use crate::util::rng::{mix64, Rng};

/// Number of observations per configuration in the brute-force dataset
/// (matches the paper's hub).
pub const OBSERVATIONS: usize = 32;

/// Heteroscedastic observation-noise model.
#[derive(Clone, Debug)]
pub struct NoiseModel {
    /// Base relative sigma of the log-normal term.
    pub sigma: f64,
    /// Probability of a scheduling outlier per observation.
    pub outlier_prob: f64,
    /// Outlier slowdown factor upper bound (uniform in [1, bound]).
    pub outlier_factor: f64,
}

impl Default for NoiseModel {
    fn default() -> Self {
        NoiseModel {
            sigma: 0.02,
            outlier_prob: 0.01,
            outlier_factor: 1.5,
        }
    }
}

impl NoiseModel {
    /// One observed value for (true time, cold, hot) at a given repeat.
    ///
    /// Observation 0 is the cold run (warmup drift); later observations
    /// jitter around the true time, floored at the hot steady-state.
    pub fn observe(
        &self,
        space_seed: u64,
        config_idx: usize,
        repeat: usize,
        t_true: f64,
        t_cold: f64,
        t_hot: f64,
    ) -> f64 {
        let mut rng = Rng::new(mix64(
            space_seed,
            mix64(config_idx as u64, repeat as u64 ^ 0xA5A5_5A5A),
        ));
        let base = if repeat == 0 { t_cold } else { t_true };
        let mut v = base * rng.lognormal_unit(self.sigma);
        if rng.chance(self.outlier_prob) {
            v *= rng.range_f64(1.0, self.outlier_factor);
        }
        v.max(t_hot)
    }

    /// The full observation vector for a configuration.
    pub fn observations(
        &self,
        space_seed: u64,
        config_idx: usize,
        t_true: f64,
        t_cold: f64,
        t_hot: f64,
        count: usize,
    ) -> Vec<f64> {
        (0..count)
            .map(|r| self.observe(space_seed, config_idx, r, t_true, t_cold, t_hot))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_key() {
        // t_hot well below t_true so the floor never collapses draws.
        let nm = NoiseModel::default();
        let a = nm.observe(1, 2, 3, 1.0, 1.03, 0.5);
        let b = nm.observe(1, 2, 3, 1.0, 1.03, 0.5);
        assert_eq!(a, b);
        let c = nm.observe(1, 2, 4, 1.0, 1.03, 0.5);
        assert_ne!(a, c);
        let d = nm.observe(2, 2, 3, 1.0, 1.03, 0.5);
        assert_ne!(a, d);
    }

    #[test]
    fn mean_near_true_value() {
        let nm = NoiseModel {
            sigma: 0.02,
            outlier_prob: 0.0,
            outlier_factor: 1.0,
        };
        let obs = nm.observations(7, 11, 1.0, 1.03, 0.9, 10_000);
        // skip cold observation
        let mean: f64 = obs[1..].iter().sum::<f64>() / (obs.len() - 1) as f64;
        assert!((mean - 1.0).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn cold_first_observation() {
        let nm = NoiseModel {
            sigma: 0.0,
            outlier_prob: 0.0,
            outlier_factor: 1.0,
        };
        let obs = nm.observations(1, 1, 1.0, 1.05, 0.995, 4);
        assert!((obs[0] - 1.05).abs() < 1e-12);
        assert!((obs[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn floored_at_hot() {
        let nm = NoiseModel {
            sigma: 0.5, // huge noise
            outlier_prob: 0.0,
            outlier_factor: 1.0,
        };
        let obs = nm.observations(3, 5, 1.0, 1.03, 0.995, 1000);
        assert!(obs.iter().all(|&v| v >= 0.995));
    }

    #[test]
    fn outliers_show_up() {
        let nm = NoiseModel {
            sigma: 0.0,
            outlier_prob: 0.5,
            outlier_factor: 2.0,
        };
        let obs = nm.observations(9, 1, 1.0, 1.0, 0.0, 1000);
        let outliers = obs.iter().filter(|&&v| v > 1.01).count();
        assert!(outliers > 300 && outliers < 700, "outliers={outliers}");
    }
}
