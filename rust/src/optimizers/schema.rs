//! Self-describing optimizer registry: typed hyperparameter schemas.
//!
//! Every optimizer *declares* its hyperparameters as a [`HyperSchema`]
//! list inside a [`Descriptor`], making the registry the single source of
//! truth for defaults, validation, documentation, and the Table III /
//! Table IV hyperparameter search spaces (which
//! [`crate::hypertuning::space`] derives from the `limited` / `extended`
//! grids declared here). Before this inversion the spaces were
//! hand-written tables that could silently drift from the string-keyed
//! defaults buried in each optimizer's `new(hp)` — a typo'd key fell back
//! to a default with no error, invalidating a whole tuning run.
//! [`Descriptor::validate`] turns unknown keys and type mismatches into
//! hard errors.

use super::{HyperParams, Optimizer};
use crate::searchspace::Value;
use crate::error::{Result, TuneError};

/// The value type a hyperparameter accepts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HyperKind {
    /// Real-valued (integers are accepted and widened).
    Float,
    /// Integer-valued (floats with a zero fractional part are accepted).
    Int,
    /// Categorical string, constrained to the schema's `choices`.
    Str,
}

/// Typed declaration of one hyperparameter: its kind, default, and the
/// value grids it contributes to the limited (Table III) and extended
/// (Table IV) hyperparameter search spaces. Empty grids mean the
/// hyperparameter is excluded from that space (e.g. PSO's `w`, dropped by
/// the paper's sensitivity screen).
#[derive(Clone, Debug)]
pub struct HyperSchema {
    pub name: &'static str,
    pub kind: HyperKind,
    /// Default used when the key is absent (merged in by
    /// [`Descriptor::resolve`]).
    pub default: Value,
    /// Allowed values for `Str` kind; empty = unconstrained.
    pub choices: Vec<Value>,
    /// Table III grid (empty = not part of the limited space).
    pub limited: Vec<Value>,
    /// Table IV grid (empty = not part of the extended space).
    pub extended: Vec<Value>,
}

impl HyperSchema {
    pub fn float(name: &'static str, default: f64) -> HyperSchema {
        HyperSchema {
            name,
            kind: HyperKind::Float,
            default: Value::Float(default),
            choices: Vec::new(),
            limited: Vec::new(),
            extended: Vec::new(),
        }
    }

    pub fn int(name: &'static str, default: i64) -> HyperSchema {
        HyperSchema {
            name,
            kind: HyperKind::Int,
            default: Value::Int(default),
            choices: Vec::new(),
            limited: Vec::new(),
            extended: Vec::new(),
        }
    }

    pub fn str(name: &'static str, default: &str, choices: &[&str]) -> HyperSchema {
        HyperSchema {
            name,
            kind: HyperKind::Str,
            default: Value::Str(default.to_string()),
            choices: strs(choices),
            limited: Vec::new(),
            extended: Vec::new(),
        }
    }

    /// Declare the Table III (limited) value grid.
    pub fn limited(mut self, values: Vec<Value>) -> HyperSchema {
        self.limited = values;
        self
    }

    /// Declare the Table IV (extended) value grid.
    pub fn extended(mut self, values: Vec<Value>) -> HyperSchema {
        self.extended = values;
        self
    }

    /// Check one assigned value against this schema entry.
    fn check(&self, owner: &str, v: &Value) -> Result<()> {
        match self.kind {
            // Bools are rejected for numeric kinds even though the Value
            // accessors would coerce them to 0/1 — exactly the silent
            // coercion this validation exists to eliminate.
            HyperKind::Float => {
                if matches!(v, Value::Bool(_)) || v.as_f64().is_none() {
                    return Err(TuneError::SchemaViolation(format!(
                        "hyperparameter {:?} of {owner} expects a float, got {v:?}",
                        self.name
                    )));
                }
            }
            HyperKind::Int => {
                if matches!(v, Value::Bool(_)) || v.as_i64().is_none() {
                    return Err(TuneError::SchemaViolation(format!(
                        "hyperparameter {:?} of {owner} expects an integer, got {v:?}",
                        self.name
                    )));
                }
            }
            HyperKind::Str => {
                let Some(s) = v.as_str() else {
                    return Err(TuneError::SchemaViolation(format!(
                        "hyperparameter {:?} of {owner} expects a string, got {v:?}",
                        self.name
                    )));
                };
                if !self.choices.is_empty()
                    && !self.choices.iter().any(|c| c.as_str() == Some(s))
                {
                    return Err(TuneError::SchemaViolation(format!(
                        "hyperparameter {:?} of {owner} has no choice {s:?}; \
                         valid choices: {}",
                        self.name,
                        self.choices
                            .iter()
                            .map(|c| c.key())
                            .collect::<Vec<_>>()
                            .join(", ")
                    )));
                }
            }
        }
        Ok(())
    }
}

/// A registered optimizer: its name, declared hyperparameter schema, and
/// factory. [`super::registry`] collects one per optimizer;
/// [`super::create`] resolves hyperparameters against the schema before
/// construction.
pub struct Descriptor {
    pub name: &'static str,
    /// One of the four algorithms the paper evaluates (Table III set).
    /// Deliberately a flag, not derived from the grids: extra optimizers
    /// may declare `limited`/`extended` grids to become hypertunable
    /// without silently joining the paper-replication experiment drivers.
    pub paper: bool,
    /// Declaration order defines the parameter order of the derived
    /// Table III / Table IV search spaces.
    pub schema: Vec<HyperSchema>,
    /// Factory invoked with schema-resolved (validated + defaulted)
    /// hyperparameters.
    pub build: fn(&HyperParams) -> Result<Box<dyn Optimizer>>,
}

impl Descriptor {
    /// True if any hyperparameter contributes a limited (Table III) grid.
    pub fn has_limited_space(&self) -> bool {
        self.schema.iter().any(|s| !s.limited.is_empty())
    }

    /// True if any hyperparameter contributes an extended (Table IV) grid.
    pub fn has_extended_space(&self) -> bool {
        self.schema.iter().any(|s| !s.extended.is_empty())
    }

    /// Size of the limited (Table III) hyperparameter grid: the product
    /// of the non-empty `limited` value lists, or 0 when the optimizer
    /// declares none (no limited space can be derived).
    pub fn limited_grid_size(&self) -> usize {
        grid_size(self.schema.iter().map(|s| s.limited.len()))
    }

    /// Size of the extended (Table IV) hyperparameter grid, or 0 when
    /// the optimizer declares none.
    pub fn extended_grid_size(&self) -> usize {
        grid_size(self.schema.iter().map(|s| s.extended.len()))
    }

    /// Hard-validate an assignment: unknown keys, type mismatches and
    /// out-of-choice categoricals are errors (listing the valid keys),
    /// rather than silently falling back to defaults.
    pub fn validate(&self, hp: &HyperParams) -> Result<()> {
        for (key, value) in &hp.0 {
            let Some(schema) = self.schema.iter().find(|s| s.name == key.as_str()) else {
                if self.schema.is_empty() {
                    return Err(TuneError::SchemaViolation(format!(
                        "unknown hyperparameter {key:?}: {} takes no hyperparameters",
                        self.name
                    )));
                }
                return Err(TuneError::SchemaViolation(format!(
                    "unknown hyperparameter {key:?} for {}; valid keys: {}",
                    self.name,
                    self.schema
                        .iter()
                        .map(|s| s.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )));
            };
            schema.check(self.name, value)?;
        }
        Ok(())
    }

    /// Validate, then merge schema defaults for every absent key, so the
    /// optimizer constructor always sees a fully populated assignment and
    /// the schema stays the single source of truth for defaults.
    pub fn resolve(&self, hp: &HyperParams) -> Result<HyperParams> {
        self.validate(hp)?;
        let mut full = hp.clone();
        for s in &self.schema {
            full.0
                .entry(s.name.to_string())
                .or_insert_with(|| s.default.clone());
        }
        Ok(full)
    }
}

/// Product of the non-empty grid lengths (0 when every grid is empty —
/// hyperparameters without a grid don't contribute a dimension, they
/// stay at their defaults).
fn grid_size(lens: impl Iterator<Item = usize>) -> usize {
    let mut size = 0usize;
    for len in lens.filter(|&l| l > 0) {
        size = if size == 0 { len } else { size * len };
    }
    size
}

// ---------------------------------------------------------------------------
// Grid helpers for schema declarations

/// Float literals as grid values.
pub fn floats(values: &[f64]) -> Vec<Value> {
    values.iter().map(|&v| Value::Float(v)).collect()
}

/// Integer literals as grid values.
pub fn ints(values: &[i64]) -> Vec<Value> {
    values.iter().map(|&v| Value::Int(v)).collect()
}

/// String literals as grid values.
pub fn strs(values: &[&str]) -> Vec<Value> {
    values.iter().map(|&v| Value::Str(v.to_string())).collect()
}

/// Inclusive integer grid `lo, lo+step, …, hi`.
pub fn int_range(lo: i64, hi: i64, step: i64) -> Vec<Value> {
    assert!(step > 0);
    (lo..=hi).step_by(step as usize).map(Value::Int).collect()
}

/// Float grid `lo, lo+step, …`, stopping at the last value ≤ `hi`. `hi`
/// itself is included exactly when `hi - lo` is an (almost exact)
/// multiple of `step` — e.g. `(0.1, 2.0, 0.1)` ends at 2.0, while
/// `(0.0001, 0.1, 0.001)` ends at 0.0991 because `lo` is off the step
/// grid.
///
/// Generated by integer index — never by accumulation, whose rounding
/// drift could drop an on-grid upper endpoint — and snapped to 1e-9
/// precision so grid values print cleanly (`0.3`, not
/// `0.30000000000000004`). The result is deduplicated, so a step below
/// the snap precision cannot emit repeated values.
pub fn float_range(lo: f64, hi: f64, step: f64) -> Vec<Value> {
    assert!(step > 0.0 && hi >= lo && lo.is_finite() && hi.is_finite());
    let span = (hi - lo) / step;
    // Tolerate representation error in the step count so an (almost)
    // exactly divisible span still includes `hi`.
    let steps = if (span - span.round()).abs() < 1e-6 {
        span.round()
    } else {
        span.floor()
    };
    let n = steps as usize;
    let mut out: Vec<Value> = Vec::with_capacity(n + 1);
    for i in 0..=n {
        let raw = lo + i as f64 * step;
        out.push(Value::Float((raw * 1e9).round() / 1e9));
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn float_range_keeps_endpoints_and_dedupes() {
        // (0.1, 2.0, 0.1): 1.9/0.1 is 18.999999999999996 in f64 — the old
        // accumulating generator was one rounding error away from dropping
        // the 2.0 endpoint.
        let vals = float_range(0.1, 2.0, 0.1);
        assert_eq!(vals.len(), 20);
        assert_eq!(vals.first().unwrap().as_f64(), Some(0.1));
        assert_eq!(vals.last().unwrap().as_f64(), Some(2.0));
        // Snapped values print cleanly.
        assert_eq!(vals[2].key(), "0.3");
        // Strictly increasing — no duplicates after rounding.
        for w in vals.windows(2) {
            assert!(w[0].as_f64().unwrap() < w[1].as_f64().unwrap());
        }
    }

    #[test]
    fn float_range_off_grid_lo_preserved() {
        // The old generator snapped values to the step grid, collapsing an
        // off-grid `lo` like 0.0001 to 0.0 (a nonsense T_min).
        let vals = float_range(0.0001, 0.1, 0.001);
        assert_eq!(vals.len(), 100);
        assert_eq!(vals[0].as_f64(), Some(0.0001));
        assert_eq!(vals[0].key(), "0.0001");
        assert_eq!(vals[99].key(), "0.0991");
        for w in vals.windows(2) {
            assert!(w[0].as_f64().unwrap() < w[1].as_f64().unwrap());
        }
    }

    #[test]
    fn float_range_quarter_steps_exact() {
        let c1 = float_range(1.0, 3.5, 0.25);
        assert_eq!(c1.len(), 11);
        assert_eq!(c1.last().unwrap().as_f64(), Some(3.5));
        let c2 = float_range(0.5, 2.0, 0.25);
        assert_eq!(c2.len(), 7);
        assert_eq!(c2.last().unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn int_range_inclusive() {
        let vals = int_range(2, 50, 2);
        assert_eq!(vals.len(), 25);
        assert_eq!(vals[0].as_i64(), Some(2));
        assert_eq!(vals[24].as_i64(), Some(50));
    }

    #[test]
    fn schema_check_types() {
        let s = HyperSchema::float("T", 1.0);
        assert!(s.check("x", &Value::Float(2.0)).is_ok());
        assert!(s.check("x", &Value::Int(2)).is_ok());
        assert!(s.check("x", &Value::Str("hot".into())).is_err());
        assert!(s.check("x", &Value::Bool(true)).is_err());
        let i = HyperSchema::int("popsize", 20);
        assert!(i.check("x", &Value::Int(10)).is_ok());
        assert!(i.check("x", &Value::Float(10.0)).is_ok());
        assert!(i.check("x", &Value::Float(10.5)).is_err());
        assert!(i.check("x", &Value::Bool(true)).is_err());
        let c = HyperSchema::str("method", "a", &["a", "b"]);
        assert!(c.check("x", &Value::Str("b".into())).is_ok());
        assert!(c.check("x", &Value::Str("z".into())).is_err());
        assert!(c.check("x", &Value::Int(1)).is_err());
    }
}
