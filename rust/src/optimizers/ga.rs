//! Genetic algorithm (Table III hyperparameters: `method`, `popsize`,
//! `maxiter`, `mutation_chance`).
//!
//! Rank-weighted parent selection, one of four crossover operators
//! (`single_point`, `two_point`, `uniform`, `disruptive_uniform`), and
//! per-gene mutation with probability `1 / mutation_chance` (Kernel
//! Tuner's convention: the hyperparameter is the denominator). Children
//! that land on invalid configurations are snapped to the nearest valid
//! lattice point.

use super::schema::{self, Descriptor, HyperSchema};
use super::{HyperParams, Optimizer};
use crate::runner::Tuning;
use crate::searchspace::SearchSpace;
use crate::util::rng::Rng;
use crate::bail;
use crate::error::Result;

pub const CROSSOVER_METHODS: [&str; 4] =
    ["single_point", "two_point", "uniform", "disruptive_uniform"];

/// Registry entry: the GA's Table III and Table IV grids.
pub fn descriptor() -> Descriptor {
    Descriptor {
        name: "genetic_algorithm",
        paper: true,
        schema: vec![
            HyperSchema::str("method", "uniform", &CROSSOVER_METHODS)
                .limited(schema::strs(&CROSSOVER_METHODS))
                .extended(schema::strs(&CROSSOVER_METHODS)),
            HyperSchema::int("popsize", 20)
                .limited(schema::ints(&[10, 20, 30]))
                .extended(schema::int_range(2, 50, 2)),
            HyperSchema::int("maxiter", 100)
                .limited(schema::ints(&[50, 100, 150]))
                .extended(schema::int_range(10, 200, 10)),
            HyperSchema::int("mutation_chance", 10)
                .limited(schema::ints(&[5, 10, 20]))
                .extended(schema::int_range(5, 100, 5)),
        ],
        build: |hp| Ok(Box::new(GeneticAlgorithm::new(hp)?)),
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Crossover {
    SinglePoint,
    TwoPoint,
    Uniform,
    DisruptiveUniform,
}

impl Crossover {
    pub fn parse(name: &str) -> Result<Crossover> {
        Ok(match name {
            "single_point" => Crossover::SinglePoint,
            "two_point" => Crossover::TwoPoint,
            "uniform" => Crossover::Uniform,
            "disruptive_uniform" => Crossover::DisruptiveUniform,
            other => bail!("unknown crossover {other:?}"),
        })
    }

    /// Produce two children from two parents (encoded configs).
    pub fn apply(&self, a: &[u16], b: &[u16], rng: &mut Rng) -> (Vec<u16>, Vec<u16>) {
        let n = a.len();
        let mut c1 = a.to_vec();
        let mut c2 = b.to_vec();
        match self {
            Crossover::SinglePoint => {
                let cut = 1 + rng.below(n.max(2) - 1);
                for d in cut..n {
                    c1[d] = b[d];
                    c2[d] = a[d];
                }
            }
            Crossover::TwoPoint => {
                let (mut lo, mut hi) = (rng.below(n), rng.below(n));
                if lo > hi {
                    std::mem::swap(&mut lo, &mut hi);
                }
                for d in lo..=hi {
                    c1[d] = b[d];
                    c2[d] = a[d];
                }
            }
            Crossover::Uniform => {
                for d in 0..n {
                    if rng.chance(0.5) {
                        c1[d] = b[d];
                        c2[d] = a[d];
                    }
                }
            }
            Crossover::DisruptiveUniform => {
                // Swap *only* where parents differ, maximizing disruption.
                for d in 0..n {
                    if a[d] != b[d] && rng.chance(0.5) {
                        c1[d] = b[d];
                        c2[d] = a[d];
                    }
                }
            }
        }
        (c1, c2)
    }
}

pub struct GeneticAlgorithm {
    pub crossover: Crossover,
    pub popsize: usize,
    pub maxiter: usize,
    /// Per-gene mutation probability = 1 / mutation_chance.
    pub mutation_chance: usize,
}

impl GeneticAlgorithm {
    pub fn new(hp: &HyperParams) -> Result<GeneticAlgorithm> {
        Ok(GeneticAlgorithm {
            crossover: Crossover::parse(&hp.str("method", "uniform"))?,
            popsize: hp.usize("popsize", 20).max(2),
            maxiter: hp.usize("maxiter", 100).max(1),
            mutation_chance: hp.usize("mutation_chance", 10).max(1),
        })
    }

    fn mutate(&self, enc: &mut [u16], space: &SearchSpace, rng: &mut Rng) {
        let dims = space.dims();
        for (d, g) in enc.iter_mut().enumerate() {
            if rng.chance(1.0 / self.mutation_chance as f64) && dims[d] > 1 {
                let mut nv = rng.below(dims[d]) as u16;
                while nv == *g {
                    nv = rng.below(dims[d]) as u16;
                }
                *g = nv;
            }
        }
    }

    /// Resolve an encoded child to a valid config index (exact packed-rank
    /// lookup, else integer-L1 snap — no float conversion, no allocation).
    fn materialize(&self, enc: &[u16], space: &SearchSpace, rng: &mut Rng) -> usize {
        space.snap_encoded(enc, rng)
    }
}

impl Optimizer for GeneticAlgorithm {
    fn name(&self) -> &'static str {
        "genetic_algorithm"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        // Initial population, evaluated as one batch. Evaluations never
        // consume optimizer RNG, so drawing the sample first and batching
        // the evals replays the scalar per-eval loop bit for bit
        // (including truncation when the budget expires mid-population).
        let n = tuning.space().len();
        let init = tuning.space().sample(rng, self.popsize.min(n));
        let vals = tuning.eval_batch(&init);
        let mut pop: Vec<(usize, f64)> =
            init.iter().zip(vals).map(|(&i, &v)| (i, v)).collect();
        if pop.len() < init.len() {
            return;
        }
        for _gen in 0..self.maxiter {
            if tuning.done() {
                return;
            }
            // Rank-weighted selection: sort ascending (better first).
            pop.sort_by(|a, b| a.1.total_cmp(&b.1));
            pop.truncate(self.popsize);
            let mut next: Vec<(usize, f64)> = Vec::with_capacity(self.popsize);
            // Elitism: carry the best through unchanged.
            next.push(pop[0]);
            // Draw the whole generation's genetic operations up front in
            // the scalar order (selection, crossover, mutation, snap per
            // pushed child), then serve every child with one batch.
            let target = self.popsize - 1;
            let mut cand: Vec<usize> = Vec::with_capacity(target);
            while cand.len() < target {
                let pa = pop[rank_pick(pop.len(), rng)].0;
                let pb = pop[rank_pick(pop.len(), rng)].0;
                let ea = tuning.space().encoded_vec(pa);
                let eb = tuning.space().encoded_vec(pb);
                let (mut c1, mut c2) = self.crossover.apply(&ea, &eb, rng);
                self.mutate(&mut c1, tuning.space(), rng);
                self.mutate(&mut c2, tuning.space(), rng);
                for child in [c1, c2] {
                    if cand.len() >= target {
                        break;
                    }
                    cand.push(self.materialize(&child, tuning.space(), rng));
                }
            }
            let vals = tuning.eval_batch(&cand);
            let consumed = vals.len();
            for (k, &v) in vals.iter().enumerate() {
                next.push((cand[k], v));
            }
            if consumed < cand.len() {
                return;
            }
            pop = next;
        }
    }
}

/// Rank-biased index pick: quadratic bias toward the front (better ranks).
fn rank_pick(len: usize, rng: &mut Rng) -> usize {
    let u = rng.next_f64();
    ((u * u) * len as f64) as usize % len
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{quality, run_optimizer};
    use super::super::HyperParams;
    use super::*;

    #[test]
    fn all_crossovers_work() {
        for m in CROSSOVER_METHODS {
            let hp = HyperParams::new().set("method", m).set("popsize", 10i64);
            let trace = run_optimizer("genetic_algorithm", &hp, 80, 31);
            assert!(quality(&trace) > 0.3, "{m}: q={}", quality(&trace));
        }
    }

    #[test]
    fn crossover_operators_distinct() {
        let mut rng = Rng::new(3);
        let a = vec![0u16, 0, 0, 0, 0, 0];
        let b = vec![1u16, 1, 1, 1, 1, 1];
        let (c1, _) = Crossover::SinglePoint.apply(&a, &b, &mut rng);
        // single point: prefix from a, suffix from b
        let switch = c1.iter().position(|&x| x == 1).unwrap_or(6);
        assert!(c1[switch..].iter().all(|&x| x == 1));

        // disruptive uniform on identical parents changes nothing
        let (d1, d2) = Crossover::DisruptiveUniform.apply(&a, &a, &mut rng);
        assert_eq!(d1, a);
        assert_eq!(d2, a);
    }

    #[test]
    fn rejects_unknown_method() {
        let hp = HyperParams::new().set("method", "bogus");
        assert!(GeneticAlgorithm::new(&hp).is_err());
    }

    #[test]
    fn mutation_rate_matters() {
        // Very high mutation (denominator 1 => p=1) behaves like random
        // search; elitism still guarantees progress is kept.
        let hi = HyperParams::new().set("mutation_chance", 1i64);
        let lo = HyperParams::new().set("mutation_chance", 100i64);
        let th = run_optimizer("genetic_algorithm", &hi, 60, 5);
        let tl = run_optimizer("genetic_algorithm", &lo, 60, 5);
        let sh: Vec<usize> = th.points.iter().map(|p| p.config).collect();
        let sl: Vec<usize> = tl.points.iter().map(|p| p.config).collect();
        assert_ne!(sh, sl);
    }

    #[test]
    fn popsize_respected_in_first_generation() {
        let hp = HyperParams::new().set("popsize", 7i64).set("maxiter", 1i64);
        let trace = run_optimizer("genetic_algorithm", &hp, 1000, 9);
        // init pop (7 unique) + <= popsize-1 children (some may revisit)
        assert!(trace.unique_evals <= 14);
    }
}
