//! Simulated annealing (Table III hyperparameters: `T`, `T_min`, `alpha`,
//! `maxiter`).
//!
//! Classic Metropolis walk over the Hamming neighborhood: always accept
//! improvements, accept worsenings with probability
//! `exp(-rel_delta / T_cur)` where `rel_delta` is the relative objective
//! increase (scale-invariant across search spaces). The temperature decays
//! geometrically by `alpha` from `T` to `T_min`, with `maxiter` proposal
//! moves at each temperature step (Kernel Tuner's semantics). When a
//! schedule completes with budget left, the walk restarts from a fresh
//! random point.
//!
//! Each proposal is one `SearchSpace::random_neighbor` call, which the
//! packed-rank engine serves with a stride-delta and a bitset probe —
//! zero heap allocations per annealing step.

use super::schema::{self, Descriptor, HyperSchema};
use super::{relative_delta, HyperParams, Optimizer};
use crate::runner::Tuning;
use crate::searchspace::Neighborhood;
use crate::util::rng::Rng;

/// Registry entry: the typed hyperparameter schema (Table III column and
/// Table IV row for simulated annealing derive from these grids).
pub fn descriptor() -> Descriptor {
    Descriptor {
        name: "simulated_annealing",
        paper: true,
        schema: vec![
            HyperSchema::float("T", 1.0)
                .limited(schema::floats(&[0.5, 1.0, 1.5]))
                .extended(schema::float_range(0.1, 2.0, 0.1)),
            HyperSchema::float("T_min", 0.001)
                .limited(schema::floats(&[0.0001, 0.001, 0.01]))
                .extended(schema::float_range(0.0001, 0.1, 0.001)),
            HyperSchema::float("alpha", 0.995)
                .limited(schema::floats(&[0.9925, 0.995, 0.9975]))
                .extended(schema::floats(&[0.9925, 0.995, 0.9975])),
            HyperSchema::int("maxiter", 2)
                .limited(schema::ints(&[1, 2, 3]))
                .extended(schema::int_range(1, 10, 1)),
        ],
        build: |hp| Ok(Box::new(SimulatedAnnealing::new(hp))),
    }
}

pub struct SimulatedAnnealing {
    pub t_start: f64,
    pub t_min: f64,
    pub alpha: f64,
    pub maxiter: usize,
}

impl SimulatedAnnealing {
    pub fn new(hp: &HyperParams) -> SimulatedAnnealing {
        SimulatedAnnealing {
            t_start: hp.f64("T", 1.0),
            t_min: hp.f64("T_min", 0.001),
            alpha: hp.f64("alpha", 0.995).clamp(0.5, 0.999999),
            maxiter: hp.usize("maxiter", 2).max(1),
        }
    }
}

impl Optimizer for SimulatedAnnealing {
    fn name(&self) -> &'static str {
        "simulated_annealing"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        // Restart full schedules until the budget is exhausted.
        while !tuning.done() {
            let mut current = tuning.space().random(rng);
            let mut current_val = tuning.eval(current);
            let mut temp = self.t_start.max(self.t_min);
            while temp > self.t_min && !tuning.done() {
                // `maxiter` proposal moves per temperature step.
                for _ in 0..self.maxiter {
                    if tuning.done() {
                        break;
                    }
                    let cand = tuning
                        .space()
                        .random_neighbor(current, Neighborhood::Hamming, rng);
                    let cand_val = tuning.eval(cand);
                    let delta = relative_delta(cand_val, current_val);
                    if delta <= 0.0 || rng.next_f64() < (-delta / temp).exp() {
                        current = cand;
                        current_val = cand_val;
                    }
                }
                temp *= self.alpha;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{quality, run_optimizer};
    use super::super::HyperParams;
    use super::*;

    #[test]
    fn default_hyperparams() {
        let sa = SimulatedAnnealing::new(&HyperParams::new());
        assert_eq!(sa.t_start, 1.0);
        assert_eq!(sa.maxiter, 2);
    }

    #[test]
    fn finds_good_configs() {
        let trace = run_optimizer("simulated_annealing", &HyperParams::new(), 100, 13);
        assert!(quality(&trace) > 0.5, "q={}", quality(&trace));
    }

    #[test]
    fn cold_anneal_is_greedy() {
        // With T ~ 0 the walk must be (nearly) monotone improving on the
        // accepted path; we can't observe acceptance directly, but a cold
        // run should reach at least the quality of the default.
        let hot = HyperParams::new().set("T", 5.0).set("alpha", 0.999);
        let cold = HyperParams::new().set("T", 0.001).set("alpha", 0.9);
        let th = run_optimizer("simulated_annealing", &hot, 80, 3);
        let tc = run_optimizer("simulated_annealing", &cold, 80, 3);
        // Both run; the temperature must change the visited trajectory
        // (final quality may coincide on a small space).
        let sh: Vec<usize> = th.points.iter().map(|p| p.config).collect();
        let sc: Vec<usize> = tc.points.iter().map(|p| p.config).collect();
        assert_ne!(sh, sc);
    }

    #[test]
    fn hyperparameters_affect_trajectory() {
        // Fast-decaying schedules (~20 moves each) so maxiter restarts fire
        // within the budget and the trajectories diverge.
        let base = || HyperParams::new().set("alpha", 0.8).set("T_min", 0.01);
        let a = run_optimizer("simulated_annealing", &base().set("maxiter", 1i64), 60, 9);
        let b = run_optimizer("simulated_annealing", &base().set("maxiter", 3i64), 60, 9);
        let pa: Vec<usize> = a.points.iter().map(|p| p.config).collect();
        let pb: Vec<usize> = b.points.iter().map(|p| p.config).collect();
        assert_ne!(pa, pb);
    }
}
