//! Particle swarm optimization (Table III hyperparameters: `popsize`,
//! `maxiter`, `c1`, `c2`; `w` exposed but excluded from the paper's tuning
//! after the sensitivity screen).
//!
//! Particles live in the continuous encoded (value-index) space; positions
//! are snapped to the nearest valid lattice point for evaluation. Velocity
//! update is the canonical `w*v + c1*r1*(pbest - x) + c2*r2*(gbest - x)`,
//! applied as a *synchronous* sweep: `gbest` is frozen per iteration and
//! the whole swarm is evaluated with one [`Tuning::eval_batch`] call.

use super::schema::{self, Descriptor, HyperSchema};
use super::{HyperParams, Optimizer};
use crate::runner::Tuning;
use crate::util::rng::Rng;

/// Registry entry. `w` is declared (typed, defaulted) but contributes no
/// grid: the paper's sensitivity screen found it had no meaningful effect
/// and dropped it from both hyperparameter spaces.
pub fn descriptor() -> Descriptor {
    Descriptor {
        name: "pso",
        paper: true,
        schema: vec![
            HyperSchema::int("popsize", 20)
                .limited(schema::ints(&[10, 20, 30]))
                .extended(schema::int_range(2, 50, 2)),
            HyperSchema::int("maxiter", 100)
                .limited(schema::ints(&[50, 100, 150]))
                .extended(schema::int_range(10, 200, 10)),
            HyperSchema::float("c1", 2.0)
                .limited(schema::floats(&[1.0, 2.0, 3.0]))
                .extended(schema::float_range(1.0, 3.5, 0.25)),
            HyperSchema::float("c2", 1.0)
                .limited(schema::floats(&[0.5, 1.0, 1.5]))
                .extended(schema::float_range(0.5, 2.0, 0.25)),
            HyperSchema::float("w", 0.5),
        ],
        build: |hp| Ok(Box::new(Pso::new(hp))),
    }
}

pub struct Pso {
    pub popsize: usize,
    pub maxiter: usize,
    pub c1: f64,
    pub c2: f64,
    pub w: f64,
}

impl Pso {
    pub fn new(hp: &HyperParams) -> Pso {
        Pso {
            popsize: hp.usize("popsize", 20).max(2),
            maxiter: hp.usize("maxiter", 100).max(1),
            c1: hp.f64("c1", 2.0),
            c2: hp.f64("c2", 1.0),
            w: hp.f64("w", 0.5),
        }
    }
}

struct Particle {
    pos: Vec<f64>,
    vel: Vec<f64>,
    best_pos: Vec<f64>,
    best_val: f64,
}

impl Optimizer for Pso {
    fn name(&self) -> &'static str {
        "pso"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        let ndim = dims.len();
        let n = tuning.space().len();

        let mut particles: Vec<Particle> = Vec::with_capacity(self.popsize);
        let mut gbest_pos: Vec<f64> = vec![0.0; ndim];
        let mut gbest_val = f64::INFINITY;

        // Initial swarm: one batched evaluation of the sample, then the
        // per-particle velocity draws in the scalar order (evaluations
        // consume no optimizer RNG, so the stream is unchanged).
        let init = tuning.space().sample(rng, self.popsize.min(n));
        let vals: Vec<f64> = tuning.eval_batch(&init).to_vec();
        for (k, &v) in vals.iter().enumerate() {
            let idx = init[k];
            let pos: Vec<f64> =
                (0..ndim).map(|d| tuning.space().digit(idx, d) as f64).collect();
            let vel: Vec<f64> = dims
                .iter()
                .map(|&d| rng.range_f64(-1.0, 1.0) * (d as f64 / 4.0))
                .collect();
            if v < gbest_val {
                gbest_val = v;
                gbest_pos = pos.clone();
            }
            particles.push(Particle {
                best_pos: pos.clone(),
                best_val: v,
                pos,
                vel,
            });
        }
        if vals.len() < init.len() {
            return;
        }

        for _iter in 0..self.maxiter {
            if tuning.done() {
                return;
            }
            // Synchronous sweep: gbest is frozen for the iteration, every
            // particle's velocity/position update and snap is drawn, and
            // the whole swarm is served by one batched evaluation.
            let mut cand: Vec<usize> = Vec::with_capacity(particles.len());
            for p in particles.iter_mut() {
                for d in 0..ndim {
                    let r1 = rng.next_f64();
                    let r2 = rng.next_f64();
                    p.vel[d] = self.w * p.vel[d]
                        + self.c1 * r1 * (p.best_pos[d] - p.pos[d])
                        + self.c2 * r2 * (gbest_pos[d] - p.pos[d]);
                    // Velocity clamp: half the dimension span.
                    let vmax = (dims[d] as f64) / 2.0;
                    p.vel[d] = p.vel[d].clamp(-vmax, vmax);
                    p.pos[d] = (p.pos[d] + p.vel[d]).clamp(0.0, (dims[d] - 1) as f64);
                }
                cand.push(tuning.space().snap(&p.pos, rng));
            }
            let vals: Vec<f64> = tuning.eval_batch(&cand).to_vec();
            for (k, &v) in vals.iter().enumerate() {
                let p = &mut particles[k];
                if v < p.best_val {
                    p.best_val = v;
                    p.best_pos.copy_from_slice(&p.pos);
                }
                if v < gbest_val {
                    gbest_val = v;
                    gbest_pos.clear();
                    gbest_pos.extend((0..ndim).map(|d| tuning.space().digit(cand[k], d) as f64));
                }
            }
            if vals.len() < cand.len() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{quality, run_optimizer};
    use super::super::HyperParams;
    use super::*;

    #[test]
    fn defaults() {
        let p = Pso::new(&HyperParams::new());
        assert_eq!(p.popsize, 20);
        assert_eq!(p.c1, 2.0);
    }

    #[test]
    fn finds_good_configs() {
        let trace = run_optimizer("pso", &HyperParams::new(), 100, 23);
        assert!(quality(&trace) > 0.4, "q={}", quality(&trace));
    }

    #[test]
    fn coefficients_change_behavior() {
        let a = run_optimizer("pso", &HyperParams::new().set("c1", 0.1), 60, 3);
        let b = run_optimizer("pso", &HyperParams::new().set("c1", 3.0), 60, 3);
        let sa: Vec<usize> = a.points.iter().map(|p| p.config).collect();
        let sb: Vec<usize> = b.points.iter().map(|p| p.config).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn tiny_popsize_still_works() {
        let hp = HyperParams::new().set("popsize", 2i64).set("maxiter", 20i64);
        let trace = run_optimizer("pso", &hp, 45, 7);
        assert!(trace.best().is_some());
    }
}
