//! Dual annealing (Table III hyperparameter: `method`).
//!
//! scipy-style dual annealing: a generalized-annealing global phase that
//! makes heavy-tailed jumps in the encoded (value-index) space, plus a
//! local-search phase triggered on improvement. The `method`
//! hyperparameter selects among eight local-search strategies named after
//! scipy's minimizers; each is a distinct discrete-lattice adaptation with
//! genuinely different behavior, so the categorical hyperparameter has
//! real signal (what the paper's tuning exploits):
//!
//! * `COBYLA`       — coordinate descent with a shrinking trust radius
//! * `L-BFGS-B`     — finite-difference descent, all dimensions stepped at once
//! * `SLSQP`        — sequential per-dimension descent with line probes
//! * `CG`           — direction-persistent descent (momentum along last move)
//! * `Powell`       — exhaustive line search per dimension, cycled
//! * `Nelder-Mead`  — simplex reflect/expand/contract on the lattice
//! * `BFGS`         — adaptive-step descent with step doubling on success
//! * `trust-constr` — random probes in a shrinking L1 ball

use super::localsearch::{self, DescentRule};
use super::schema::{self, Descriptor, HyperSchema};
use super::{relative_delta, HyperParams, Optimizer};
use crate::runner::Tuning;
use crate::searchspace::{Neighborhood, SearchSpace};
use crate::util::rng::Rng;

pub const LOCAL_METHODS: [&str; 8] = [
    "COBYLA",
    "L-BFGS-B",
    "SLSQP",
    "CG",
    "Powell",
    "Nelder-Mead",
    "BFGS",
    "trust-constr",
];

/// Registry entry. Only the categorical `method` is hypertuned (Table III);
/// the annealing-schedule knobs keep scipy's defaults and are excluded
/// from the extended space, as in the paper.
pub fn descriptor() -> Descriptor {
    Descriptor {
        name: "dual_annealing",
        paper: true,
        schema: vec![
            HyperSchema::str("method", "Powell", &LOCAL_METHODS)
                .limited(schema::strs(&LOCAL_METHODS)),
            HyperSchema::float("initial_temp", 5230.0),
            HyperSchema::float("restart_temp_ratio", 2e-5),
        ],
        build: |hp| Ok(Box::new(DualAnnealing::new(hp))),
    }
}

pub struct DualAnnealing {
    pub method: String,
    /// Initial global-phase temperature (scipy's `initial_temp` analogue).
    pub temp: f64,
    /// Restart threshold: reanneal when temperature decays below this.
    pub restart_temp_ratio: f64,
}

impl DualAnnealing {
    pub fn new(hp: &HyperParams) -> DualAnnealing {
        DualAnnealing {
            method: hp.str("method", "Powell"),
            temp: hp.f64("initial_temp", 5230.0),
            restart_temp_ratio: hp.f64("restart_temp_ratio", 2e-5),
        }
    }
}

impl Optimizer for DualAnnealing {
    fn name(&self) -> &'static str {
        "dual_annealing"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        // Reusable jump-target scratch: one allocation per run, not per step.
        let mut jump = Vec::with_capacity(dims.len());
        while !tuning.done() {
            // --- (re)anneal from a fresh random point -----------------------
            let mut current = tuning.space().random(rng);
            let mut current_val = tuning.eval(current);
            let mut best_val = current_val;
            let mut step = 0u32;
            let mut temp = self.temp;
            let t_restart = self.temp * self.restart_temp_ratio;
            while temp > t_restart && !tuning.done() {
                // Generalized-annealing visit: heavy-tailed jump size.
                let cand = heavy_tailed_jump(
                    tuning.space(),
                    current,
                    &dims,
                    temp / self.temp,
                    rng,
                    &mut jump,
                );
                let cand_val = tuning.eval(cand);
                let delta = relative_delta(cand_val, current_val);
                let accept = -delta * (1.0 + step as f64 / 50.0) / (temp / self.temp).max(1e-12);
                if delta <= 0.0 || rng.next_f64() < accept.exp() {
                    current = cand;
                    current_val = cand_val;
                }
                if cand_val < best_val {
                    best_val = cand_val;
                    // Local-search phase on improvement.
                    let (li, lv) = local_search(
                        &self.method,
                        tuning,
                        cand,
                        cand_val,
                        rng,
                    );
                    if lv < current_val {
                        current = li;
                        current_val = lv;
                        best_val = best_val.min(lv);
                    }
                }
                step += 1;
                // scipy's visiting-distribution temperature schedule ~ t0 / log-ish;
                // geometric decay is a faithful discrete stand-in.
                temp *= 0.95;
            }
        }
    }
}

/// Heavy-tailed jump: each dimension moves with probability ~temp-scaled,
/// by a geometric step length (long jumps early, short late). `target` is
/// a caller-owned scratch buffer reused across steps.
fn heavy_tailed_jump(
    space: &SearchSpace,
    from: usize,
    dims: &[usize],
    temp_frac: f64,
    rng: &mut Rng,
    target: &mut Vec<f64>,
) -> usize {
    target.clear();
    target.extend((0..dims.len()).map(|d| space.digit(from, d) as f64));
    let p_move = 0.3 + 0.5 * temp_frac;
    let mut moved = false;
    for (d, t) in target.iter_mut().enumerate() {
        if rng.next_f64() < p_move {
            // Geometric step: mostly 1, occasionally far.
            let mut len = 1usize;
            while rng.next_f64() < 0.35 + 0.4 * temp_frac {
                len += 1;
            }
            let dir = if rng.chance(0.5) { 1.0 } else { -1.0 };
            *t = (*t + dir * len as f64).clamp(0.0, (dims[d] - 1) as f64);
            moved = true;
        }
    }
    if !moved {
        return space.random_neighbor(from, Neighborhood::Hamming, rng);
    }
    space.snap(target, rng)
}

/// Dispatch to the selected local-search method. Returns the best
/// (index, value) found.
pub fn local_search(
    method: &str,
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    rng: &mut Rng,
) -> (usize, f64) {
    match method {
        "COBYLA" => cobyla(tuning, start, start_val, rng),
        "L-BFGS-B" => lbfgsb(tuning, start, start_val, rng),
        "SLSQP" => slsqp(tuning, start, start_val),
        "CG" => cg(tuning, start, start_val, rng),
        "Powell" => powell(tuning, start, start_val),
        "Nelder-Mead" => nelder_mead(tuning, start, start_val, rng),
        "BFGS" => bfgs(tuning, start, start_val, rng),
        "trust-constr" => trust_constr(tuning, start, start_val, rng),
        _ => greedy_descent(tuning, start, start_val, rng),
    }
}

/// Try to move config `base` by `delta` along dimension `d`; returns
/// Some((idx, val)) if the move lands on a valid config. One packed-rank
/// stride-delta — no encoded-vector clone.
fn probe(
    tuning: &mut Tuning<'_>,
    base: usize,
    d: usize,
    delta: i64,
) -> Option<(usize, f64)> {
    let cand = {
        let space = tuning.space();
        let next = space.digit(base, d) as i64 + delta;
        if next < 0 || next >= space.dims()[d] as i64 {
            return None;
        }
        space.with_dim(base, d, next as u16)?
    };
    let v = tuning.eval(cand);
    Some((cand, v))
}

/// COBYLA stand-in: coordinate descent with a shrinking trust radius.
fn cobyla(tuning: &mut Tuning<'_>, start: usize, start_val: f64, rng: &mut Rng) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let mut radius = 3i64;
    let (mut best, mut best_val) = (start, start_val);
    while radius >= 1 && !tuning.done() {
        let mut improved = false;
        let mut order: Vec<usize> = (0..ndim).collect();
        rng.shuffle(&mut order);
        for &d in &order {
            if tuning.done() {
                break;
            }
            let base = best;
            for delta in [-radius, radius] {
                if let Some((i, v)) = probe(tuning, base, d, delta) {
                    if v < best_val {
                        best = i;
                        best_val = v;
                        improved = true;
                    }
                }
            }
        }
        if !improved {
            radius /= 2;
        }
    }
    (best, best_val)
}

/// L-BFGS-B stand-in: finite-difference "gradient", step all dims at once.
fn lbfgsb(tuning: &mut Tuning<'_>, start: usize, start_val: f64, rng: &mut Rng) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let (mut best, mut best_val) = (start, start_val);
    for _ in 0..4 {
        if tuning.done() {
            break;
        }
        let base = best;
        let mut grad = vec![0i64; ndim];
        for d in 0..ndim {
            if tuning.done() {
                break;
            }
            let up = probe(tuning, base, d, 1).map(|(_, v)| v).unwrap_or(f64::INFINITY);
            let down = probe(tuning, base, d, -1).map(|(_, v)| v).unwrap_or(f64::INFINITY);
            grad[d] = if up < best_val && up <= down {
                1
            } else if down < best_val {
                -1
            } else {
                0
            };
        }
        if grad.iter().all(|&g| g == 0) {
            break;
        }
        let target: Vec<f64> = (0..ndim)
            .map(|d| tuning.space().digit(base, d) as f64 + grad[d] as f64)
            .collect();
        let idx = tuning.space().snap(&target, rng);
        let v = tuning.eval(idx);
        if v < best_val {
            best = idx;
            best_val = v;
        } else {
            break;
        }
    }
    (best, best_val)
}

/// SLSQP stand-in: sequential per-dimension descent, ±1 then ±2 probes.
fn slsqp(tuning: &mut Tuning<'_>, start: usize, start_val: f64) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let (mut best, mut best_val) = (start, start_val);
    for d in 0..ndim {
        if tuning.done() {
            break;
        }
        loop {
            let base = best;
            let mut step_taken = false;
            for delta in [-1i64, 1, -2, 2] {
                if tuning.done() {
                    break;
                }
                if let Some((i, v)) = probe(tuning, base, d, delta) {
                    if v < best_val {
                        best = i;
                        best_val = v;
                        step_taken = true;
                        break;
                    }
                }
            }
            if !step_taken {
                break;
            }
        }
    }
    (best, best_val)
}

/// CG stand-in: remembers the last improving direction and re-applies it.
fn cg(tuning: &mut Tuning<'_>, start: usize, start_val: f64, rng: &mut Rng) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let (mut best, mut best_val) = (start, start_val);
    let mut momentum: Option<(usize, i64)> = None;
    for _ in 0..3 * ndim {
        if tuning.done() {
            break;
        }
        let base = best;
        // Try momentum first.
        if let Some((d, delta)) = momentum {
            if let Some((i, v)) = probe(tuning, base, d, delta) {
                if v < best_val {
                    best = i;
                    best_val = v;
                    continue;
                }
            }
            momentum = None;
        }
        let d = rng.below(ndim);
        let delta = if rng.chance(0.5) { 1 } else { -1 };
        if let Some((i, v)) = probe(tuning, base, d, delta) {
            if v < best_val {
                best = i;
                best_val = v;
                momentum = Some((d, delta));
            }
        }
    }
    (best, best_val)
}

/// Powell: full line search along each dimension, cycled until no change.
/// Every probe on a line shares one fixed base and consumes no RNG, so the
/// whole line is served by a single batched evaluation and folded in
/// order — bit-identical to the scalar probe loop, including mid-line
/// budget truncation.
fn powell(tuning: &mut Tuning<'_>, start: usize, start_val: f64) -> (usize, f64) {
    let dims: Vec<usize> = tuning.space().dims().to_vec();
    let (mut best, mut best_val) = (start, start_val);
    let mut cand: Vec<usize> = Vec::new();
    let mut improved = true;
    while improved && !tuning.done() {
        improved = false;
        for d in 0..dims.len() {
            if tuning.done() {
                break;
            }
            let base = best;
            let orig = tuning.space().digit(base, d);
            cand.clear();
            for v_idx in 0..dims[d] as u16 {
                if v_idx == orig {
                    continue;
                }
                // One stride-delta per probe; no encoded-vector clones in
                // the line search.
                if let Some(i) = tuning.space().with_dim(base, d, v_idx) {
                    cand.push(i);
                }
            }
            let vals = tuning.eval_batch(&cand);
            for (k, &v) in vals.iter().enumerate() {
                if v < best_val {
                    best = cand[k];
                    best_val = v;
                    improved = true;
                }
            }
        }
    }
    (best, best_val)
}

/// Nelder–Mead: lattice simplex with reflect / expand / shrink.
fn nelder_mead(
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    rng: &mut Rng,
) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    // Simplex of ndim+1 points around the start.
    let mut simplex: Vec<(usize, f64)> = vec![(start, start_val)];
    for _ in 0..ndim.min(6) {
        if tuning.done() {
            break;
        }
        let p = tuning.space().random_neighbor(start, Neighborhood::Hamming, rng);
        let v = tuning.eval(p);
        simplex.push((p, v));
    }
    for _ in 0..2 * ndim {
        if tuning.done() || simplex.len() < 3 {
            break;
        }
        simplex.sort_by(|a, b| a.1.total_cmp(&b.1));
        // lint: allow(W03, reason = "simplex always has dim+1 vertices")
        let worst = simplex.last().unwrap().0;
        // Centroid of all but worst, reflected through the worst point.
        let ndims = tuning.space().dims().len();
        let mut centroid = vec![0.0f64; ndims];
        for (i, _) in &simplex[..simplex.len() - 1] {
            for (d, c) in centroid.iter_mut().enumerate() {
                *c += tuning.space().digit(*i, d) as f64;
            }
        }
        for c in centroid.iter_mut() {
            *c /= (simplex.len() - 1) as f64;
        }
        let reflected: Vec<f64> = centroid
            .iter()
            .enumerate()
            .map(|(d, &c)| 2.0 * c - tuning.space().digit(worst, d) as f64)
            .collect();
        let r_idx = tuning.space().snap(&reflected, rng);
        let r_val = tuning.eval(r_idx);
        let last = simplex.len() - 1;
        if r_val < simplex[last].1 {
            simplex[last] = (r_idx, r_val);
        } else {
            // Shrink toward the best.
            let best_enc: Vec<f64> = (0..ndims)
                .map(|d| tuning.space().digit(simplex[0].0, d) as f64)
                .collect();
            for item in simplex.iter_mut().skip(1) {
                if tuning.done() {
                    break;
                }
                let target: Vec<f64> = best_enc
                    .iter()
                    .enumerate()
                    .map(|(d, &b)| (tuning.space().digit(item.0, d) as f64 + b) / 2.0)
                    .collect();
                let idx = tuning.space().snap(&target, rng);
                let v = tuning.eval(idx);
                *item = (idx, v);
            }
        }
    }
    simplex
        .into_iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .unwrap_or((start, start_val))
}

/// BFGS stand-in: descent direction with step doubling while improving.
fn bfgs(tuning: &mut Tuning<'_>, start: usize, start_val: f64, rng: &mut Rng) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let (mut best, mut best_val) = (start, start_val);
    for _ in 0..ndim {
        if tuning.done() {
            break;
        }
        let base = best;
        let d = rng.below(ndim);
        // Find improving direction.
        let mut dir = 0i64;
        for delta in [1i64, -1] {
            if let Some((i, v)) = probe(tuning, base, d, delta) {
                if v < best_val {
                    best = i;
                    best_val = v;
                    dir = delta;
                    break;
                }
            }
            if tuning.done() {
                return (best, best_val);
            }
        }
        // Double the step while it keeps improving.
        let mut step = 2i64;
        while dir != 0 && !tuning.done() {
            match probe(tuning, best, d, dir * step) {
                Some((i, v)) if v < best_val => {
                    best = i;
                    best_val = v;
                    step *= 2;
                }
                _ => break,
            }
        }
    }
    (best, best_val)
}

/// trust-constr stand-in: random probes in a shrinking L1 ball.
fn trust_constr(
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    rng: &mut Rng,
) -> (usize, f64) {
    let ndim = tuning.space().dims().len();
    let dims: Vec<usize> = tuning.space().dims().to_vec();
    let (mut best, mut best_val) = (start, start_val);
    let mut radius = 4.0f64;
    let mut target: Vec<f64> = Vec::with_capacity(ndim);
    while radius >= 1.0 && !tuning.done() {
        let mut improved = false;
        for _ in 0..2 * ndim {
            if tuning.done() {
                break;
            }
            target.clear();
            target.extend((0..ndim).map(|d| tuning.space().digit(best, d) as f64));
            let mut remaining = radius;
            while remaining >= 1.0 {
                let d = rng.below(ndim);
                let dir = if rng.chance(0.5) { 1.0 } else { -1.0 };
                target[d] = (target[d] + dir).clamp(0.0, (dims[d] - 1) as f64);
                remaining -= 1.0;
            }
            let idx = tuning.space().snap(&target, rng);
            let v = tuning.eval(idx);
            if v < best_val {
                best = idx;
                best_val = v;
                improved = true;
            }
        }
        if !improved {
            radius /= 2.0;
        }
    }
    (best, best_val)
}

/// Plain greedy fallback for unknown method names (unreachable through
/// the registry, which validates `method` against the schema choices, but
/// kept for direct construction): shared best-improvement descent over
/// the adjacent CSR neighborhood.
fn greedy_descent(
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    rng: &mut Rng,
) -> (usize, f64) {
    let mut ns: Vec<usize> = Vec::new();
    localsearch::descend(
        tuning,
        start,
        start_val,
        Neighborhood::Adjacent,
        DescentRule::BestImprovement,
        false,
        rng,
        &mut ns,
    )
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{quality, run_optimizer};
    use super::super::HyperParams;
    use super::*;

    #[test]
    fn all_methods_work() {
        for m in LOCAL_METHODS {
            let hp = HyperParams::new().set("method", m);
            let trace = run_optimizer("dual_annealing", &hp, 70, 21);
            assert!(trace.unique_evals <= 70, "{m}");
            assert!(quality(&trace) > 0.3, "{m}: q={}", quality(&trace));
        }
    }

    #[test]
    fn methods_differ_behaviorally() {
        // Different local methods must visit different configuration
        // sequences given the same seed.
        let mut signatures = std::collections::HashSet::new();
        for m in LOCAL_METHODS {
            let hp = HyperParams::new().set("method", m);
            let trace = run_optimizer("dual_annealing", &hp, 60, 17);
            let sig: Vec<usize> = trace.points.iter().map(|p| p.config).collect();
            signatures.insert(sig);
        }
        assert!(
            signatures.len() >= 6,
            "only {} distinct behaviors",
            signatures.len()
        );
    }

    #[test]
    fn default_is_powell() {
        let da = DualAnnealing::new(&HyperParams::new());
        assert_eq!(da.method, "Powell");
    }
}
