//! Additional optimization algorithms beyond the paper's four: Kernel
//! Tuner ships 20+ strategies, and carrying a broader registry exercises
//! the hyperparameter machinery's generality (any registered optimizer can
//! be hypertuned or used as a meta-strategy).

use super::localsearch::{self, DescentRule};
use super::schema::{self, Descriptor, HyperSchema};
use super::{relative_delta, HyperParams, Optimizer};
use crate::runner::Tuning;
use crate::searchspace::Neighborhood;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Differential evolution

/// Registry entry for DE (kept outside any Table III/IV space).
pub fn differential_evolution_descriptor() -> Descriptor {
    Descriptor {
        name: "differential_evolution",
        paper: false,
        schema: vec![
            HyperSchema::int("popsize", 20),
            HyperSchema::float("F", 0.7),
            HyperSchema::float("CR", 0.6),
        ],
        build: |hp| Ok(Box::new(DifferentialEvolution::new(hp))),
    }
}

/// DE/rand/1/bin adapted to the lattice.
pub struct DifferentialEvolution {
    pub popsize: usize,
    pub f: f64,
    pub cr: f64,
}

impl DifferentialEvolution {
    pub fn new(hp: &HyperParams) -> DifferentialEvolution {
        DifferentialEvolution {
            popsize: hp.usize("popsize", 20).max(4),
            f: hp.f64("F", 0.7),
            cr: hp.f64("CR", 0.6),
        }
    }
}

impl Optimizer for DifferentialEvolution {
    fn name(&self) -> &'static str {
        "differential_evolution"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        let ndim = dims.len();
        let n = tuning.space().len();
        let init = tuning.space().sample(rng, self.popsize.min(n));
        let vals: Vec<f64> = tuning.eval_batch(&init).to_vec();
        let mut pop: Vec<(usize, f64)> =
            init.iter().zip(&vals).map(|(&i, &v)| (i, v)).collect();
        if pop.len() < init.len() {
            return;
        }
        // Reusable mutant-vector and trial-batch scratch.
        let mut target = vec![0.0f64; ndim];
        let mut cand: Vec<usize> = Vec::with_capacity(pop.len());
        loop {
            if tuning.done() {
                return;
            }
            // Generational sweep: every trial vector is built against the
            // generation-start population snapshot, then the whole set is
            // served by one batched evaluation; selection follows.
            cand.clear();
            for i in 0..pop.len() {
                // Three distinct others.
                let (a, b, c) = {
                    let mut picks = rng.sample_indices(pop.len(), 3.min(pop.len()));
                    while picks.len() < 3 {
                        picks.push(rng.below(pop.len()));
                    }
                    (picks[0], picks[1], picks[2])
                };
                {
                    // Read parent genes digit-by-digit (works whether or
                    // not the flat buffer is materialized); the borrow
                    // ends before snap() needs the rng.
                    let space = tuning.space();
                    let (ia, ib, ic, ix) = (pop[a].0, pop[b].0, pop[c].0, pop[i].0);
                    let jrand = rng.below(ndim);
                    for d in 0..ndim {
                        target[d] = if d == jrand || rng.chance(self.cr) {
                            (space.digit(ia, d) as f64
                                + self.f
                                    * (space.digit(ib, d) as f64 - space.digit(ic, d) as f64))
                                .clamp(0.0, (dims[d] - 1) as f64)
                        } else {
                            space.digit(ix, d) as f64
                        };
                    }
                }
                cand.push(tuning.space().snap(&target, rng));
            }
            let vals: Vec<f64> = tuning.eval_batch(&cand).to_vec();
            for (i, &v) in vals.iter().enumerate() {
                if v < pop[i].1 {
                    pop[i] = (cand[i], v);
                }
            }
            if vals.len() < cand.len() {
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Basin hopping

/// Registry entry for basin hopping. Declares `limited` grids (ROADMAP:
/// meta-strategy sweep over the full registry), so a derived
/// hyperparameter space exists — `Descriptor::paper` stays false, keeping
/// the paper-replication drivers pinned to the original four.
pub fn basin_hopping_descriptor() -> Descriptor {
    Descriptor {
        name: "basin_hopping",
        paper: false,
        schema: vec![
            HyperSchema::float("T", 1.0).limited(schema::floats(&[0.5, 1.0, 1.5])),
            HyperSchema::int("perturbation", 2).limited(schema::ints(&[1, 2, 3])),
        ],
        build: |hp| Ok(Box::new(BasinHopping::new(hp))),
    }
}

/// Greedy local descent + temperature-accepted random kicks.
pub struct BasinHopping {
    pub t: f64,
    pub perturbation: usize,
}

impl BasinHopping {
    pub fn new(hp: &HyperParams) -> BasinHopping {
        BasinHopping {
            t: hp.f64("T", 1.0).max(1e-6),
            perturbation: hp.usize("perturbation", 2).max(1),
        }
    }
}

impl Optimizer for BasinHopping {
    fn name(&self) -> &'static str {
        "basin_hopping"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        let mut current = tuning.space().random(rng);
        let mut current_val = tuning.eval(current);
        // Reusable scratch: neighbor list for descent, kick target.
        let mut ns: Vec<usize> = Vec::new();
        let mut target: Vec<f64> = Vec::with_capacity(dims.len());
        while !tuning.done() {
            // Local descent to the basin floor.
            let (li, lv) = descend(tuning, current, current_val, rng, &mut ns);
            if lv < current_val {
                current = li;
                current_val = lv;
            }
            if tuning.done() {
                break;
            }
            // Kick: perturb `perturbation` dimensions.
            target.clear();
            target.extend((0..dims.len()).map(|d| tuning.space().digit(current, d) as f64));
            for _ in 0..self.perturbation {
                let d = rng.below(dims.len());
                target[d] = rng.below(dims[d]) as f64;
            }
            let idx = tuning.space().snap(&target, rng);
            let v = tuning.eval(idx);
            let delta = relative_delta(v, current_val);
            if delta <= 0.0 || rng.next_f64() < (-delta / self.t).exp() {
                current = idx;
                current_val = v;
            }
        }
    }
}

/// Greedy shuffled first-improvement descent over the adjacent CSR
/// neighborhood — the shared engine configured the way basin hopping and
/// greedy ILS walk their basins. `ns` is a caller-owned neighbor buffer
/// reused across descents.
fn descend(
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    rng: &mut Rng,
    ns: &mut Vec<usize>,
) -> (usize, f64) {
    localsearch::descend(
        tuning,
        start,
        start_val,
        Neighborhood::Adjacent,
        DescentRule::FirstImprovement,
        true,
        rng,
        ns,
    )
}

// ---------------------------------------------------------------------------
// Multi-start local search

/// Registry entry for multi-start local search.
pub fn mls_descriptor() -> Descriptor {
    Descriptor {
        name: "mls",
        paper: false,
        schema: vec![HyperSchema::str(
            "neighborhood",
            "Hamming",
            &["Hamming", "Adjacent"],
        )],
        build: |hp| Ok(Box::new(Mls::new(hp))),
    }
}

/// Repeated best-improvement hill descent from random starts.
pub struct Mls {
    pub neighborhood: Neighborhood,
}

impl Mls {
    pub fn new(hp: &HyperParams) -> Mls {
        // Case-insensitive for direct construction (the registry path is
        // stricter: create() only admits the schema's exact choices).
        let hood = if hp
            .str("neighborhood", "Hamming")
            .eq_ignore_ascii_case("adjacent")
        {
            Neighborhood::Adjacent
        } else {
            Neighborhood::Hamming
        };
        Mls { neighborhood: hood }
    }
}

impl Optimizer for Mls {
    fn name(&self) -> &'static str {
        "mls"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        // Reusable neighbor buffer across descents and restarts.
        let mut ns: Vec<usize> = Vec::new();
        while !tuning.done() {
            let start = tuning.space().random(rng);
            let start_val = tuning.eval(start);
            localsearch::descend(
                tuning,
                start,
                start_val,
                self.neighborhood,
                DescentRule::BestImprovement,
                false,
                rng,
                &mut ns,
            );
            // Local optimum (or budget): restart from a fresh random point.
        }
    }
}

// ---------------------------------------------------------------------------
// Greedy iterated local search

/// Registry entry for greedy iterated local search. Like basin hopping,
/// carries `limited` grids so the hypertuner can derive its space without
/// joining the paper's Table III set.
pub fn greedy_ils_descriptor() -> Descriptor {
    Descriptor {
        name: "greedy_ils",
        paper: false,
        schema: vec![
            HyperSchema::int("perturbation", 1).limited(schema::ints(&[1, 2, 3])),
            HyperSchema::int("restart", 5).limited(schema::ints(&[3, 5, 10])),
        ],
        build: |hp| Ok(Box::new(GreedyIls::new(hp))),
    }
}

/// Greedy descent + bounded perturbation, restarting from the incumbent.
pub struct GreedyIls {
    pub perturbation: usize,
    /// Restart from scratch when no improvement for this many kicks.
    pub restart: usize,
}

impl GreedyIls {
    pub fn new(hp: &HyperParams) -> GreedyIls {
        GreedyIls {
            perturbation: hp.usize("perturbation", 1).max(1),
            restart: hp.usize("restart", 5).max(1),
        }
    }
}

impl Optimizer for GreedyIls {
    fn name(&self) -> &'static str {
        "greedy_ils"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        let mut ns: Vec<usize> = Vec::new();
        let mut target: Vec<f64> = Vec::with_capacity(dims.len());
        'outer: while !tuning.done() {
            let mut incumbent = tuning.space().random(rng);
            let mut incumbent_val = tuning.eval(incumbent);
            let mut stale = 0usize;
            while stale < self.restart {
                if tuning.done() {
                    break 'outer;
                }
                let (li, lv) = descend(tuning, incumbent, incumbent_val, rng, &mut ns);
                if lv < incumbent_val {
                    incumbent = li;
                    incumbent_val = lv;
                    stale = 0;
                } else {
                    stale += 1;
                }
                if tuning.done() {
                    break 'outer;
                }
                // Kick the incumbent.
                target.clear();
                target
                    .extend((0..dims.len()).map(|d| tuning.space().digit(incumbent, d) as f64));
                for _ in 0..self.perturbation {
                    let d = rng.below(dims.len());
                    target[d] = rng.below(dims[d]) as f64;
                }
                let idx = tuning.space().snap(&target, rng);
                let v = tuning.eval(idx);
                if v < incumbent_val {
                    incumbent = idx;
                    incumbent_val = v;
                    stale = 0;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Firefly algorithm

/// Registry entry for the firefly algorithm.
pub fn firefly_descriptor() -> Descriptor {
    Descriptor {
        name: "firefly",
        paper: false,
        schema: vec![
            HyperSchema::int("popsize", 15),
            HyperSchema::int("maxiter", 100),
            HyperSchema::float("beta0", 1.0),
            HyperSchema::float("gamma", 0.1),
            HyperSchema::float("alpha", 0.3),
        ],
        build: |hp| Ok(Box::new(Firefly::new(hp))),
    }
}

/// Fireflies move toward brighter (better) ones with distance-attenuated
/// attraction plus a random walk.
pub struct Firefly {
    pub popsize: usize,
    pub maxiter: usize,
    pub beta0: f64,
    pub gamma: f64,
    pub alpha: f64,
}

impl Firefly {
    pub fn new(hp: &HyperParams) -> Firefly {
        Firefly {
            popsize: hp.usize("popsize", 15).max(2),
            maxiter: hp.usize("maxiter", 100).max(1),
            beta0: hp.f64("beta0", 1.0),
            gamma: hp.f64("gamma", 0.1),
            alpha: hp.f64("alpha", 0.3),
        }
    }
}

impl Optimizer for Firefly {
    fn name(&self) -> &'static str {
        "firefly"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let dims: Vec<usize> = tuning.space().dims().to_vec();
        let ndim = dims.len();
        let n = tuning.space().len();
        // positions + brightness (negated value: higher is better)
        let mut pos: Vec<Vec<f64>> = Vec::new();
        let mut val: Vec<f64> = Vec::new();
        let init = tuning.space().sample(rng, self.popsize.min(n));
        let vals: Vec<f64> = tuning.eval_batch(&init).to_vec();
        for (k, &v) in vals.iter().enumerate() {
            pos.push((0..ndim).map(|d| tuning.space().digit(init[k], d) as f64).collect());
            val.push(v);
        }
        if vals.len() < init.len() {
            return;
        }
        let m = pos.len();
        // Reusable move-target and move-batch scratch.
        let mut target = vec![0.0f64; ndim];
        let mut movers: Vec<usize> = Vec::new();
        let mut cand: Vec<usize> = Vec::new();
        for _iter in 0..self.maxiter {
            if tuning.done() {
                return;
            }
            // Synchronous sweep: attractions are computed against the
            // iteration-start brightness/position snapshot, every move is
            // drawn, and the whole set is served by one batched
            // evaluation before any firefly advances.
            movers.clear();
            cand.clear();
            for i in 0..m {
                for j in 0..m {
                    if !(val[j] < val[i]) {
                        continue; // j not brighter
                    }
                    let r2: f64 = pos[i]
                        .iter()
                        .zip(&pos[j])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    let beta = self.beta0 * (-self.gamma * r2).exp();
                    for d in 0..ndim {
                        let step = beta * (pos[j][d] - pos[i][d])
                            + self.alpha * rng.range_f64(-1.0, 1.0) * dims[d] as f64 / 8.0;
                        target[d] = (pos[i][d] + step).clamp(0.0, (dims[d] - 1) as f64);
                    }
                    movers.push(i);
                    cand.push(tuning.space().snap(&target, rng));
                }
            }
            let vals: Vec<f64> = tuning.eval_batch(&cand).to_vec();
            for (k, &v) in vals.iter().enumerate() {
                let i = movers[k];
                if v < val[i] {
                    val[i] = v;
                    pos[i].clear();
                    pos[i].extend((0..ndim).map(|d| tuning.space().digit(cand[k], d) as f64));
                }
            }
            if vals.len() < cand.len() {
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{quality, run_optimizer};
    use super::super::HyperParams;

    #[test]
    fn de_quality() {
        let trace = run_optimizer("differential_evolution", &HyperParams::new(), 90, 41);
        assert!(quality(&trace) > 0.4, "q={}", quality(&trace));
    }

    #[test]
    fn basin_hopping_quality() {
        let trace = run_optimizer("basin_hopping", &HyperParams::new(), 90, 43);
        assert!(quality(&trace) > 0.4, "q={}", quality(&trace));
    }

    #[test]
    fn mls_visits_neighbors() {
        let trace = run_optimizer("mls", &HyperParams::new(), 60, 47);
        assert!(quality(&trace) > 0.4, "q={}", quality(&trace));
    }

    #[test]
    fn ils_perturbation_matters() {
        let a = run_optimizer("greedy_ils", &HyperParams::new().set("perturbation", 1i64), 60, 3);
        let b = run_optimizer("greedy_ils", &HyperParams::new().set("perturbation", 4i64), 60, 3);
        let sa: Vec<usize> = a.points.iter().map(|p| p.config).collect();
        let sb: Vec<usize> = b.points.iter().map(|p| p.config).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn firefly_quality() {
        let trace = run_optimizer("firefly", &HyperParams::new(), 90, 53);
        assert!(quality(&trace) > 0.3, "q={}", quality(&trace));
    }
}
