//! Shared local-search descent engine.
//!
//! `dual_annealing`'s greedy fallback, `mls`, `greedy_ils` and
//! `basin_hopping` all used to carry their own copy of the same descent
//! loop. This module is the single implementation they program against;
//! it walks the precomputed CSR neighbor slices
//! ([`SearchSpace::neighbors`](crate::searchspace::SearchSpace::neighbors))
//! instead of re-probing the packed-rank index every pass, copying each
//! slice into a caller-owned scratch buffer so evaluations can interleave
//! with the borrow-checked `&mut Tuning`.

use crate::runner::Tuning;
use crate::searchspace::Neighborhood;
use crate::util::rng::Rng;

/// Which neighbor a descent pass moves to.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DescentRule {
    /// Move to the first improving neighbor found (stochastic descent when
    /// combined with shuffling).
    FirstImprovement,
    /// Evaluate the whole neighborhood and move to the best improvement.
    BestImprovement,
}

/// Descend from `(start, start_val)` until a local optimum or budget
/// exhaustion, returning the best `(index, value)` reached.
///
/// Each pass copies the incumbent's neighborhood into `ns` (a
/// caller-owned buffer reused across descents) — from the CSR slice on
/// spaces small enough for the graph to amortize, else by probing —
/// optionally shuffles it (`shuffle` — `rng` is untouched otherwise,
/// preserving RNG streams), then evaluates neighbors under `rule`. Both
/// fill paths produce the identical visitor order, so the choice never
/// changes a trajectory and refactored callers keep theirs.
#[allow(clippy::too_many_arguments)]
pub fn descend(
    tuning: &mut Tuning<'_>,
    start: usize,
    start_val: f64,
    hood: Neighborhood,
    rule: DescentRule,
    shuffle: bool,
    rng: &mut Rng,
    ns: &mut Vec<usize>,
) -> (usize, f64) {
    let (mut best, mut best_val) = (start, start_val);
    // CSR slices only where the one-time graph build amortizes; on bigger
    // spaces probe per pass (cost proportional to configs visited).
    let use_csr = tuning.space().csr_worthwhile();
    loop {
        if tuning.done() {
            return (best, best_val);
        }
        if use_csr {
            ns.clear();
            ns.extend(
                tuning
                    .space()
                    .neighbors(best, hood)
                    .iter()
                    .map(|&n| n as usize),
            );
        } else {
            tuning.space().neighbors_into(best, hood, ns);
        }
        if shuffle {
            rng.shuffle(ns);
        }
        // `best`/`best_val` move in lockstep so an early (budget) return
        // never pairs the old incumbent with a newer neighbor's value.
        let mut improved = false;
        for i in 0..ns.len() {
            if tuning.done() {
                return (best, best_val);
            }
            let n = ns[i];
            let v = tuning.eval(n);
            if v < best_val {
                best = n;
                best_val = v;
                improved = true;
                if rule == DescentRule::FirstImprovement {
                    break;
                }
            }
        }
        if !improved {
            return (best, best_val); // local optimum
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizers::testutil::synthetic_cache;
    use crate::runner::{Budget, SimulationRunner, Tuning};
    use std::sync::Arc;

    fn with_tuning(evals: usize, f: impl FnOnce(&mut Tuning<'_>)) {
        let (space, cache) = synthetic_cache();
        let mut sim = SimulationRunner::new(Arc::clone(&space), cache).unwrap();
        let mut tuning = Tuning::new(&mut sim, Budget::evals(evals));
        f(&mut tuning);
    }

    #[test]
    fn descent_never_worsens_and_reaches_local_optimum() {
        with_tuning(500, |tuning| {
            let mut rng = Rng::new(11);
            let mut ns = Vec::new();
            let start = tuning.space().random(&mut rng);
            let start_val = tuning.eval(start);
            let (best, best_val) = descend(
                tuning,
                start,
                start_val,
                Neighborhood::Adjacent,
                DescentRule::BestImprovement,
                false,
                &mut rng,
                &mut ns,
            );
            assert!(best_val <= start_val);
            if !tuning.done() {
                // Local optimum: no adjacent neighbor improves on it.
                let hood: Vec<usize> = tuning
                    .space()
                    .neighbors(best, Neighborhood::Adjacent)
                    .iter()
                    .map(|&n| n as usize)
                    .collect();
                for n in hood {
                    assert!(tuning.eval(n) >= best_val);
                }
            }
        });
    }

    /// On a 1-D monotone landscape the two rules' exact evaluation
    /// sequences are fully determined: first-improvement breaks at the
    /// first better neighbor each pass (re-probing earlier configs from
    /// the within-run cache), best-improvement scans each whole
    /// neighborhood once. Pins both traces end to end.
    #[test]
    fn first_improvement_breaks_where_best_scans_all() {
        use crate::dataset::cache::{CacheData, ConfigRecord};
        use crate::searchspace::{SearchSpace, TunableParam};

        let space = Arc::new(
            SearchSpace::build("ls", vec![TunableParam::new("a", vec![0i64, 1, 2, 3, 4])], vec![])
                .unwrap(),
        );
        let vals = [5.0, 4.0, 3.0, 2.0, 1.0];
        let records: Vec<ConfigRecord> = (0..space.len())
            .map(|i| ConfigRecord {
                key: space.key(i),
                value: vals[i],
                observations: vec![vals[i]],
                compile_time: 1.0,
                valid: true,
            })
            .collect();
        let cache = Arc::new(CacheData::new(
            "ls",
            "x",
            "",
            0,
            1,
            0.0,
            vec!["a".into()],
            records,
        ));
        let trace_for = |rule: DescentRule| {
            let mut sim =
                SimulationRunner::new_unchecked(Arc::clone(&space), Arc::clone(&cache));
            let mut tuning = Tuning::new(&mut sim, Budget::evals(10));
            let mut rng = Rng::new(1);
            let mut ns = Vec::new();
            let v0 = tuning.eval(0);
            let (best, best_val) = descend(
                &mut tuning,
                0,
                v0,
                Neighborhood::Hamming,
                rule,
                false,
                &mut rng,
                &mut ns,
            );
            assert_eq!((best, best_val), (4, 1.0));
            tuning
                .finish()
                .points
                .iter()
                .map(|p| p.config)
                .collect::<Vec<_>>()
        };
        let first = trace_for(DescentRule::FirstImprovement);
        let best = trace_for(DescentRule::BestImprovement);
        // Best-improvement: one full scan of 0's neighborhood finds 4.
        assert_eq!(best, vec![0, 1, 2, 3, 4]);
        // First-improvement: one step per pass, rescanning (cached)
        // earlier configs before reaching the next improvement.
        assert_eq!(first, vec![0, 1, 0, 2, 0, 1, 3, 0, 1, 2, 4]);
        assert_ne!(first, best);
    }
}
