//! Optimization algorithms with exposed hyperparameters.
//!
//! All algorithms program against [`Tuning`] (budget-tracked evaluations
//! with within-run caching) and take their hyperparameters through
//! [`HyperParams`], a string→value map with typed accessors — the
//! interface the hypertuner ("tuning the tuner") drives.
//!
//! Each algorithm *declares* its hyperparameters as a typed
//! [`schema::HyperSchema`] inside a [`schema::Descriptor`]; the
//! [`registry`] of descriptors is the single source of truth for names,
//! defaults, validation (unknown keys and type mismatches are hard errors
//! in [`create`]) and the Table III / Table IV hyperparameter search
//! spaces that [`crate::hypertuning::space`] derives from the declared
//! grids.
//!
//! Implemented algorithms (Kernel Tuner's spread of global + local
//! methods) and their schema defaults:
//!
//! | name                  | hyperparameters (schema defaults)                 |
//! |-----------------------|---------------------------------------------------|
//! | `random_search`       | —                                                 |
//! | `simulated_annealing` | `T`=1, `T_min`=0.001, `alpha`=0.995, `maxiter`=2  |
//! | `dual_annealing`      | `method`=Powell (8 local-search variants), `initial_temp`=5230, `restart_temp_ratio`=0.00002 |
//! | `genetic_algorithm`   | `method`=uniform (4 crossovers), `popsize`=20, `maxiter`=100, `mutation_chance`=10 |
//! | `pso`                 | `popsize`=20, `maxiter`=100, `c1`=2, `c2`=1, `w`=0.5 |
//! | `differential_evolution` | `popsize`=20, `F`=0.7, `CR`=0.6                |
//! | `basin_hopping`       | `T`=1, `perturbation`=2                           |
//! | `mls`                 | `neighborhood`=Hamming                            |
//! | `greedy_ils`          | `perturbation`=1, `restart`=5                     |
//! | `firefly`             | `popsize`=15, `maxiter`=100, `beta0`=1, `gamma`=0.1, `alpha`=0.3 |
//!
//! (This table is checked against the registry by the
//! `doc_table_matches_registry` test — regenerate it from
//! [`schema_table`] when schemas change.)

pub mod schema;
pub mod localsearch;
pub mod random;
pub mod annealing;
pub mod dual_annealing;
pub mod ga;
pub mod pso;
pub mod extras;

pub use schema::{Descriptor, HyperKind, HyperSchema};

use crate::runner::Tuning;
use crate::searchspace::{SearchSpace, Value};
use crate::util::rng::Rng;
use crate::error::Result;
use std::collections::BTreeMap;
use std::sync::OnceLock;

/// Hyperparameter assignment for an optimizer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HyperParams(pub BTreeMap<String, Value>);

impl HyperParams {
    pub fn new() -> HyperParams {
        HyperParams(BTreeMap::new())
    }

    pub fn set<V: Into<Value>>(mut self, key: &str, v: V) -> HyperParams {
        self.0.insert(key.to_string(), v.into());
        self
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.0.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.0
            .get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i.max(0) as usize)
            .unwrap_or(default)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.0
            .get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// Build from a configuration of a hyperparameter search space.
    pub fn from_space_config(space: &SearchSpace, idx: usize) -> HyperParams {
        HyperParams(space.named_values(idx).into_iter().collect())
    }

    /// Stable display string `k=v,k=v`.
    pub fn key(&self) -> String {
        self.0
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// An optimization algorithm.
pub trait Optimizer: Send + Sync {
    fn name(&self) -> &'static str;
    /// Run until the tuning budget is exhausted (or the algorithm's own
    /// iteration limits are reached). Must check `tuning.done()` between
    /// evaluations.
    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng);
}

/// The self-describing optimizer registry: one [`Descriptor`] per
/// algorithm, each declaring its typed hyperparameter schema. Built once;
/// registration order is the public `optimizer_names()` order.
pub fn registry() -> &'static [Descriptor] {
    static REGISTRY: OnceLock<Vec<Descriptor>> = OnceLock::new();
    REGISTRY.get_or_init(|| {
        vec![
            random::descriptor(),
            annealing::descriptor(),
            dual_annealing::descriptor(),
            ga::descriptor(),
            pso::descriptor(),
            extras::differential_evolution_descriptor(),
            extras::basin_hopping_descriptor(),
            extras::mls_descriptor(),
            extras::greedy_ils_descriptor(),
            extras::firefly_descriptor(),
        ]
    })
}

/// Look up a registered optimizer's descriptor by name.
pub fn descriptor(name: &str) -> Result<&'static Descriptor> {
    registry()
        .iter()
        .find(|d| d.name == name)
        .ok_or_else(|| crate::error::TuneError::UnknownAlgorithm {
            name: name.to_string(),
            known: optimizer_names().join(", "),
        })
}

/// All registered optimizer names, in registration order.
pub fn optimizer_names() -> Vec<&'static str> {
    registry().iter().map(|d| d.name).collect()
}

/// The four algorithms evaluated in the paper (`Descriptor::paper`), in
/// Table III (alphabetical) order. Deliberately flag-based: other
/// optimizers may declare Table III/IV grids to become hypertunable
/// without silently joining the paper-replication drivers.
pub fn paper_algorithms() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = registry()
        .iter()
        .filter(|d| d.paper)
        .map(|d| d.name)
        .collect();
    names.sort_unstable();
    names
}

/// Descriptors of every hypertunable optimizer — those declaring a
/// limited (Table III-style) grid, so a derived hyperparameter space
/// exists for them — in registration order. This is the set the
/// full-registry sweep (`hypertuning::sweep`) iterates: the paper four
/// plus extras such as `greedy_ils`/`basin_hopping`.
pub fn hypertunable() -> Vec<&'static Descriptor> {
    registry().iter().filter(|d| d.has_limited_space()).collect()
}

/// Names of the [`hypertunable`] optimizers, in registration order.
pub fn hypertunable_names() -> Vec<&'static str> {
    hypertunable().iter().map(|d| d.name).collect()
}

/// One-line-per-optimizer rendering of the registry (name plus
/// `key=default` pairs) — the source for the module-doc table and the
/// `tunetuner info` listing.
pub fn schema_table() -> String {
    let mut out = String::new();
    for d in registry() {
        let hps = if d.schema.is_empty() {
            "—".to_string()
        } else {
            d.schema
                .iter()
                .map(|s| format!("{}={}", s.name, s.default.key()))
                .collect::<Vec<_>>()
                .join(", ")
        };
        out.push_str(&format!("  {:<24} {hps}\n", d.name));
    }
    out
}

/// Instantiate an optimizer by name. The hyperparameters are resolved
/// against the optimizer's declared schema first: unknown keys, type
/// mismatches, and out-of-choice categoricals are hard errors (listing
/// the valid keys), and schema defaults are merged in for absent keys.
pub fn create(name: &str, hp: &HyperParams) -> Result<Box<dyn Optimizer>> {
    let d = descriptor(name)?;
    let resolved = d.resolve(hp)?;
    (d.build)(&resolved)
}

/// Relative acceptance scale for annealing-type methods: objective values
/// are kernel times (~1e-3 s), so acceptance tests use relative
/// differences to stay scale-invariant across search spaces.
pub(crate) fn relative_delta(new: f64, old: f64) -> f64 {
    if !old.is_finite() || !new.is_finite() {
        // Moving to/from an invalid config: strongly discouraged / neutral.
        return if new.is_finite() { -1.0 } else { 1.0 };
    }
    (new - old) / old
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::dataset::cache::CacheData;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::{Budget, LiveRunner, SimulationRunner, Trace, TuningScratch};
    use crate::runtime::Engine;
    use std::sync::Arc;
    use std::sync::OnceLock;

    /// Shared brute-forced synthetic space for optimizer tests.
    pub fn synthetic_cache() -> (Arc<crate::searchspace::SearchSpace>, Arc<CacheData>) {
        static CACHE: OnceLock<(Arc<crate::searchspace::SearchSpace>, Arc<CacheData>)> =
            OnceLock::new();
        CACHE
            .get_or_init(|| {
                let kernel = kernels::kernel_by_name("synthetic").unwrap();
                let mut live = LiveRunner::new(
                    kernels::kernel_by_name("synthetic").unwrap(),
                    &A100,
                    Arc::new(Engine::native()),
                    NoiseModel::default(),
                    42,
                );
                let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                (kernel.space_arc(), cache)
            })
            .clone()
    }

    /// Run an optimizer on the synthetic space with an eval budget.
    /// Deliberately runs on the pooled per-thread scratch (the campaign
    /// hot path), so every optimizer test also exercises scratch reuse.
    pub fn run_optimizer(name: &str, hp: &HyperParams, evals: usize, seed: u64) -> Trace {
        let (space, cache) = synthetic_cache();
        let mut sim = SimulationRunner::new(space, cache).unwrap();
        let opt = create(name, hp).unwrap();
        let mut rng = Rng::new(seed);
        TuningScratch::with_pooled(|scratch| {
            let mut tuning = Tuning::with_scratch(&mut sim, Budget::evals(evals), scratch);
            opt.run(&mut tuning, &mut rng);
            tuning.finish()
        })
    }

    /// Fraction of the gap between space median and optimum closed.
    pub fn quality(trace: &Trace) -> f64 {
        let (_, cache) = synthetic_cache();
        let vals = cache.sorted_valid_values();
        let opt = vals[0];
        let median = vals[vals.len() / 2];
        let best = trace.best().unwrap_or(f64::INFINITY);
        ((median - best) / (median - opt)).clamp(-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    #[test]
    fn hyperparams_accessors() {
        let hp = HyperParams::new()
            .set("T", 1.5)
            .set("popsize", 20i64)
            .set("method", "uniform");
        assert_eq!(hp.f64("T", 0.0), 1.5);
        assert_eq!(hp.usize("popsize", 0), 20);
        assert_eq!(hp.str("method", "x"), "uniform");
        assert_eq!(hp.f64("missing", 7.0), 7.0);
        assert_eq!(hp.key(), "T=1.5,method=uniform,popsize=20");
    }

    /// The hypertunable set is exactly the grid-bearing descriptors —
    /// paper four plus the ROADMAP extras, never the grid-less
    /// optimizers — in registration order.
    #[test]
    fn hypertunable_matches_grid_bearing_descriptors() {
        let names = hypertunable_names();
        let want: Vec<&str> = registry()
            .iter()
            .filter(|d| d.has_limited_space())
            .map(|d| d.name)
            .collect();
        assert_eq!(names, want);
        for algo in paper_algorithms() {
            assert!(names.contains(&algo), "paper algo {algo} missing");
        }
        assert!(names.contains(&"greedy_ils"));
        assert!(names.contains(&"basin_hopping"));
        assert!(!names.contains(&"random_search"));
        assert!(!names.contains(&"mls"));
        assert!(names.len() > paper_algorithms().len(), "extras must extend the paper set");
    }

    #[test]
    fn registry_creates_every_optimizer() {
        for name in optimizer_names() {
            let opt = create(name, &HyperParams::new()).unwrap();
            assert_eq!(opt.name(), name);
        }
        assert!(create("nope", &HyperParams::new()).is_err());
    }

    #[test]
    fn create_rejects_unknown_keys_listing_schema() {
        // A typo'd key used to silently fall back to the default,
        // invalidating a whole tuning campaign.
        let err = create("pso", &HyperParams::new().set("c3", 1.0)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown hyperparameter"), "{msg}");
        assert!(msg.contains("c1") && msg.contains("c2") && msg.contains("w"), "{msg}");
        // Keys valid for one optimizer are still rejected for another.
        assert!(create("simulated_annealing", &HyperParams::new().set("c1", 1.0)).is_err());
        // Optimizers without hyperparameters reject any key.
        assert!(create("random_search", &HyperParams::new().set("T", 1.0)).is_err());
    }

    #[test]
    fn create_rejects_type_mismatches() {
        // String where a float is expected.
        assert!(create("pso", &HyperParams::new().set("c1", "fast")).is_err());
        // Fractional float where an integer is expected.
        assert!(create("pso", &HyperParams::new().set("popsize", 2.5)).is_err());
        // Integral float widens fine; integer narrows fine.
        assert!(create("pso", &HyperParams::new().set("popsize", 10.0)).is_ok());
        assert!(create("pso", &HyperParams::new().set("c1", 2i64)).is_ok());
    }

    #[test]
    fn create_rejects_out_of_choice_categoricals() {
        let err =
            create("dual_annealing", &HyperParams::new().set("method", "powwww")).unwrap_err();
        assert!(format!("{err:#}").contains("Powell"), "{err:#}");
        assert!(create("mls", &HyperParams::new().set("neighborhood", "diag")).is_err());
        assert!(create("mls", &HyperParams::new().set("neighborhood", "Adjacent")).is_ok());
        for m in dual_annealing::LOCAL_METHODS {
            assert!(create("dual_annealing", &HyperParams::new().set("method", m)).is_ok());
        }
    }

    /// The schema defaults must describe the same configuration the
    /// builders use when a key is absent: building raw (no schema
    /// resolution) and building through `create` (schema defaults merged
    /// in) must produce identical trajectories.
    #[test]
    fn schema_defaults_match_builder_defaults() {
        use crate::runner::{Budget, SimulationRunner};
        for d in registry() {
            let (space, cache) = synthetic_cache();
            let seq = |opt: Box<dyn Optimizer>| {
                let space = std::sync::Arc::clone(&space);
                let cache = std::sync::Arc::clone(&cache);
                let mut sim = SimulationRunner::new(space, cache).unwrap();
                let mut tuning = Tuning::new(&mut sim, Budget::evals(50));
                let mut rng = Rng::new(23);
                opt.run(&mut tuning, &mut rng);
                tuning
                    .finish()
                    .points
                    .iter()
                    .map(|p| p.config)
                    .collect::<Vec<_>>()
            };
            let raw = seq((d.build)(&HyperParams::new()).unwrap());
            let resolved = seq(create(d.name, &HyperParams::new()).unwrap());
            assert_eq!(raw, resolved, "{}: schema defaults drifted", d.name);
        }
    }

    /// The module-doc hyperparameter table must track the registry:
    /// every optimizer and every `name=default` pair appears in it.
    /// Regenerate it from [`schema_table`] when schemas change.
    #[test]
    fn doc_table_matches_registry() {
        let doc: String = include_str!("mod.rs")
            .split_whitespace()
            .collect::<Vec<_>>()
            .join(" ");
        for d in registry() {
            assert!(
                doc.contains(&format!("| `{}` |", d.name)),
                "doc table missing row for {}",
                d.name
            );
            for s in &d.schema {
                let frag = format!("`{}`={}", s.name, s.default.key());
                assert!(
                    doc.contains(&format!("{frag},")) || doc.contains(&format!("{frag} ")),
                    "doc table missing {frag} for {}",
                    d.name
                );
            }
        }
    }

    /// Every optimizer respects the evaluation budget and finds something.
    #[test]
    fn all_optimizers_run_within_budget() {
        for name in optimizer_names() {
            let trace = run_optimizer(name, &HyperParams::new(), 60, 7);
            assert!(
                trace.unique_evals <= 60,
                "{name} used {} unique evals",
                trace.unique_evals
            );
            assert!(trace.best().is_some(), "{name} found nothing");
        }
    }

    /// Deterministic given the same seed.
    #[test]
    fn optimizers_deterministic_per_seed() {
        for name in optimizer_names() {
            let a = run_optimizer(name, &HyperParams::new(), 40, 5);
            let b = run_optimizer(name, &HyperParams::new(), 40, 5);
            assert_eq!(
                a.points.iter().map(|p| p.config).collect::<Vec<_>>(),
                b.points.iter().map(|p| p.config).collect::<Vec<_>>(),
                "{name} not deterministic"
            );
        }
    }

    /// With a healthy budget every algorithm must beat the space median.
    #[test]
    fn all_optimizers_beat_median() {
        for name in optimizer_names() {
            let trace = run_optimizer(name, &HyperParams::new(), 80, 11);
            let q = quality(&trace);
            assert!(q > 0.3, "{name} quality {q}");
        }
    }

    #[test]
    fn relative_delta_handles_invalid() {
        assert!(relative_delta(f64::INFINITY, 1.0) > 0.0);
        assert!(relative_delta(1.0, f64::INFINITY) < 0.0);
        assert!((relative_delta(1.1, 1.0) - 0.1).abs() < 1e-12);
    }
}
