//! Optimization algorithms with exposed hyperparameters.
//!
//! All algorithms program against [`Tuning`] (budget-tracked evaluations
//! with within-run caching) and take their hyperparameters through
//! [`HyperParams`], a string→value map with typed accessors and defaults —
//! the interface the hypertuner ("tuning the tuner") drives.
//!
//! Implemented algorithms (Kernel Tuner's spread of global + local
//! methods):
//!
//! | name                  | hyperparameters                                   |
//! |-----------------------|---------------------------------------------------|
//! | `random_search`       | —                                                 |
//! | `simulated_annealing` | `T`, `T_min`, `alpha`, `maxiter`                  |
//! | `dual_annealing`      | `method` (8 local-search variants)                |
//! | `genetic_algorithm`   | `method` (4 crossovers), `popsize`, `maxiter`, `mutation_chance` |
//! | `pso`                 | `popsize`, `maxiter`, `c1`, `c2`, `w`             |
//! | `differential_evolution` | `popsize`, `F`, `CR`                           |
//! | `basin_hopping`       | `T`, `perturbation`                               |
//! | `mls`                 | `restart`, `neighborhood`                         |
//! | `greedy_ils`          | `perturbation`, `restart`                         |
//! | `firefly`             | `popsize`, `maxiter`, `beta0`, `gamma`, `alpha`   |

pub mod random;
pub mod annealing;
pub mod dual_annealing;
pub mod ga;
pub mod pso;
pub mod extras;

use crate::runner::Tuning;
use crate::searchspace::{SearchSpace, Value};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Hyperparameter assignment for an optimizer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HyperParams(pub BTreeMap<String, Value>);

impl HyperParams {
    pub fn new() -> HyperParams {
        HyperParams(BTreeMap::new())
    }

    pub fn set<V: Into<Value>>(mut self, key: &str, v: V) -> HyperParams {
        self.0.insert(key.to_string(), v.into());
        self
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.0.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.0
            .get(key)
            .and_then(|v| v.as_i64())
            .map(|i| i.max(0) as usize)
            .unwrap_or(default)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.0
            .get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    /// Build from a configuration of a hyperparameter search space.
    pub fn from_space_config(space: &SearchSpace, idx: usize) -> HyperParams {
        HyperParams(space.named_values(idx).into_iter().collect())
    }

    /// Stable display string `k=v,k=v`.
    pub fn key(&self) -> String {
        self.0
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

/// An optimization algorithm.
pub trait Optimizer: Send + Sync {
    fn name(&self) -> &'static str;
    /// Run until the tuning budget is exhausted (or the algorithm's own
    /// iteration limits are reached). Must check `tuning.done()` between
    /// evaluations.
    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng);
}

/// All registered optimizer names.
pub fn optimizer_names() -> Vec<&'static str> {
    vec![
        "random_search",
        "simulated_annealing",
        "dual_annealing",
        "genetic_algorithm",
        "pso",
        "differential_evolution",
        "basin_hopping",
        "mls",
        "greedy_ils",
        "firefly",
    ]
}

/// The four algorithms evaluated in the paper (Table III order).
pub fn paper_algorithms() -> Vec<&'static str> {
    vec![
        "dual_annealing",
        "genetic_algorithm",
        "pso",
        "simulated_annealing",
    ]
}

/// Instantiate an optimizer by name with hyperparameters.
pub fn create(name: &str, hp: &HyperParams) -> Result<Box<dyn Optimizer>> {
    Ok(match name {
        "random_search" => Box::new(random::RandomSearch),
        "simulated_annealing" => Box::new(annealing::SimulatedAnnealing::new(hp)),
        "dual_annealing" => Box::new(dual_annealing::DualAnnealing::new(hp)),
        "genetic_algorithm" => Box::new(ga::GeneticAlgorithm::new(hp)?),
        "pso" => Box::new(pso::Pso::new(hp)),
        "differential_evolution" => Box::new(extras::DifferentialEvolution::new(hp)),
        "basin_hopping" => Box::new(extras::BasinHopping::new(hp)),
        "mls" => Box::new(extras::Mls::new(hp)),
        "greedy_ils" => Box::new(extras::GreedyIls::new(hp)),
        "firefly" => Box::new(extras::Firefly::new(hp)),
        other => bail!("unknown optimizer {other:?}"),
    })
}

/// Relative acceptance scale for annealing-type methods: objective values
/// are kernel times (~1e-3 s), so acceptance tests use relative
/// differences to stay scale-invariant across search spaces.
pub(crate) fn relative_delta(new: f64, old: f64) -> f64 {
    if !old.is_finite() || !new.is_finite() {
        // Moving to/from an invalid config: strongly discouraged / neutral.
        return if new.is_finite() { -1.0 } else { 1.0 };
    }
    (new - old) / old
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::dataset::bruteforce;
    use crate::dataset::cache::CacheData;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runner::{Budget, LiveRunner, SimulationRunner, Trace};
    use crate::runtime::Engine;
    use std::sync::Arc;
    use std::sync::OnceLock;

    /// Shared brute-forced synthetic space for optimizer tests.
    pub fn synthetic_cache() -> (Arc<crate::searchspace::SearchSpace>, Arc<CacheData>) {
        static CACHE: OnceLock<(Arc<crate::searchspace::SearchSpace>, Arc<CacheData>)> =
            OnceLock::new();
        CACHE
            .get_or_init(|| {
                let kernel = kernels::kernel_by_name("synthetic").unwrap();
                let mut live = LiveRunner::new(
                    kernels::kernel_by_name("synthetic").unwrap(),
                    &A100,
                    Arc::new(Engine::native()),
                    NoiseModel::default(),
                    42,
                );
                let cache = Arc::new(bruteforce::bruteforce(&mut live).unwrap());
                (kernel.space_arc(), cache)
            })
            .clone()
    }

    /// Run an optimizer on the synthetic space with an eval budget.
    pub fn run_optimizer(name: &str, hp: &HyperParams, evals: usize, seed: u64) -> Trace {
        let (space, cache) = synthetic_cache();
        let mut sim = SimulationRunner::new(space, cache).unwrap();
        let mut tuning = Tuning::new(&mut sim, Budget::evals(evals));
        let opt = create(name, hp).unwrap();
        let mut rng = Rng::new(seed);
        opt.run(&mut tuning, &mut rng);
        tuning.finish()
    }

    /// Fraction of the gap between space median and optimum closed.
    pub fn quality(trace: &Trace) -> f64 {
        let (_, cache) = synthetic_cache();
        let vals = cache.sorted_valid_values();
        let opt = vals[0];
        let median = vals[vals.len() / 2];
        let best = trace.best().unwrap_or(f64::INFINITY);
        ((median - best) / (median - opt)).clamp(-1.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use testutil::*;

    #[test]
    fn hyperparams_accessors() {
        let hp = HyperParams::new()
            .set("T", 1.5)
            .set("popsize", 20i64)
            .set("method", "uniform");
        assert_eq!(hp.f64("T", 0.0), 1.5);
        assert_eq!(hp.usize("popsize", 0), 20);
        assert_eq!(hp.str("method", "x"), "uniform");
        assert_eq!(hp.f64("missing", 7.0), 7.0);
        assert_eq!(hp.key(), "T=1.5,method=uniform,popsize=20");
    }

    #[test]
    fn registry_creates_every_optimizer() {
        for name in optimizer_names() {
            let opt = create(name, &HyperParams::new()).unwrap();
            assert_eq!(opt.name(), name);
        }
        assert!(create("nope", &HyperParams::new()).is_err());
    }

    /// Every optimizer respects the evaluation budget and finds something.
    #[test]
    fn all_optimizers_run_within_budget() {
        for name in optimizer_names() {
            let trace = run_optimizer(name, &HyperParams::new(), 60, 7);
            assert!(
                trace.unique_evals <= 60,
                "{name} used {} unique evals",
                trace.unique_evals
            );
            assert!(trace.best().is_some(), "{name} found nothing");
        }
    }

    /// Deterministic given the same seed.
    #[test]
    fn optimizers_deterministic_per_seed() {
        for name in optimizer_names() {
            let a = run_optimizer(name, &HyperParams::new(), 40, 5);
            let b = run_optimizer(name, &HyperParams::new(), 40, 5);
            assert_eq!(
                a.points.iter().map(|p| p.config).collect::<Vec<_>>(),
                b.points.iter().map(|p| p.config).collect::<Vec<_>>(),
                "{name} not deterministic"
            );
        }
    }

    /// With a healthy budget every algorithm must beat the space median.
    #[test]
    fn all_optimizers_beat_median() {
        for name in optimizer_names() {
            let trace = run_optimizer(name, &HyperParams::new(), 80, 11);
            let q = quality(&trace);
            assert!(q > 0.3, "{name} quality {q}");
        }
    }

    #[test]
    fn relative_delta_handles_invalid() {
        assert!(relative_delta(f64::INFINITY, 1.0) > 0.0);
        assert!(relative_delta(1.0, f64::INFINITY) < 0.0);
        assert!((relative_delta(1.1, 1.0) - 0.1).abs() < 1e-12);
    }
}
