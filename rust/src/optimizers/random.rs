//! Random search: the methodology's calibration baseline.
//!
//! Samples valid configurations uniformly without replacement (falling
//! back to with-replacement once the space is exhausted, which only
//! happens on tiny spaces).

use super::schema::Descriptor;
use super::Optimizer;
use crate::runner::Tuning;
use crate::util::rng::Rng;

/// Registry entry: random search declares no hyperparameters.
pub fn descriptor() -> Descriptor {
    Descriptor {
        name: "random_search",
        paper: false,
        schema: vec![],
        build: |_hp| Ok(Box::new(RandomSearch)),
    }
}

pub struct RandomSearch;

impl Optimizer for RandomSearch {
    fn name(&self) -> &'static str {
        "random_search"
    }

    fn run(&self, tuning: &mut Tuning<'_>, rng: &mut Rng) {
        let n = tuning.space().len();
        // Without-replacement ordering via an incremental Fisher–Yates:
        // avoids materializing a full permutation of very large spaces
        // unless the run actually visits that many configs.
        let mut swapped: crate::util::hash::FastMap<usize, usize> = Default::default();
        let mut drawn = 0usize;
        while !tuning.done() {
            if drawn == n {
                // Space exhausted: keep sampling uniformly (cache hits).
                let idx = rng.below(n);
                tuning.eval(idx);
                continue;
            }
            let j = drawn + rng.below(n - drawn);
            let pick = *swapped.get(&j).unwrap_or(&j);
            let head = *swapped.get(&drawn).unwrap_or(&drawn);
            swapped.insert(j, head);
            drawn += 1;
            tuning.eval(pick);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::run_optimizer;
    use super::super::HyperParams;

    #[test]
    fn no_repeats_until_exhaustion() {
        let trace = run_optimizer("random_search", &HyperParams::new(), 50, 3);
        let mut seen = std::collections::HashSet::new();
        for p in &trace.points {
            assert!(seen.insert(p.config), "config {} repeated", p.config);
        }
        assert_eq!(trace.unique_evals, 50);
    }

    #[test]
    fn covers_space_uniformly() {
        // Two different seeds should explore different prefixes.
        let a = run_optimizer("random_search", &HyperParams::new(), 30, 1);
        let b = run_optimizer("random_search", &HyperParams::new(), 30, 2);
        let sa: Vec<usize> = a.points.iter().map(|p| p.config).collect();
        let sb: Vec<usize> = b.points.iter().map(|p| p.config).collect();
        assert_ne!(sa, sb);
    }
}
