//! Typed errors for the library crate.
//!
//! Everything fallible in `tunetuner` returns [`TuneError`] (through the
//! crate-wide [`Result`] alias) so embedders can match on failure classes
//! — an unknown optimizer name is programmatically distinguishable from a
//! stale cache or an I/O failure — instead of string-matching an opaque
//! `anyhow::Error`. The CLI binary (`main.rs`) still uses `anyhow` for
//! top-level reporting; `TuneError` implements [`std::error::Error`], so
//! `?` converts at that boundary.
//!
//! The [`Context`] extension trait mirrors the `anyhow::Context` API
//! (`.context(...)` / `.with_context(...)` on `Result` and `Option`), and
//! the [`crate::bail!`] macro mirrors `anyhow::bail!`, so error-handling
//! call sites read the same as before the migration. `{err:#}` renders
//! the full context chain, `{err}` just the outermost message.

use std::fmt;

/// Crate-wide result alias over [`TuneError`].
pub type Result<T, E = TuneError> = std::result::Result<T, E>;

/// The failure classes of the tunetuner library.
#[derive(Debug)]
pub enum TuneError {
    /// Optimizer name not present in the registry.
    UnknownAlgorithm {
        name: String,
        /// Comma-separated registered names (for the message).
        known: String,
    },
    /// Kernel name not known to `kernels::kernel_by_name`.
    UnknownKernel(String),
    /// Device name not known to `gpu::specs`.
    UnknownDevice(String),
    /// A hyperparameter assignment violated an optimizer's declared
    /// schema (unknown key, type mismatch, out-of-choice categorical).
    SchemaViolation(String),
    /// A persisted cache no longer matches the space it claims to index
    /// (fingerprint/key/length mismatch).
    StaleCache(String),
    /// JSON / constraint-expression / file-format parse failure.
    Parse(String),
    /// Engine (PJRT/XLA runtime) failure, including artifact problems.
    Engine(String),
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// Malformed input that fits no more specific class.
    InvalidInput(String),
    /// An executor job (one (space, repeat) tuning run) panicked and
    /// exhausted its retry budget. Carries the first captured panic
    /// payload; sweep drivers quarantine the leg on this variant.
    WorkerPanic {
        /// Job index within the campaign's (space × repeat) matrix.
        job: usize,
        /// Attempts performed (initial run + retries).
        attempts: usize,
        /// First captured panic payload message.
        message: String,
    },
    /// Free-form message (the [`crate::bail!`] macro produces these).
    Msg(String),
    /// A lower-level error wrapped with a context message.
    Context {
        msg: String,
        source: Box<TuneError>,
    },
}

impl TuneError {
    /// Free-form error from a message.
    pub fn msg(m: impl Into<String>) -> TuneError {
        TuneError::Msg(m.into())
    }

    /// The outermost message, without the source chain.
    fn message(&self) -> String {
        match self {
            TuneError::UnknownAlgorithm { name, known } => {
                format!("unknown optimizer {name:?}; registered: {known}")
            }
            TuneError::UnknownKernel(n) => format!("unknown kernel {n:?}"),
            TuneError::UnknownDevice(n) => format!("unknown device {n:?}"),
            TuneError::SchemaViolation(m)
            | TuneError::StaleCache(m)
            | TuneError::Parse(m)
            | TuneError::Engine(m)
            | TuneError::InvalidInput(m)
            | TuneError::Msg(m) => m.clone(),
            TuneError::WorkerPanic {
                job,
                attempts,
                message,
            } => format!("tuning job {job} panicked after {attempts} attempt(s): {message}"),
            TuneError::Io(e) => e.to_string(),
            TuneError::Context { msg, .. } => msg.clone(),
        }
    }

    /// Wrap with a context message (the `source` of the result is `self`).
    pub fn wrap(self, msg: impl Into<String>) -> TuneError {
        TuneError::Context {
            msg: msg.into(),
            source: Box::new(self),
        }
    }

    fn source_tune(&self) -> Option<&TuneError> {
        match self {
            TuneError::Context { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl fmt::Display for TuneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message())?;
        if f.alternate() {
            // `{err:#}`: anyhow-style "outer: inner: innermost" chain.
            let mut cur = self.source_tune();
            while let Some(e) = cur {
                write!(f, ": {}", e.message())?;
                cur = e.source_tune();
            }
        }
        Ok(())
    }
}

impl std::error::Error for TuneError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TuneError::Io(e) => Some(e),
            TuneError::Context { source, .. } => Some(source.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for TuneError {
    fn from(e: std::io::Error) -> TuneError {
        TuneError::Io(e)
    }
}

impl From<crate::util::json::ParseError> for TuneError {
    fn from(e: crate::util::json::ParseError) -> TuneError {
        TuneError::Parse(e.to_string())
    }
}

impl From<std::string::FromUtf8Error> for TuneError {
    fn from(e: std::string::FromUtf8Error) -> TuneError {
        TuneError::Parse(e.to_string())
    }
}

/// `anyhow::Context`-style extension methods for attaching a message to
/// an error (`Result`) or turning an absent value into one (`Option`).
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<S: Into<String>>(self, msg: S) -> Result<T>;
    /// Attach a lazily computed context message.
    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T>;
}

impl<T, E: Into<TuneError>> Context<T> for std::result::Result<T, E> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<S: Into<String>>(self, msg: S) -> Result<T> {
        self.ok_or_else(|| TuneError::Msg(msg.into()))
    }

    fn with_context<S: Into<String>, F: FnOnce() -> S>(self, f: F) -> Result<T> {
        self.ok_or_else(|| TuneError::Msg(f().into()))
    }
}

/// Return early with a [`TuneError::Msg`] built from format arguments —
/// the drop-in replacement for `anyhow::bail!` inside the library.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::TuneError::Msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_plain_and_alternate() {
        let inner = TuneError::Parse("bad token".into());
        let outer = inner.wrap("parsing config").wrap("loading cache");
        assert_eq!(format!("{outer}"), "loading cache");
        assert_eq!(
            format!("{outer:#}"),
            "loading cache: parsing config: bad token"
        );
    }

    #[test]
    fn source_chain_reaches_io() {
        use std::error::Error;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = TuneError::from(io).wrap("read hub");
        let src = e.source().expect("has source");
        assert!(src.source().is_some(), "Io links through to io::Error");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::other("boom"));
        let e = r.context("doing io").unwrap_err();
        assert_eq!(format!("{e:#}"), "doing io: boom");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }

    #[test]
    fn bail_macro_formats() {
        fn f(x: usize) -> Result<()> {
            if x > 2 {
                bail!("x too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(format!("{}", f(3).unwrap_err()), "x too big: 3");
    }

    #[test]
    fn typed_variants_render() {
        let e = TuneError::UnknownAlgorithm {
            name: "nope".into(),
            known: "pso, mls".into(),
        };
        let s = format!("{e}");
        assert!(s.contains("nope") && s.contains("pso"), "{s}");
        assert!(format!("{}", TuneError::UnknownKernel("k".into())).contains("kernel"));
        assert!(format!("{}", TuneError::UnknownDevice("d".into())).contains("device"));
    }
}
