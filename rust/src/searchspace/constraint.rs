//! The constraint expression language.
//!
//! Restrictions on a search space are written as boolean expressions over
//! parameter names, e.g. the CLBlast GEMM constraints:
//!
//! ```text
//! MWG % (MDIMC * VWM) == 0
//! (MDIMC * NDIMC) % 32 == 0 || (MDIMC * NDIMC) % 64 == 0
//! ```
//!
//! Grammar (Pratt parser, C-like precedence):
//!
//! ```text
//! expr   := or
//! or     := and ('||' and)*
//! and    := cmp ('&&' cmp)*
//! cmp    := sum (('=='|'!='|'<='|'>='|'<'|'>') sum)?
//! sum    := prod (('+'|'-') prod)*
//! prod   := unary (('*'|'/'|'%') unary)*
//! unary  := '!' unary | '-' unary | atom
//! atom   := number | string | ident | '(' expr ')'
//!         | ('min'|'max') '(' expr ',' expr ')'
//! ```
//!
//! Integer-valued operands use exact i64 arithmetic (so `%` behaves like
//! the Python constraints in Kernel Tuner specs); mixed or fractional
//! operands fall back to f64.

use super::param::{TunableParam, Value};
use crate::bail;
use crate::error::{Context, Result};
use crate::util::hash::FastMap;
use std::collections::BTreeMap;

/// A compiled constraint: source text + AST + referenced parameter names.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub source: String,
    expr: Expr,
    pub vars: Vec<String>,
}

impl Constraint {
    /// Parse a constraint expression.
    pub fn parse(source: &str) -> Result<Constraint> {
        let tokens = lex(source).with_context(|| format!("lexing {source:?}"))?;
        let mut p = Parser { tokens, pos: 0 };
        let expr = p.parse_expr(0)?;
        if p.pos != p.tokens.len() {
            bail!("trailing tokens in constraint {source:?}");
        }
        let mut vars = Vec::new();
        collect_vars(&expr, &mut vars);
        vars.sort();
        vars.dedup();
        Ok(Constraint {
            source: source.to_string(),
            expr,
            vars,
        })
    }

    /// Evaluate against a full assignment (name -> value).
    pub fn eval(&self, env: &dyn Fn(&str) -> Option<Value>) -> Result<bool> {
        match eval_expr(&self.expr, env)? {
            Num::Bool(b) => Ok(b),
            Num::Int(i) => Ok(i != 0),
            Num::Float(x) => Ok(x != 0.0),
            Num::Str(_) => bail!("constraint {:?} evaluated to a string", self.source),
        }
    }

    /// Evaluate with a sorted-map environment (convenience). Kept as the
    /// slow-path *reference oracle* for tests; the enumeration hot path
    /// goes through [`Constraint::compile`] + [`CompiledConstraint`].
    pub fn eval_map(&self, env: &BTreeMap<String, Value>) -> Result<bool> {
        self.eval(&|name| env.get(name).cloned())
    }

    /// Lower this constraint to typed stack bytecode bound to `params`
    /// (dimension order = parameter order). Every variable is resolved to
    /// a per-dimension slot at compile time, and each slot carries the
    /// parameter's value grid pre-converted to immediate [`CVal`]s
    /// (strings interned, so equality is id equality) — evaluation then
    /// does no name lookups, no `Value` clones and no allocation beyond
    /// the caller-provided stack scratch.
    ///
    /// Errors when a variable names no parameter in `params`.
    pub fn compile(&self, params: &[TunableParam]) -> Result<CompiledConstraint> {
        let mut c = Compiler {
            params,
            source: &self.source,
            ops: Vec::new(),
            slots: Vec::new(),
            slot_of_dim: FastMap::default(),
            interned: FastMap::default(),
            max_dim: 0,
        };
        c.emit(&self.expr)?;
        Ok(CompiledConstraint {
            source: self.source.clone(),
            max_dim: c.max_dim,
            ops: c.ops,
            slots: c.slots,
        })
    }
}

// ---------------------------------------------------------------------------
// Lexer

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Num(f64),
    Int(i64),
    Str(String),
    Ident(String),
    Op(&'static str),
    LParen,
    RParen,
    Comma,
}

fn lex(src: &str) -> Result<Vec<Tok>> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            b'\'' | b'"' => {
                let quote = c;
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != quote {
                    j += 1;
                }
                if j == b.len() {
                    bail!("unterminated string literal");
                }
                out.push(Tok::Str(src[start..j].to_string()));
                i = j + 1;
            }
            b'0'..=b'9' | b'.' => {
                let start = i;
                let mut is_float = false;
                while i < b.len()
                    && (b[i].is_ascii_digit() || b[i] == b'.' || b[i] == b'e' || b[i] == b'E'
                        || ((b[i] == b'+' || b[i] == b'-')
                            && i > start
                            && (b[i - 1] == b'e' || b[i - 1] == b'E')))
                {
                    if b[i] == b'.' || b[i] == b'e' || b[i] == b'E' {
                        is_float = true;
                    }
                    i += 1;
                }
                let text = &src[start..i];
                if is_float {
                    out.push(Tok::Num(text.parse()?));
                } else {
                    out.push(Tok::Int(text.parse()?));
                }
            }
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(Tok::Ident(src[start..i].to_string()));
            }
            _ => {
                let two = if i + 1 < b.len() { &src[i..i + 2] } else { "" };
                let op2 = ["==", "!=", "<=", ">=", "&&", "||"]
                    .iter()
                    .find(|&&o| o == two);
                if let Some(&op) = op2 {
                    out.push(Tok::Op(op));
                    i += 2;
                } else {
                    let one = &src[i..i + 1];
                    let op1 = ["+", "-", "*", "/", "%", "<", ">", "!"]
                        .iter()
                        .find(|&&o| o == one);
                    match op1 {
                        Some(&op) => {
                            out.push(Tok::Op(op));
                            i += 1;
                        }
                        None => bail!("unexpected character {:?} at {}", c as char, i),
                    }
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// AST + Pratt parser

#[derive(Clone, Debug)]
enum Expr {
    Int(i64),
    Float(f64),
    Str(String),
    Var(String),
    Unary(&'static str, Box<Expr>),
    Binary(&'static str, Box<Expr>, Box<Expr>),
    Call(&'static str, Vec<Expr>),
}

fn collect_vars(e: &Expr, out: &mut Vec<String>) {
    match e {
        Expr::Var(n) => out.push(n.clone()),
        Expr::Unary(_, a) => collect_vars(a, out),
        Expr::Binary(_, a, b) => {
            collect_vars(a, out);
            collect_vars(b, out);
        }
        Expr::Call(_, args) => args.iter().for_each(|a| collect_vars(a, out)),
        _ => {}
    }
}

struct Parser {
    tokens: Vec<Tok>,
    pos: usize,
}

fn binding_power(op: &str) -> Option<(u8, u8)> {
    Some(match op {
        "||" => (1, 2),
        "&&" => (3, 4),
        "==" | "!=" | "<" | ">" | "<=" | ">=" => (5, 6),
        "+" | "-" => (7, 8),
        "*" | "/" | "%" => (9, 10),
        _ => return None,
    })
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn parse_expr(&mut self, min_bp: u8) -> Result<Expr> {
        let mut lhs = match self.next() {
            Some(Tok::Int(i)) => Expr::Int(i),
            Some(Tok::Num(x)) => Expr::Float(x),
            Some(Tok::Str(s)) => Expr::Str(s),
            Some(Tok::Ident(name)) => {
                if (name == "min" || name == "max") && self.peek() == Some(&Tok::LParen) {
                    self.next();
                    let a = self.parse_expr(0)?;
                    if self.next() != Some(Tok::Comma) {
                        bail!("expected ',' in {name}()");
                    }
                    let b = self.parse_expr(0)?;
                    if self.next() != Some(Tok::RParen) {
                        bail!("expected ')' in {name}()");
                    }
                    let f: &'static str = if name == "min" { "min" } else { "max" };
                    Expr::Call(f, vec![a, b])
                } else if name == "True" || name == "true" {
                    Expr::Int(1)
                } else if name == "False" || name == "false" {
                    Expr::Int(0)
                } else {
                    Expr::Var(name)
                }
            }
            Some(Tok::LParen) => {
                let e = self.parse_expr(0)?;
                if self.next() != Some(Tok::RParen) {
                    bail!("expected ')'");
                }
                e
            }
            Some(Tok::Op("-")) => Expr::Unary("-", Box::new(self.parse_expr(11)?)),
            Some(Tok::Op("!")) => Expr::Unary("!", Box::new(self.parse_expr(11)?)),
            other => bail!("unexpected token {other:?}"),
        };

        loop {
            let op = match self.peek() {
                Some(Tok::Op(op)) => *op,
                _ => break,
            };
            let Some((lbp, rbp)) = binding_power(op) else {
                break;
            };
            if lbp < min_bp {
                break;
            }
            self.next();
            let rhs = self.parse_expr(rbp)?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }
}

// ---------------------------------------------------------------------------
// Evaluator

#[derive(Clone, Debug)]
enum Num {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Num {
    fn to_f64(&self) -> Result<f64> {
        Ok(match self {
            Num::Int(i) => *i as f64,
            Num::Float(x) => *x,
            Num::Bool(b) => *b as i64 as f64,
            Num::Str(_) => bail!("string used in numeric context"),
        })
    }
}

fn from_value(v: Value) -> Num {
    match v {
        Value::Int(i) => Num::Int(i),
        Value::Float(x) => Num::Float(x),
        Value::Bool(b) => Num::Bool(b),
        Value::Str(s) => Num::Str(s),
    }
}

fn eval_expr(e: &Expr, env: &dyn Fn(&str) -> Option<Value>) -> Result<Num> {
    Ok(match e {
        Expr::Int(i) => Num::Int(*i),
        Expr::Float(x) => Num::Float(*x),
        Expr::Str(s) => Num::Str(s.clone()),
        Expr::Var(name) => from_value(
            env(name).with_context(|| format!("unknown parameter {name:?} in constraint"))?,
        ),
        Expr::Unary("-", a) => match eval_expr(a, env)? {
            Num::Int(i) => Num::Int(-i),
            other => Num::Float(-other.to_f64()?),
        },
        Expr::Unary("!", a) => {
            let v = eval_expr(a, env)?;
            Num::Bool(match v {
                Num::Bool(b) => !b,
                Num::Int(i) => i == 0,
                Num::Float(x) => x == 0.0,
                Num::Str(_) => bail!("! applied to string"),
            })
        }
        Expr::Unary(op, _) => bail!("unknown unary {op}"),
        Expr::Call(f, args) => {
            let a = eval_expr(&args[0], env)?;
            let b = eval_expr(&args[1], env)?;
            match (f, &a, &b) {
                (&"min", Num::Int(x), Num::Int(y)) => Num::Int(*x.min(y)),
                (&"max", Num::Int(x), Num::Int(y)) => Num::Int(*x.max(y)),
                (&"min", _, _) => Num::Float(a.to_f64()?.min(b.to_f64()?)),
                (&"max", _, _) => Num::Float(a.to_f64()?.max(b.to_f64()?)),
                _ => bail!("unknown function {f}"),
            }
        }
        Expr::Binary(op, a, b) => {
            // Short-circuit logicals.
            if *op == "&&" || *op == "||" {
                let av = truthy(eval_expr(a, env)?)?;
                return Ok(Num::Bool(if *op == "&&" {
                    av && truthy(eval_expr(b, env)?)?
                } else {
                    av || truthy(eval_expr(b, env)?)?
                }));
            }
            let av = eval_expr(a, env)?;
            let bv = eval_expr(b, env)?;
            // String equality.
            if let (Num::Str(x), Num::Str(y)) = (&av, &bv) {
                return Ok(match *op {
                    "==" => Num::Bool(x == y),
                    "!=" => Num::Bool(x != y),
                    _ => bail!("operator {op} not defined on strings"),
                });
            }
            // Exact integer arithmetic when both sides are ints.
            if let (Num::Int(x), Num::Int(y)) = (&av, &bv) {
                let (x, y) = (*x, *y);
                return Ok(match *op {
                    "+" => Num::Int(x.wrapping_add(y)),
                    "-" => Num::Int(x.wrapping_sub(y)),
                    "*" => Num::Int(x.wrapping_mul(y)),
                    "/" => {
                        if y == 0 {
                            bail!("division by zero");
                        }
                        // Python-style floor semantics are not needed by the
                        // kernel specs; constraints use exact divisibility.
                        Num::Int(x / y)
                    }
                    "%" => {
                        if y == 0 {
                            bail!("modulo by zero");
                        }
                        Num::Int(x.rem_euclid(y))
                    }
                    "==" => Num::Bool(x == y),
                    "!=" => Num::Bool(x != y),
                    "<" => Num::Bool(x < y),
                    ">" => Num::Bool(x > y),
                    "<=" => Num::Bool(x <= y),
                    ">=" => Num::Bool(x >= y),
                    _ => bail!("unknown operator {op}"),
                });
            }
            let x = av.to_f64()?;
            let y = bv.to_f64()?;
            match *op {
                "+" => Num::Float(x + y),
                "-" => Num::Float(x - y),
                "*" => Num::Float(x * y),
                "/" => Num::Float(x / y),
                "%" => Num::Float(x.rem_euclid(y)),
                "==" => Num::Bool(x == y),
                "!=" => Num::Bool(x != y),
                "<" => Num::Bool(x < y),
                ">" => Num::Bool(x > y),
                "<=" => Num::Bool(x <= y),
                ">=" => Num::Bool(x >= y),
                _ => bail!("unknown operator {op}"),
            }
        }
    })
}

fn truthy(n: Num) -> Result<bool> {
    Ok(match n {
        Num::Bool(b) => b,
        Num::Int(i) => i != 0,
        Num::Float(x) => x != 0.0,
        Num::Str(_) => bail!("string used as boolean"),
    })
}

// ---------------------------------------------------------------------------
// Compiled bytecode
//
// The AST interpreter above allocates an env lookup per variable and clones
// `Value`s on every evaluation — fine for a handful of calls, ruinous when
// enumerating 10^8+ Cartesian ranks. `CompiledConstraint` is the hot-path
// form: a flat op tape over `Copy` immediates, with variables pre-resolved
// to (dimension, value-table) slots so an evaluation is one `u16` digit
// read and one table index per variable. Semantics are pinned bit-for-bit
// to the interpreter by the oracle tests below.

/// Immediate value on the compiled evaluation stack. Strings are interned
/// at compile time with content dedup, so `Str` id equality is exactly
/// string equality.
#[derive(Clone, Copy, Debug)]
enum CVal {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(u32),
}

/// Pop the compiled evaluation stack. The emit pass is arity-checked —
/// every op's operands are pushed before the op that consumes them — so
/// an underflow here would be a compiler bug, not bad user input.
fn pop(stack: &mut Vec<CVal>) -> CVal {
    // lint: allow(W03, reason = "emit pass is arity-checked; underflow is a compiler bug")
    stack.pop().expect("compiled stack underflow")
}

fn cval_f64(v: CVal) -> Result<f64> {
    Ok(match v {
        CVal::Int(i) => i as f64,
        CVal::Float(x) => x,
        CVal::Bool(b) => b as i64 as f64,
        CVal::Str(_) => bail!("string used in numeric context"),
    })
}

fn cval_truthy(v: CVal) -> Result<bool> {
    Ok(match v {
        CVal::Bool(b) => b,
        CVal::Int(i) => i != 0,
        CVal::Float(x) => x != 0.0,
        CVal::Str(_) => bail!("string used as boolean"),
    })
}

/// Binary operators of the compiled form.
#[derive(Clone, Copy, Debug)]
enum BOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Gt,
    Le,
    Ge,
}

impl BOp {
    fn of(op: &str) -> Option<BOp> {
        Some(match op {
            "+" => BOp::Add,
            "-" => BOp::Sub,
            "*" => BOp::Mul,
            "/" => BOp::Div,
            "%" => BOp::Mod,
            "==" => BOp::Eq,
            "!=" => BOp::Ne,
            "<" => BOp::Lt,
            ">" => BOp::Gt,
            "<=" => BOp::Le,
            ">=" => BOp::Ge,
            _ => return None,
        })
    }

    fn symbol(self) -> &'static str {
        match self {
            BOp::Add => "+",
            BOp::Sub => "-",
            BOp::Mul => "*",
            BOp::Div => "/",
            BOp::Mod => "%",
            BOp::Eq => "==",
            BOp::Ne => "!=",
            BOp::Lt => "<",
            BOp::Gt => ">",
            BOp::Le => "<=",
            BOp::Ge => ">=",
        }
    }
}

/// One op of the compiled tape.
#[derive(Clone, Copy, Debug)]
enum COp {
    /// Push an immediate.
    Push(CVal),
    /// Push the current value of slot `.0` (digit read + table index).
    Load(u32),
    /// Integer-preserving negation (interpreter `Unary("-")` semantics).
    Neg,
    /// Boolean negation with the interpreter's truthiness coercion.
    Not,
    /// Coerce top-of-stack to `Bool` via truthiness (errors on strings).
    ToBool,
    /// Short-circuit jump: top-of-stack is a Bool (always preceded by
    /// `ToBool`); when it equals `cond`, jump to `to` *keeping* the Bool
    /// as the result, otherwise pop it and fall through to the other arm.
    JumpIf { cond: bool, to: u32 },
    /// Binary operator (exact-i64 / f64-fallback triage as interpreted).
    Bin(BOp),
    Min,
    Max,
}

/// Per-variable slot: the dimension it reads and the parameter's value
/// grid pre-converted to immediates.
#[derive(Clone, Debug)]
struct Slot {
    dim: usize,
    values: Vec<CVal>,
}

/// Reusable evaluation stack for [`CompiledConstraint::eval_encoded`];
/// one per build/evaluation loop, cleared on every call.
#[derive(Default)]
pub struct EvalScratch {
    stack: Vec<CVal>,
}

/// A constraint lowered to typed stack bytecode over encoded `u16` digits.
#[derive(Clone, Debug)]
pub struct CompiledConstraint {
    /// Source text (diagnostics only).
    pub source: String,
    /// Highest dimension index referenced: the constraint is fully bound
    /// once the odometer has assigned dimensions `0..=max_dim` (0 for
    /// constant constraints).
    pub max_dim: usize,
    ops: Vec<COp>,
    slots: Vec<Slot>,
}

impl CompiledConstraint {
    /// Evaluate against encoded digits: `digit(d)` returns the value
    /// *index* of dimension `d` (only dimensions `<= max_dim` are read).
    /// Result coercion matches [`Constraint::eval`] exactly.
    pub fn eval_encoded(
        &self,
        mut digit: impl FnMut(usize) -> u16,
        scratch: &mut EvalScratch,
    ) -> Result<bool> {
        let stack = &mut scratch.stack;
        stack.clear();
        let mut pc = 0usize;
        while pc < self.ops.len() {
            match self.ops[pc] {
                COp::Push(v) => stack.push(v),
                COp::Load(s) => {
                    let slot = &self.slots[s as usize];
                    stack.push(slot.values[digit(slot.dim) as usize]);
                }
                COp::Neg => {
                    let v = pop(stack);
                    stack.push(match v {
                        CVal::Int(i) => CVal::Int(-i),
                        other => CVal::Float(-cval_f64(other)?),
                    });
                }
                COp::Not => {
                    let v = pop(stack);
                    stack.push(CVal::Bool(match v {
                        CVal::Bool(b) => !b,
                        CVal::Int(i) => i == 0,
                        CVal::Float(x) => x == 0.0,
                        CVal::Str(_) => bail!("! applied to string"),
                    }));
                }
                COp::ToBool => {
                    let v = pop(stack);
                    stack.push(CVal::Bool(cval_truthy(v)?));
                }
                COp::JumpIf { cond, to } => {
                    let v = pop(stack);
                    let CVal::Bool(b) = v else {
                        unreachable!("JumpIf over a non-Bool (compiler always emits ToBool first)")
                    };
                    if b == cond {
                        stack.push(v);
                        pc = to as usize;
                        continue;
                    }
                }
                COp::Bin(op) => {
                    let b = pop(stack);
                    let a = pop(stack);
                    stack.push(eval_bin(op, a, b)?);
                }
                COp::Min | COp::Max => {
                    let b = pop(stack);
                    let a = pop(stack);
                    let is_min = matches!(self.ops[pc], COp::Min);
                    stack.push(match (a, b) {
                        (CVal::Int(x), CVal::Int(y)) => {
                            CVal::Int(if is_min { x.min(y) } else { x.max(y) })
                        }
                        _ => {
                            let (x, y) = (cval_f64(a)?, cval_f64(b)?);
                            CVal::Float(if is_min { x.min(y) } else { x.max(y) })
                        }
                    });
                }
            }
            pc += 1;
        }
        match pop(stack) {
            CVal::Bool(b) => Ok(b),
            CVal::Int(i) => Ok(i != 0),
            CVal::Float(x) => Ok(x != 0.0),
            CVal::Str(_) => bail!("constraint {:?} evaluated to a string", self.source),
        }
    }
}

/// Binary-op triage, mirroring the interpreter's `Expr::Binary` arm:
/// string==string first, then exact i64, then the f64 fallback.
fn eval_bin(op: BOp, a: CVal, b: CVal) -> Result<CVal> {
    if let (CVal::Str(x), CVal::Str(y)) = (a, b) {
        return Ok(match op {
            BOp::Eq => CVal::Bool(x == y),
            BOp::Ne => CVal::Bool(x != y),
            _ => bail!("operator {} not defined on strings", op.symbol()),
        });
    }
    if let (CVal::Int(x), CVal::Int(y)) = (a, b) {
        return Ok(match op {
            BOp::Add => CVal::Int(x.wrapping_add(y)),
            BOp::Sub => CVal::Int(x.wrapping_sub(y)),
            BOp::Mul => CVal::Int(x.wrapping_mul(y)),
            BOp::Div => {
                if y == 0 {
                    bail!("division by zero");
                }
                CVal::Int(x / y)
            }
            BOp::Mod => {
                if y == 0 {
                    bail!("modulo by zero");
                }
                CVal::Int(x.rem_euclid(y))
            }
            BOp::Eq => CVal::Bool(x == y),
            BOp::Ne => CVal::Bool(x != y),
            BOp::Lt => CVal::Bool(x < y),
            BOp::Gt => CVal::Bool(x > y),
            BOp::Le => CVal::Bool(x <= y),
            BOp::Ge => CVal::Bool(x >= y),
        });
    }
    let x = cval_f64(a)?;
    let y = cval_f64(b)?;
    Ok(match op {
        BOp::Add => CVal::Float(x + y),
        BOp::Sub => CVal::Float(x - y),
        BOp::Mul => CVal::Float(x * y),
        BOp::Div => CVal::Float(x / y),
        BOp::Mod => CVal::Float(x.rem_euclid(y)),
        BOp::Eq => CVal::Bool(x == y),
        BOp::Ne => CVal::Bool(x != y),
        BOp::Lt => CVal::Bool(x < y),
        BOp::Gt => CVal::Bool(x > y),
        BOp::Le => CVal::Bool(x <= y),
        BOp::Ge => CVal::Bool(x >= y),
    })
}

struct Compiler<'a> {
    params: &'a [TunableParam],
    source: &'a str,
    ops: Vec<COp>,
    slots: Vec<Slot>,
    slot_of_dim: FastMap<usize, u32>,
    interned: FastMap<String, u32>,
    max_dim: usize,
}

impl Compiler<'_> {
    fn intern(&mut self, s: &str) -> u32 {
        if let Some(&id) = self.interned.get(s) {
            return id;
        }
        let id = self.interned.len() as u32;
        self.interned.insert(s.to_string(), id);
        id
    }

    fn slot(&mut self, name: &str) -> Result<u32> {
        let dim = match self.params.iter().position(|p| p.name == name) {
            Some(d) => d,
            None => bail!(
                "constraint {:?} references unknown parameter {name:?}",
                self.source
            ),
        };
        self.max_dim = self.max_dim.max(dim);
        if let Some(&s) = self.slot_of_dim.get(&dim) {
            return Ok(s);
        }
        let values = self.params[dim]
            .values
            .iter()
            .map(|v| match v {
                Value::Int(i) => CVal::Int(*i),
                Value::Float(x) => CVal::Float(*x),
                Value::Bool(b) => CVal::Bool(*b),
                Value::Str(s) => CVal::Str(self.intern(s)),
            })
            .collect();
        let s = self.slots.len() as u32;
        self.slots.push(Slot { dim, values });
        self.slot_of_dim.insert(dim, s);
        Ok(s)
    }

    fn emit(&mut self, e: &Expr) -> Result<()> {
        match e {
            Expr::Int(i) => self.ops.push(COp::Push(CVal::Int(*i))),
            Expr::Float(x) => self.ops.push(COp::Push(CVal::Float(*x))),
            Expr::Str(s) => {
                let id = self.intern(s);
                self.ops.push(COp::Push(CVal::Str(id)));
            }
            Expr::Var(name) => {
                let s = self.slot(name)?;
                self.ops.push(COp::Load(s));
            }
            Expr::Unary("-", a) => {
                self.emit(a)?;
                self.ops.push(COp::Neg);
            }
            Expr::Unary("!", a) => {
                self.emit(a)?;
                self.ops.push(COp::Not);
            }
            Expr::Unary(op, _) => bail!("unknown unary {op}"),
            Expr::Call(f, args) => {
                self.emit(&args[0])?;
                self.emit(&args[1])?;
                match *f {
                    "min" => self.ops.push(COp::Min),
                    "max" => self.ops.push(COp::Max),
                    other => bail!("unknown function {other}"),
                }
            }
            Expr::Binary(op @ ("&&" | "||"), a, b) => {
                // Short-circuit: coerce the left arm, keep it as the
                // result when it decides the outcome, otherwise pop it
                // and take the coerced right arm. Errors in the skipped
                // arm are skipped too, exactly like the interpreter.
                self.emit(a)?;
                self.ops.push(COp::ToBool);
                let patch = self.ops.len();
                self.ops.push(COp::JumpIf {
                    cond: *op == "||",
                    to: 0,
                });
                self.emit(b)?;
                self.ops.push(COp::ToBool);
                let end = self.ops.len() as u32;
                let COp::JumpIf { to, .. } = &mut self.ops[patch] else {
                    unreachable!()
                };
                *to = end;
            }
            Expr::Binary(op, a, b) => {
                self.emit(a)?;
                self.emit(b)?;
                match BOp::of(op) {
                    Some(bop) => self.ops.push(COp::Bin(bop)),
                    None => bail!("unknown operator {op}"),
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env_of(pairs: &[(&str, Value)]) -> BTreeMap<String, Value> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn arithmetic_and_modulo() {
        let c = Constraint::parse("MWG % (MDIMC * VWM) == 0").unwrap();
        assert_eq!(c.vars, vec!["MDIMC", "MWG", "VWM"]);
        let env = env_of(&[
            ("MWG", Value::Int(64)),
            ("MDIMC", Value::Int(8)),
            ("VWM", Value::Int(4)),
        ]);
        assert!(c.eval_map(&env).unwrap());
        let env = env_of(&[
            ("MWG", Value::Int(48)),
            ("MDIMC", Value::Int(8)),
            ("VWM", Value::Int(4)),
        ]);
        assert!(!c.eval_map(&env).unwrap());
    }

    #[test]
    fn logicals_and_comparison() {
        let c = Constraint::parse("a * b <= 1024 && (a == 32 || b >= 4)").unwrap();
        let t = env_of(&[("a", Value::Int(32)), ("b", Value::Int(2))]);
        assert!(c.eval_map(&t).unwrap());
        let f = env_of(&[("a", Value::Int(64)), ("b", Value::Int(2))]);
        assert!(!c.eval_map(&f).unwrap());
    }

    #[test]
    fn string_equality() {
        let c = Constraint::parse("method == 'uniform' || method == \"two_point\"").unwrap();
        assert!(c
            .eval_map(&env_of(&[("method", Value::Str("uniform".into()))]))
            .unwrap());
        assert!(!c
            .eval_map(&env_of(&[("method", Value::Str("single".into()))]))
            .unwrap());
    }

    #[test]
    fn unary_and_functions() {
        let c = Constraint::parse("!(x > 3) && min(x, 10) == x && max(x, -1) == x").unwrap();
        assert!(c.eval_map(&env_of(&[("x", Value::Int(2))])).unwrap());
        assert!(!c.eval_map(&env_of(&[("x", Value::Int(5))])).unwrap());
    }

    #[test]
    fn float_arithmetic() {
        let c = Constraint::parse("t * 2.0 >= 1.0").unwrap();
        assert!(c.eval_map(&env_of(&[("t", Value::Float(0.5))])).unwrap());
        assert!(!c.eval_map(&env_of(&[("t", Value::Float(0.4))])).unwrap());
    }

    #[test]
    fn precedence() {
        let c = Constraint::parse("2 + 3 * 4 == 14").unwrap();
        assert!(c.eval_map(&BTreeMap::new()).unwrap());
        let c = Constraint::parse("(2 + 3) * 4 == 20").unwrap();
        assert!(c.eval_map(&BTreeMap::new()).unwrap());
    }

    #[test]
    fn errors() {
        assert!(Constraint::parse("a &&& b").is_err());
        assert!(Constraint::parse("(a").is_err());
        assert!(Constraint::parse("a ==").is_err());
        let c = Constraint::parse("missing == 1").unwrap();
        assert!(c.eval_map(&BTreeMap::new()).is_err());
        let c = Constraint::parse("1 / 0 == 1").unwrap();
        assert!(c.eval_map(&BTreeMap::new()).is_err());
    }

    #[test]
    fn booleans_in_env() {
        let c = Constraint::parse("use_padding == 1 || tile == 1").unwrap();
        assert!(c
            .eval_map(&env_of(&[
                ("use_padding", Value::Bool(true)),
                ("tile", Value::Int(4)),
            ]))
            .unwrap());
    }

    // -- compiled bytecode vs interpreter oracle --------------------------

    /// Assert the compiled form agrees with `eval_map` on the *entire*
    /// cross product of `params` — Ok values bitwise, Err-ness matched.
    fn assert_compiled_matches_oracle(src: &str, params: &[TunableParam]) {
        let c = Constraint::parse(src).unwrap();
        let cc = c.compile(params).unwrap();
        let dims: Vec<usize> = params.iter().map(|p| p.cardinality()).collect();
        let mut cursor = vec![0usize; dims.len()];
        let mut scratch = EvalScratch::default();
        loop {
            let env: BTreeMap<String, Value> = params
                .iter()
                .zip(&cursor)
                .map(|(p, &i)| (p.name.clone(), p.values[i].clone()))
                .collect();
            let oracle = c.eval_map(&env);
            let got = cc.eval_encoded(|d| cursor[d] as u16, &mut scratch);
            match (&oracle, &got) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "{src} @ {cursor:?}"),
                (Err(_), Err(_)) => {}
                _ => panic!("divergence on {src} @ {cursor:?}: {oracle:?} vs {got:?}"),
            }
            // Odometer over the cross product.
            let mut d = dims.len();
            loop {
                if d == 0 {
                    return;
                }
                d -= 1;
                cursor[d] += 1;
                if cursor[d] < dims[d] {
                    break;
                }
                cursor[d] = 0;
            }
        }
    }

    #[test]
    fn compiled_matches_interpreter_on_kernel_style_constraints() {
        let params = vec![
            TunableParam::new("MWG", vec![16i64, 32, 48, 64]),
            TunableParam::new("MDIMC", vec![8i64, 16, 32]),
            TunableParam::new("VWM", vec![1i64, 2, 4]),
        ];
        assert_compiled_matches_oracle("MWG % (MDIMC * VWM) == 0", &params);
        assert_compiled_matches_oracle(
            "(MDIMC * VWM) % 32 == 0 || (MDIMC * VWM) % 64 == 0",
            &params,
        );
        assert_compiled_matches_oracle("MWG * MDIMC <= 1024 && (MWG == 32 || MDIMC >= 16)", &params);
        assert_compiled_matches_oracle("min(MWG, MDIMC) < max(VWM, 8)", &params);
        assert_compiled_matches_oracle("!(MWG > 32) && -MDIMC < 0", &params);
    }

    #[test]
    fn compiled_matches_interpreter_on_mixed_types_and_errors() {
        let params = vec![
            TunableParam::new("x", vec![0i64, 1, 2, 5]),
            TunableParam::new("t", vec![0.0f64, 0.4, 0.5]),
            TunableParam::new(
                "method",
                vec!["uniform".to_string(), "two_point".to_string()],
            ),
            TunableParam::new("pad", vec![false, true]),
        ];
        // Division/modulo by a zero-valued parameter: error parity.
        assert_compiled_matches_oracle("8 % x == 0", &params);
        assert_compiled_matches_oracle("8 / x >= 2", &params);
        // Short-circuit guards must skip the erroring arm on both paths.
        assert_compiled_matches_oracle("x == 0 || 8 / x >= 2", &params);
        assert_compiled_matches_oracle("x != 0 && 8 % x == 0", &params);
        // Float fallback + bool coercion.
        assert_compiled_matches_oracle("t * 2.0 >= 1.0", &params);
        assert_compiled_matches_oracle("pad + 1 == 2", &params);
        assert_compiled_matches_oracle("pad == 1 || x == 1", &params);
        // String equality (interned ids) and string-misuse errors.
        assert_compiled_matches_oracle("method == 'uniform' || method == \"two_point\"", &params);
        assert_compiled_matches_oracle("method != 'uniform'", &params);
        assert_compiled_matches_oracle("method == 1", &params);
        assert_compiled_matches_oracle("method + 1 == 2", &params);
        assert_compiled_matches_oracle("!method", &params);
        // Constant expressions bind at depth 0 and still agree.
        assert_compiled_matches_oracle("2 + 3 * 4 == 14", &params);
        assert_compiled_matches_oracle("True && !False", &params);
    }

    #[test]
    fn compile_reports_max_dim_and_rejects_unknowns() {
        let params = vec![
            TunableParam::new("a", vec![1i64, 2]),
            TunableParam::new("b", vec![1i64, 2]),
            TunableParam::new("c", vec![1i64, 2]),
        ];
        let c = Constraint::parse("a + b <= 3").unwrap();
        assert_eq!(c.compile(&params).unwrap().max_dim, 1);
        let c = Constraint::parse("c > 0").unwrap();
        assert_eq!(c.compile(&params).unwrap().max_dim, 2);
        let c = Constraint::parse("1 == 1").unwrap();
        assert_eq!(c.compile(&params).unwrap().max_dim, 0);
        let c = Constraint::parse("nope == 1").unwrap();
        assert!(c.compile(&params).is_err());
    }

    #[test]
    fn compiled_string_interning_spans_literals_and_params() {
        // The same text must compare equal whether it came from a literal
        // or from two different parameters' value grids.
        let params = vec![
            TunableParam::new("m1", vec!["a".to_string(), "b".to_string()]),
            TunableParam::new("m2", vec!["b".to_string(), "c".to_string()]),
        ];
        assert_compiled_matches_oracle("m1 == m2", &params);
        assert_compiled_matches_oracle("m1 == 'b' && m2 == 'b'", &params);
        assert_compiled_matches_oracle("m1 != 'a' || m2 != 'c'", &params);
    }
}
