//! The constraint expression language.
//!
//! Restrictions on a search space are written as boolean expressions over
//! parameter names, e.g. the CLBlast GEMM constraints:
//!
//! ```text
//! MWG % (MDIMC * VWM) == 0
//! (MDIMC * NDIMC) % 32 == 0 || (MDIMC * NDIMC) % 64 == 0
//! ```
//!
//! Grammar (Pratt parser, C-like precedence):
//!
//! ```text
//! expr   := or
//! or     := and ('||' and)*
//! and    := cmp ('&&' cmp)*
//! cmp    := sum (('=='|'!='|'<='|'>='|'<'|'>') sum)?
//! sum    := prod (('+'|'-') prod)*
//! prod   := unary (('*'|'/'|'%') unary)*
//! unary  := '!' unary | '-' unary | atom
//! atom   := number | string | ident | '(' expr ')'
//!         | ('min'|'max') '(' expr ',' expr ')'
//! ```
//!
//! Integer-valued operands use exact i64 arithmetic (so `%` behaves like
//! the Python constraints in Kernel Tuner specs); mixed or fractional
//! operands fall back to f64.

use super::param::Value;
use crate::bail;
use crate::error::{Context, Result};
use std::collections::HashMap;

/// A compiled constraint: source text + AST + referenced parameter names.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub source: String,
    expr: Expr,
    pub vars: Vec<String>,
}

impl Constraint {
    /// Parse a constraint expression.
    pub fn parse(source: &str) -> Result<Constraint> {
        let tokens = lex(source).with_context(|| format!("lexing {source:?}"))?;
        let mut p = Parser { tokens, pos: 0 };
        let expr = p.parse_expr(0)?;
        if p.pos != p.tokens.len() {
            bail!("trailing tokens in constraint {source:?}");
        }
        let mut vars = Vec::new();
        collect_vars(&expr, &mut vars);
        vars.sort();
        vars.dedup();
        Ok(Constraint {
            source: source.to_string(),
            expr,
            vars,
        })
    }

    /// Evaluate against a full assignment (name -> value).
    pub fn eval(&self, env: &dyn Fn(&str) -> Option<Value>) -> Result<bool> {
        match eval_expr(&self.expr, env)? {
            Num::Bool(b) => Ok(b),
            Num::Int(i) => Ok(i != 0),
            Num::Float(x) => Ok(x != 0.0),
            Num::Str(_) => bail!("constraint {:?} evaluated to a string", self.source),
        }
    }

    /// Evaluate with a HashMap environment (convenience).
    pub fn eval_map(&self, env: &HashMap<String, Value>) -> Result<bool> {
        self.eval(&|name| env.get(name).cloned())
    }
}

// ---------------------------------------------------------------------------
// Lexer

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Num(f64),
    Int(i64),
    Str(String),
    Ident(String),
    Op(&'static str),
    LParen,
    RParen,
    Comma,
}

fn lex(src: &str) -> Result<Vec<Tok>> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            b')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            b',' => {
                out.push(Tok::Comma);
                i += 1;
            }
            b'\'' | b'"' => {
                let quote = c;
                let start = i + 1;
                let mut j = start;
                while j < b.len() && b[j] != quote {
                    j += 1;
                }
                if j == b.len() {
                    bail!("unterminated string literal");
                }
                out.push(Tok::Str(src[start..j].to_string()));
                i = j + 1;
            }
            b'0'..=b'9' | b'.' => {
                let start = i;
                let mut is_float = false;
                while i < b.len()
                    && (b[i].is_ascii_digit() || b[i] == b'.' || b[i] == b'e' || b[i] == b'E'
                        || ((b[i] == b'+' || b[i] == b'-')
                            && i > start
                            && (b[i - 1] == b'e' || b[i - 1] == b'E')))
                {
                    if b[i] == b'.' || b[i] == b'e' || b[i] == b'E' {
                        is_float = true;
                    }
                    i += 1;
                }
                let text = &src[start..i];
                if is_float {
                    out.push(Tok::Num(text.parse()?));
                } else {
                    out.push(Tok::Int(text.parse()?));
                }
            }
            b'A'..=b'Z' | b'a'..=b'z' | b'_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
                out.push(Tok::Ident(src[start..i].to_string()));
            }
            _ => {
                let two = if i + 1 < b.len() { &src[i..i + 2] } else { "" };
                let op2 = ["==", "!=", "<=", ">=", "&&", "||"]
                    .iter()
                    .find(|&&o| o == two);
                if let Some(&op) = op2 {
                    out.push(Tok::Op(op));
                    i += 2;
                } else {
                    let one = &src[i..i + 1];
                    let op1 = ["+", "-", "*", "/", "%", "<", ">", "!"]
                        .iter()
                        .find(|&&o| o == one);
                    match op1 {
                        Some(&op) => {
                            out.push(Tok::Op(op));
                            i += 1;
                        }
                        None => bail!("unexpected character {:?} at {}", c as char, i),
                    }
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// AST + Pratt parser

#[derive(Clone, Debug)]
enum Expr {
    Int(i64),
    Float(f64),
    Str(String),
    Var(String),
    Unary(&'static str, Box<Expr>),
    Binary(&'static str, Box<Expr>, Box<Expr>),
    Call(&'static str, Vec<Expr>),
}

fn collect_vars(e: &Expr, out: &mut Vec<String>) {
    match e {
        Expr::Var(n) => out.push(n.clone()),
        Expr::Unary(_, a) => collect_vars(a, out),
        Expr::Binary(_, a, b) => {
            collect_vars(a, out);
            collect_vars(b, out);
        }
        Expr::Call(_, args) => args.iter().for_each(|a| collect_vars(a, out)),
        _ => {}
    }
}

struct Parser {
    tokens: Vec<Tok>,
    pos: usize,
}

fn binding_power(op: &str) -> Option<(u8, u8)> {
    Some(match op {
        "||" => (1, 2),
        "&&" => (3, 4),
        "==" | "!=" | "<" | ">" | "<=" | ">=" => (5, 6),
        "+" | "-" => (7, 8),
        "*" | "/" | "%" => (9, 10),
        _ => return None,
    })
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.tokens.get(self.pos).cloned();
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn parse_expr(&mut self, min_bp: u8) -> Result<Expr> {
        let mut lhs = match self.next() {
            Some(Tok::Int(i)) => Expr::Int(i),
            Some(Tok::Num(x)) => Expr::Float(x),
            Some(Tok::Str(s)) => Expr::Str(s),
            Some(Tok::Ident(name)) => {
                if (name == "min" || name == "max") && self.peek() == Some(&Tok::LParen) {
                    self.next();
                    let a = self.parse_expr(0)?;
                    if self.next() != Some(Tok::Comma) {
                        bail!("expected ',' in {name}()");
                    }
                    let b = self.parse_expr(0)?;
                    if self.next() != Some(Tok::RParen) {
                        bail!("expected ')' in {name}()");
                    }
                    let f: &'static str = if name == "min" { "min" } else { "max" };
                    Expr::Call(f, vec![a, b])
                } else if name == "True" || name == "true" {
                    Expr::Int(1)
                } else if name == "False" || name == "false" {
                    Expr::Int(0)
                } else {
                    Expr::Var(name)
                }
            }
            Some(Tok::LParen) => {
                let e = self.parse_expr(0)?;
                if self.next() != Some(Tok::RParen) {
                    bail!("expected ')'");
                }
                e
            }
            Some(Tok::Op("-")) => Expr::Unary("-", Box::new(self.parse_expr(11)?)),
            Some(Tok::Op("!")) => Expr::Unary("!", Box::new(self.parse_expr(11)?)),
            other => bail!("unexpected token {other:?}"),
        };

        loop {
            let op = match self.peek() {
                Some(Tok::Op(op)) => *op,
                _ => break,
            };
            let Some((lbp, rbp)) = binding_power(op) else {
                break;
            };
            if lbp < min_bp {
                break;
            }
            self.next();
            let rhs = self.parse_expr(rbp)?;
            lhs = Expr::Binary(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }
}

// ---------------------------------------------------------------------------
// Evaluator

#[derive(Clone, Debug)]
enum Num {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Num {
    fn to_f64(&self) -> Result<f64> {
        Ok(match self {
            Num::Int(i) => *i as f64,
            Num::Float(x) => *x,
            Num::Bool(b) => *b as i64 as f64,
            Num::Str(_) => bail!("string used in numeric context"),
        })
    }
}

fn from_value(v: Value) -> Num {
    match v {
        Value::Int(i) => Num::Int(i),
        Value::Float(x) => Num::Float(x),
        Value::Bool(b) => Num::Bool(b),
        Value::Str(s) => Num::Str(s),
    }
}

fn eval_expr(e: &Expr, env: &dyn Fn(&str) -> Option<Value>) -> Result<Num> {
    Ok(match e {
        Expr::Int(i) => Num::Int(*i),
        Expr::Float(x) => Num::Float(*x),
        Expr::Str(s) => Num::Str(s.clone()),
        Expr::Var(name) => from_value(
            env(name).with_context(|| format!("unknown parameter {name:?} in constraint"))?,
        ),
        Expr::Unary("-", a) => match eval_expr(a, env)? {
            Num::Int(i) => Num::Int(-i),
            other => Num::Float(-other.to_f64()?),
        },
        Expr::Unary("!", a) => {
            let v = eval_expr(a, env)?;
            Num::Bool(match v {
                Num::Bool(b) => !b,
                Num::Int(i) => i == 0,
                Num::Float(x) => x == 0.0,
                Num::Str(_) => bail!("! applied to string"),
            })
        }
        Expr::Unary(op, _) => bail!("unknown unary {op}"),
        Expr::Call(f, args) => {
            let a = eval_expr(&args[0], env)?;
            let b = eval_expr(&args[1], env)?;
            match (f, &a, &b) {
                (&"min", Num::Int(x), Num::Int(y)) => Num::Int(*x.min(y)),
                (&"max", Num::Int(x), Num::Int(y)) => Num::Int(*x.max(y)),
                (&"min", _, _) => Num::Float(a.to_f64()?.min(b.to_f64()?)),
                (&"max", _, _) => Num::Float(a.to_f64()?.max(b.to_f64()?)),
                _ => bail!("unknown function {f}"),
            }
        }
        Expr::Binary(op, a, b) => {
            // Short-circuit logicals.
            if *op == "&&" || *op == "||" {
                let av = truthy(eval_expr(a, env)?)?;
                return Ok(Num::Bool(if *op == "&&" {
                    av && truthy(eval_expr(b, env)?)?
                } else {
                    av || truthy(eval_expr(b, env)?)?
                }));
            }
            let av = eval_expr(a, env)?;
            let bv = eval_expr(b, env)?;
            // String equality.
            if let (Num::Str(x), Num::Str(y)) = (&av, &bv) {
                return Ok(match *op {
                    "==" => Num::Bool(x == y),
                    "!=" => Num::Bool(x != y),
                    _ => bail!("operator {op} not defined on strings"),
                });
            }
            // Exact integer arithmetic when both sides are ints.
            if let (Num::Int(x), Num::Int(y)) = (&av, &bv) {
                let (x, y) = (*x, *y);
                return Ok(match *op {
                    "+" => Num::Int(x.wrapping_add(y)),
                    "-" => Num::Int(x.wrapping_sub(y)),
                    "*" => Num::Int(x.wrapping_mul(y)),
                    "/" => {
                        if y == 0 {
                            bail!("division by zero");
                        }
                        // Python-style floor semantics are not needed by the
                        // kernel specs; constraints use exact divisibility.
                        Num::Int(x / y)
                    }
                    "%" => {
                        if y == 0 {
                            bail!("modulo by zero");
                        }
                        Num::Int(x.rem_euclid(y))
                    }
                    "==" => Num::Bool(x == y),
                    "!=" => Num::Bool(x != y),
                    "<" => Num::Bool(x < y),
                    ">" => Num::Bool(x > y),
                    "<=" => Num::Bool(x <= y),
                    ">=" => Num::Bool(x >= y),
                    _ => bail!("unknown operator {op}"),
                });
            }
            let x = av.to_f64()?;
            let y = bv.to_f64()?;
            match *op {
                "+" => Num::Float(x + y),
                "-" => Num::Float(x - y),
                "*" => Num::Float(x * y),
                "/" => Num::Float(x / y),
                "%" => Num::Float(x.rem_euclid(y)),
                "==" => Num::Bool(x == y),
                "!=" => Num::Bool(x != y),
                "<" => Num::Bool(x < y),
                ">" => Num::Bool(x > y),
                "<=" => Num::Bool(x <= y),
                ">=" => Num::Bool(x >= y),
                _ => bail!("unknown operator {op}"),
            }
        }
    })
}

fn truthy(n: Num) -> Result<bool> {
    Ok(match n {
        Num::Bool(b) => b,
        Num::Int(i) => i != 0,
        Num::Float(x) => x != 0.0,
        Num::Str(_) => bail!("string used as boolean"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env_of(pairs: &[(&str, Value)]) -> HashMap<String, Value> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn arithmetic_and_modulo() {
        let c = Constraint::parse("MWG % (MDIMC * VWM) == 0").unwrap();
        assert_eq!(c.vars, vec!["MDIMC", "MWG", "VWM"]);
        let env = env_of(&[
            ("MWG", Value::Int(64)),
            ("MDIMC", Value::Int(8)),
            ("VWM", Value::Int(4)),
        ]);
        assert!(c.eval_map(&env).unwrap());
        let env = env_of(&[
            ("MWG", Value::Int(48)),
            ("MDIMC", Value::Int(8)),
            ("VWM", Value::Int(4)),
        ]);
        assert!(!c.eval_map(&env).unwrap());
    }

    #[test]
    fn logicals_and_comparison() {
        let c = Constraint::parse("a * b <= 1024 && (a == 32 || b >= 4)").unwrap();
        let t = env_of(&[("a", Value::Int(32)), ("b", Value::Int(2))]);
        assert!(c.eval_map(&t).unwrap());
        let f = env_of(&[("a", Value::Int(64)), ("b", Value::Int(2))]);
        assert!(!c.eval_map(&f).unwrap());
    }

    #[test]
    fn string_equality() {
        let c = Constraint::parse("method == 'uniform' || method == \"two_point\"").unwrap();
        assert!(c
            .eval_map(&env_of(&[("method", Value::Str("uniform".into()))]))
            .unwrap());
        assert!(!c
            .eval_map(&env_of(&[("method", Value::Str("single".into()))]))
            .unwrap());
    }

    #[test]
    fn unary_and_functions() {
        let c = Constraint::parse("!(x > 3) && min(x, 10) == x && max(x, -1) == x").unwrap();
        assert!(c.eval_map(&env_of(&[("x", Value::Int(2))])).unwrap());
        assert!(!c.eval_map(&env_of(&[("x", Value::Int(5))])).unwrap());
    }

    #[test]
    fn float_arithmetic() {
        let c = Constraint::parse("t * 2.0 >= 1.0").unwrap();
        assert!(c.eval_map(&env_of(&[("t", Value::Float(0.5))])).unwrap());
        assert!(!c.eval_map(&env_of(&[("t", Value::Float(0.4))])).unwrap());
    }

    #[test]
    fn precedence() {
        let c = Constraint::parse("2 + 3 * 4 == 14").unwrap();
        assert!(c.eval_map(&HashMap::new()).unwrap());
        let c = Constraint::parse("(2 + 3) * 4 == 20").unwrap();
        assert!(c.eval_map(&HashMap::new()).unwrap());
    }

    #[test]
    fn errors() {
        assert!(Constraint::parse("a &&& b").is_err());
        assert!(Constraint::parse("(a").is_err());
        assert!(Constraint::parse("a ==").is_err());
        let c = Constraint::parse("missing == 1").unwrap();
        assert!(c.eval_map(&HashMap::new()).is_err());
        let c = Constraint::parse("1 / 0 == 1").unwrap();
        assert!(c.eval_map(&HashMap::new()).is_err());
    }

    #[test]
    fn booleans_in_env() {
        let c = Constraint::parse("use_padding == 1 || tile == 1").unwrap();
        assert!(c
            .eval_map(&env_of(&[
                ("use_padding", Value::Bool(true)),
                ("tile", Value::Int(4)),
            ]))
            .unwrap());
    }
}
