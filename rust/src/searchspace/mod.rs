//! The auto-tuning search-space engine.
//!
//! A search space is the set of valid kernel configurations: the Cartesian
//! product of every tunable parameter's value list, filtered by
//! user-defined constraints (Section III-A of the paper). This module
//! provides:
//!
//! * [`param`] — parameter values and definitions,
//! * [`constraint`] — a small expression language for restrictions such as
//!   `MWG % (MDIMC * VWM) == 0`,
//! * [`space`] — enumeration with prefix pruning, config⇄index mapping,
//!   neighbor graphs and sampling.
//!
//! # Packed-rank engine
//!
//! The config⇄index mapping is a **mixed-radix packed-rank** design
//! rather than a hash map keyed by encoded vectors:
//!
//! * **Strides.** At `build()` time each dimension gets a stride
//!   `strides[d] = Π dims[d+1..]`, so an encoded configuration packs into
//!   a single `u64` Cartesian rank `Σ enc[d] * strides[d]`. Moving one
//!   dimension is one add/subtract of a stride — neighbor candidates and
//!   local-search probes never materialize an encoded vector.
//! * **Rank select.** Validity lookup (`index_of_rank`) is served by one
//!   of three interchangeable indexes ([`space::IndexKind`]). Up to 2^26
//!   Cartesian ranks, a bitset with a per-64-bit-word popcount prefix:
//!   bit test + `prefix[word] + popcnt(word & below)` — two array reads
//!   and a popcount (≤ 8 MiB bits + 4 MiB prefix at the threshold).
//!   Beyond, a **compressed sampled-select** over the sorted valid ranks:
//!   `rank >> shift` buckets of average occupancy ≤ 4 plus a tiny binary
//!   search, with memory proportional to the *valid* count — there is no
//!   Cartesian-size ceiling. A `u64 → usize` hash map remains as the
//!   reference implementation. All three return identical indices.
//! * **Memory layout.** Valid encoded configs live in one row-major
//!   `Vec<u16>` SoA buffer (`flat`, stride = ndim) while small; past
//!   [`space::FlatPolicy`]'s 64 MiB threshold the buffer is elided and
//!   decode is stride-based off the packed rank (`digit`,
//!   `encoded_into`); per-index ranks are a parallel `Vec<u64>`. There is
//!   no vec-of-vecs.
//! * **Compiled constraints.** Enumeration evaluates constraints through
//!   [`constraint::CompiledConstraint`] — typed stack bytecode with
//!   variables resolved to per-dimension slots over encoded digits — so
//!   prefix pruning costs no name lookups or per-eval allocation;
//!   per-depth pruning counters land in [`space::BuildStats`]. Synthetic
//!   constrained spaces at any scale come from [`spacegen`].
//!
//! * **CSR neighbor graphs.** Each `(space, neighborhood)` pair lazily
//!   builds a compressed-sparse-row adjacency on first use, after which
//!   `neighbors` is a borrowed `&[u32]` slice — zero probes — at
//!   ~O(Σ|N(v)|) memory; the shared local-search engine in
//!   [`crate::optimizers::localsearch`] walks these slices.
//!
//! Hot queries (`index_of`, `with_dim`, `random_neighbor`,
//! `for_each_neighbor`, `neighbors`, `snap`, `snap_encoded`) perform zero
//! heap allocations per call (the CSR build being a one-time cost).
//!
//! The same engine backs both levels of the paper: *kernel* configuration
//! spaces (L3 tuning) and *hyperparameter* configuration spaces
//! (hypertuning — "tuning the tuner"), which is exactly how the paper
//! reuses its auto-tuner machinery as a meta-strategy.

pub mod param;
pub mod constraint;
pub mod space;
pub mod spacegen;

pub use constraint::{CompiledConstraint, Constraint, EvalScratch};
pub use param::{TunableParam, Value};
pub use space::{BuildOptions, BuildStats, FlatPolicy, IndexKind, Neighborhood, SearchSpace};
pub use spacegen::{ConstraintFamily, SpaceGenSpec};
