//! The auto-tuning search-space engine.
//!
//! A search space is the set of valid kernel configurations: the Cartesian
//! product of every tunable parameter's value list, filtered by
//! user-defined constraints (Section III-A of the paper). This module
//! provides:
//!
//! * [`param`] — parameter values and definitions,
//! * [`constraint`] — a small expression language for restrictions such as
//!   `MWG % (MDIMC * VWM) == 0`,
//! * [`space`] — enumeration with prefix pruning, config⇄index mapping,
//!   neighbor graphs and sampling.
//!
//! The same engine backs both levels of the paper: *kernel* configuration
//! spaces (L3 tuning) and *hyperparameter* configuration spaces
//! (hypertuning — "tuning the tuner"), which is exactly how the paper
//! reuses its auto-tuner machinery as a meta-strategy.

pub mod param;
pub mod constraint;
pub mod space;

pub use constraint::Constraint;
pub use param::{TunableParam, Value};
pub use space::{Neighborhood, SearchSpace};
