//! The auto-tuning search-space engine.
//!
//! A search space is the set of valid kernel configurations: the Cartesian
//! product of every tunable parameter's value list, filtered by
//! user-defined constraints (Section III-A of the paper). This module
//! provides:
//!
//! * [`param`] — parameter values and definitions,
//! * [`constraint`] — a small expression language for restrictions such as
//!   `MWG % (MDIMC * VWM) == 0`,
//! * [`space`] — enumeration with prefix pruning, config⇄index mapping,
//!   neighbor graphs and sampling.
//!
//! # Packed-rank engine
//!
//! The config⇄index mapping is a **mixed-radix packed-rank** design
//! rather than a hash map keyed by encoded vectors:
//!
//! * **Strides.** At `build()` time each dimension gets a stride
//!   `strides[d] = Π dims[d+1..]`, so an encoded configuration packs into
//!   a single `u64` Cartesian rank `Σ enc[d] * strides[d]`. Moving one
//!   dimension is one add/subtract of a stride — neighbor candidates and
//!   local-search probes never materialize an encoded vector.
//! * **Bitset rank/select.** Validity is a bitset over Cartesian ranks
//!   with a per-64-bit-word popcount prefix. `index_of` = bit test +
//!   `prefix[word] + popcnt(word & below)`: two array reads and a
//!   popcount, no hashing, no allocation. Cartesian products beyond 2^26
//!   fall back to a `u64 → usize` hash map (still allocation-free per
//!   lookup). Memory: ≤ 8 MiB bits + 4 MiB prefix at the threshold.
//! * **Memory layout.** All valid encoded configs live in one row-major
//!   `Vec<u16>` SoA buffer (`flat`, stride = ndim) — the single source of
//!   truth for decoding and the cache-friendly scan that `snap()` uses;
//!   per-index ranks are a parallel `Vec<u64>`. There is no vec-of-vecs.
//!
//! * **CSR neighbor graphs.** Each `(space, neighborhood)` pair lazily
//!   builds a compressed-sparse-row adjacency on first use, after which
//!   `neighbors` is a borrowed `&[u32]` slice — zero probes — at
//!   ~O(Σ|N(v)|) memory; the shared local-search engine in
//!   [`crate::optimizers::localsearch`] walks these slices.
//!
//! Hot queries (`index_of`, `with_dim`, `random_neighbor`,
//! `for_each_neighbor`, `neighbors`, `snap`, `snap_encoded`) perform zero
//! heap allocations per call (the CSR build being a one-time cost).
//!
//! The same engine backs both levels of the paper: *kernel* configuration
//! spaces (L3 tuning) and *hyperparameter* configuration spaces
//! (hypertuning — "tuning the tuner"), which is exactly how the paper
//! reuses its auto-tuner machinery as a meta-strategy.

pub mod param;
pub mod constraint;
pub mod space;

pub use constraint::Constraint;
pub use param::{TunableParam, Value};
pub use space::{Neighborhood, SearchSpace};
