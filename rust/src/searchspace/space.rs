//! Search-space enumeration, indexing, neighbors and sampling.
//!
//! Enumeration walks the Cartesian product in odometer order, evaluating
//! each constraint as soon as all of its referenced parameters are bound
//! (prefix pruning), which skips entire subtrees of invalid assignments —
//! the same idea behind efficient search-space construction in the
//! Kernel Tuner ecosystem.

use super::constraint::Constraint;
use super::param::{TunableParam, Value};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use crate::util::hash::FastMap;
use std::collections::HashMap;

/// Encoded configuration: per-dimension value indices.
pub type Encoded = Vec<u16>;

/// Neighborhood definitions for local-search moves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Neighborhood {
    /// Change one dimension to any other value.
    Hamming,
    /// Change one dimension to an adjacent value index (±1).
    Adjacent,
}

/// A fully enumerated, constraint-filtered search space.
///
/// Valid configurations are indexed `0..len()`; optimizers address
/// configurations by index and decode only when needed.
pub struct SearchSpace {
    pub name: String,
    pub params: Vec<TunableParam>,
    pub constraints: Vec<Constraint>,
    valid: Vec<Encoded>,
    /// Row-major flattened copy of `valid` (stride = ndim): contiguous
    /// storage for the snap() distance scan, which is cache-miss bound on
    /// the nested Vec layout.
    flat: Vec<u16>,
    index: FastMap<Encoded, usize>,
    /// Per-dimension cardinalities.
    dims: Vec<usize>,
}

impl SearchSpace {
    /// Enumerate the valid configurations of `params` under `constraints`.
    pub fn build(
        name: &str,
        params: Vec<TunableParam>,
        constraints: Vec<Constraint>,
    ) -> Result<SearchSpace> {
        let n = params.len();
        if n == 0 {
            bail!("search space {name:?} has no parameters");
        }
        if n > u16::MAX as usize {
            bail!("too many parameters");
        }
        let dims: Vec<usize> = params.iter().map(|p| p.cardinality()).collect();
        let name_to_dim: HashMap<&str, usize> = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.as_str(), i))
            .collect();

        // Bind each constraint to the earliest odometer depth at which all
        // of its variables are assigned.
        let mut by_depth: Vec<Vec<&Constraint>> = vec![Vec::new(); n];
        for c in &constraints {
            let mut max_dim = 0usize;
            for v in &c.vars {
                match name_to_dim.get(v.as_str()) {
                    Some(&d) => max_dim = max_dim.max(d),
                    None => bail!(
                        "constraint {:?} references unknown parameter {v:?}",
                        c.source
                    ),
                }
            }
            by_depth[max_dim].push(c);
        }

        let mut valid: Vec<Encoded> = Vec::new();
        let mut cursor: Encoded = vec![0; n];
        // env closure over a prefix of assignments
        let mut depth = 0usize;
        'outer: loop {
            // Check constraints that become fully bound at this depth.
            let assignment_ok = {
                let cursor_ref = &cursor;
                let params_ref = &params;
                let env = |name: &str| -> Option<Value> {
                    let d = *name_to_dim.get(name)?;
                    if d > depth {
                        return None;
                    }
                    Some(params_ref[d].values[cursor_ref[d] as usize].clone())
                };
                by_depth[depth]
                    .iter()
                    .all(|c| c.eval(&env).unwrap_or(false))
            };

            if assignment_ok {
                if depth + 1 == n {
                    valid.push(cursor.clone());
                } else {
                    depth += 1;
                    cursor[depth] = 0;
                    continue 'outer;
                }
            }

            // Advance odometer at current depth, backtracking when exhausted.
            loop {
                cursor[depth] += 1;
                if (cursor[depth] as usize) < dims[depth] {
                    break;
                }
                if depth == 0 {
                    break 'outer;
                }
                depth -= 1;
            }
        }

        let index: FastMap<Encoded, usize> = valid
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, e)| (e, i))
            .collect();
        let flat: Vec<u16> = valid.iter().flatten().copied().collect();
        Ok(SearchSpace {
            name: name.to_string(),
            params,
            constraints,
            valid,
            flat,
            index,
            dims,
        })
    }

    /// Number of valid configurations.
    pub fn len(&self) -> usize {
        self.valid.len()
    }

    pub fn is_empty(&self) -> bool {
        self.valid.is_empty()
    }

    /// Size of the unconstrained Cartesian product.
    pub fn cartesian_size(&self) -> u128 {
        self.dims.iter().map(|&d| d as u128).product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Encoded configuration at a valid index.
    pub fn encoded(&self, idx: usize) -> &Encoded {
        &self.valid[idx]
    }

    /// Decode to parameter values.
    pub fn values(&self, idx: usize) -> Vec<Value> {
        self.valid[idx]
            .iter()
            .zip(&self.params)
            .map(|(&vi, p)| p.values[vi as usize].clone())
            .collect()
    }

    /// name=value map for a configuration (for JSON output).
    pub fn named_values(&self, idx: usize) -> Vec<(String, Value)> {
        self.valid[idx]
            .iter()
            .zip(&self.params)
            .map(|(&vi, p)| (p.name.clone(), p.values[vi as usize].clone()))
            .collect()
    }

    /// Stable key string like `64,8,uniform` for hashing/serialization.
    pub fn key(&self, idx: usize) -> String {
        self.values(idx)
            .iter()
            .map(|v| v.key())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Index of an encoded configuration (None if invalid).
    pub fn index_of(&self, enc: &Encoded) -> Option<usize> {
        self.index.get(enc).copied()
    }

    /// Uniform random valid configuration.
    pub fn random(&self, rng: &mut Rng) -> usize {
        rng.below(self.len())
    }

    /// Distinct random sample of k valid configurations.
    pub fn sample(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        rng.sample_indices(self.len(), k.min(self.len()))
    }

    /// Neighbor indices of a configuration under a neighborhood.
    ///
    /// Results are valid configurations only. For `Adjacent`, if neither
    /// ±1 of a dimension yields a valid config, that dimension contributes
    /// nothing (matching Kernel Tuner's 'strictly-adjacent' behavior).
    pub fn neighbors(&self, idx: usize, hood: Neighborhood) -> Vec<usize> {
        let enc = &self.valid[idx];
        let mut out = Vec::new();
        let mut probe = enc.clone();
        for d in 0..self.dims.len() {
            let orig = enc[d];
            match hood {
                Neighborhood::Hamming => {
                    for v in 0..self.dims[d] as u16 {
                        if v == orig {
                            continue;
                        }
                        probe[d] = v;
                        if let Some(i) = self.index_of(&probe) {
                            out.push(i);
                        }
                    }
                }
                Neighborhood::Adjacent => {
                    if orig > 0 {
                        probe[d] = orig - 1;
                        if let Some(i) = self.index_of(&probe) {
                            out.push(i);
                        }
                    }
                    if (orig as usize) + 1 < self.dims[d] {
                        probe[d] = orig + 1;
                        if let Some(i) = self.index_of(&probe) {
                            out.push(i);
                        }
                    }
                }
            }
            probe[d] = orig;
        }
        out
    }

    /// A random valid neighbor, falling back to a random config if the
    /// neighborhood is empty (keeps stochastic optimizers moving).
    ///
    /// Hot path for annealing-type walks: O(1) rejection sampling (pick a
    /// dimension, pick a different value, check validity) with a bounded
    /// number of tries before falling back to full enumeration. Not
    /// perfectly uniform over the neighborhood, but each valid neighbor
    /// has positive probability — the property the walks need.
    pub fn random_neighbor(&self, idx: usize, hood: Neighborhood, rng: &mut Rng) -> usize {
        let enc = &self.valid[idx];
        let ndim = self.dims.len();
        let mut probe = enc.clone();
        for _ in 0..16 {
            let d = rng.below(ndim);
            if self.dims[d] < 2 {
                continue;
            }
            let orig = enc[d];
            let cand = match hood {
                Neighborhood::Hamming => {
                    let mut v = rng.below(self.dims[d]) as u16;
                    if v == orig {
                        v = (v + 1) % self.dims[d] as u16;
                    }
                    v
                }
                Neighborhood::Adjacent => {
                    let up = rng.chance(0.5);
                    if up && (orig as usize) + 1 < self.dims[d] {
                        orig + 1
                    } else if !up && orig > 0 {
                        orig - 1
                    } else {
                        continue;
                    }
                }
            };
            probe[d] = cand;
            if let Some(i) = self.index_of(&probe) {
                return i;
            }
            probe[d] = orig;
        }
        // Rare: dense constraints around this point; enumerate.
        let ns = self.neighbors(idx, hood);
        if ns.is_empty() {
            self.random(rng)
        } else {
            *rng.choose(&ns)
        }
    }

    /// Nearest-ish valid configuration to an arbitrary encoded point
    /// (used by continuous optimizers like PSO that propose off-lattice
    /// points).
    ///
    /// Hot path (PSO snaps every particle move): round to the lattice and
    /// accept if valid; otherwise pick the closest of 64 random valid
    /// candidates by L1 distance (exact nearest would be O(|space|)).
    pub fn snap(&self, target: &[f64], rng: &mut Rng) -> usize {
        // Round to the lattice first; if valid, done.
        let enc: Encoded = target
            .iter()
            .zip(&self.dims)
            .map(|(&t, &d)| (t.round().clamp(0.0, (d - 1) as f64)) as u16)
            .collect();
        if let Some(i) = self.index_of(&enc) {
            return i;
        }
        // Distance-biased random-candidate search over the flattened
        // storage (contiguous u16 rows; the nested-Vec layout made this
        // loop cache-miss bound). Distances use the already-rounded
        // target in integer arithmetic. (A jittered local repair with
        // hash probes was tried and measured 2x slower: constraint
        // patterns like divisibility are rarely fixed by ±1 jitter.)
        let ndim = self.dims.len();
        let mut best = usize::MAX;
        let mut best_dist = f64::INFINITY;
        let n = self.len();
        for _ in 0..64.min(n) {
            let cand = rng.below(n);
            let row = &self.flat[cand * ndim..(cand + 1) * ndim];
            let dist: f64 = row
                .iter()
                .zip(target)
                .map(|(&v, &t)| (v as f64 - t).abs())
                .sum();
            if dist < best_dist {
                best_dist = dist;
                best = cand;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space_2d() -> SearchSpace {
        SearchSpace::build(
            "t",
            vec![
                TunableParam::new("a", vec![1i64, 2, 4, 8]),
                TunableParam::new("b", vec![1i64, 2, 4]),
            ],
            vec![Constraint::parse("a * b <= 8").unwrap()],
        )
        .unwrap()
    }

    #[test]
    fn enumeration_respects_constraints() {
        let s = space_2d();
        // valid pairs: (1,1)(1,2)(1,4)(2,1)(2,2)(2,4)(4,1)(4,2)(8,1) = 9
        assert_eq!(s.len(), 9);
        assert_eq!(s.cartesian_size(), 12);
        for i in 0..s.len() {
            let v = s.values(i);
            let a = v[0].as_i64().unwrap();
            let b = v[1].as_i64().unwrap();
            assert!(a * b <= 8);
        }
    }

    #[test]
    fn index_roundtrip() {
        let s = space_2d();
        for i in 0..s.len() {
            assert_eq!(s.index_of(s.encoded(i)), Some(i));
        }
        assert_eq!(s.index_of(&vec![3, 2]), None); // (8,4) invalid
    }

    #[test]
    fn prefix_pruning_equals_naive() {
        // Multi-constraint space: compare against naive filtering.
        let params = vec![
            TunableParam::new("x", vec![0i64, 1, 2, 3, 4, 5]),
            TunableParam::new("y", vec![0i64, 1, 2, 3, 4, 5]),
            TunableParam::new("z", vec![0i64, 1, 2]),
        ];
        let cs = vec![
            Constraint::parse("x % 2 == 0").unwrap(),
            Constraint::parse("x + y <= 6").unwrap(),
            Constraint::parse("z < 2 || y == 0").unwrap(),
        ];
        let s = SearchSpace::build("t", params.clone(), cs.clone()).unwrap();
        let mut naive = 0;
        for x in 0..6i64 {
            for y in 0..6i64 {
                for z in 0..3i64 {
                    if x % 2 == 0 && x + y <= 6 && (z < 2 || y == 0) {
                        naive += 1;
                    }
                }
            }
        }
        assert_eq!(s.len(), naive);
    }

    #[test]
    fn neighbors_hamming_and_adjacent() {
        let s = space_2d();
        let idx = s.index_of(&vec![0, 0]).unwrap(); // (1,1)
        let h = s.neighbors(idx, Neighborhood::Hamming);
        // change a: (2,1)(4,1)(8,1); change b: (1,2)(1,4) => 5
        assert_eq!(h.len(), 5);
        let adj = s.neighbors(idx, Neighborhood::Adjacent);
        // a->2 (valid), b->2 (valid) => 2
        assert_eq!(adj.len(), 2);
        // All neighbors valid and distinct from self.
        for &n in h.iter().chain(adj.iter()) {
            assert_ne!(n, idx);
            assert!(n < s.len());
        }
    }

    #[test]
    fn sampling_in_range() {
        let s = space_2d();
        let mut rng = Rng::new(1);
        let sample = s.sample(&mut rng, 5);
        assert_eq!(sample.len(), 5);
        assert!(sample.iter().all(|&i| i < s.len()));
        for _ in 0..100 {
            assert!(s.random(&mut rng) < s.len());
        }
    }

    #[test]
    fn snap_valid() {
        let s = space_2d();
        let mut rng = Rng::new(2);
        let i = s.snap(&[2.9, 1.8], &mut rng);
        assert!(i < s.len());
        // (8,4) rounds to invalid; snap must still return a valid config
        let i = s.snap(&[3.0, 2.0], &mut rng);
        assert!(i < s.len());
    }

    #[test]
    fn unknown_constraint_var_rejected() {
        let r = SearchSpace::build(
            "t",
            vec![TunableParam::new("a", vec![1i64])],
            vec![Constraint::parse("nope == 1").unwrap()],
        );
        assert!(r.is_err());
    }

    #[test]
    fn key_stable() {
        let s = space_2d();
        let i = s.index_of(&vec![1, 2]).unwrap();
        assert_eq!(s.key(i), "2,4");
    }
}
