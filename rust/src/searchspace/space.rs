//! Search-space enumeration, indexing, neighbors and sampling.
//!
//! Enumeration walks the Cartesian product in odometer order, evaluating
//! each constraint as soon as all of its referenced parameters are bound
//! (prefix pruning), which skips entire subtrees of invalid assignments —
//! the same idea behind efficient search-space construction in the
//! Kernel Tuner ecosystem.
//!
//! # Packed-rank representation
//!
//! Configurations are addressed internally by their **mixed-radix
//! Cartesian rank**: a single `u64` computed from per-dimension strides
//! (`strides[d] = Π dims[d+1..]`, so `rank = Σ enc[d] * strides[d]`).
//! Because enumeration is lexicographic, ranks of valid configurations are
//! strictly increasing, and the valid-config index is exactly the number
//! of valid ranks below a given rank. Three interchangeable rank indexes
//! serve that select ([`IndexKind`]): a bitset over Cartesian ranks with a
//! per-word popcount prefix (two array reads plus one `popcnt`; memory
//! proportional to the *Cartesian* size, so only worthwhile up to 2^26
//! ranks), a `u64 → usize` hash map (reference/fallback), and the default
//! past the bitset range — a **compressed sampled-select** over the sorted
//! valid ranks (`rank >> shift` buckets of average occupancy ≤ 4, one
//! shift plus a tiny binary search per lookup; memory proportional to the
//! *valid* count, so there is no Cartesian-size ceiling at all). All three
//! return identical indices; tuning traces are bitwise-equal across them.
//!
//! Encoded configurations live in one row-major `Vec<u16>` (the SoA
//! `flat` buffer) while the space is small; above [`FlatPolicy`]'s
//! threshold the buffer is elided and decode is stride-based from the
//! packed rank ([`SearchSpace::digit`] / [`SearchSpace::encoded_into`]),
//! halving resident memory on million-config constrained spaces.
//!
//! Constraints are evaluated during enumeration through their compiled
//! bytecode form ([`super::constraint::CompiledConstraint`]) bound
//! directly to encoded digits — no name lookups or per-eval allocation —
//! with per-depth prefix-pruning counters recorded in [`BuildStats`].
//!
//! # CSR neighbor graphs
//!
//! Local-search-heavy optimizers re-walk the same neighborhoods every
//! descent, so each `(space, neighborhood)` pair additionally carries a
//! **compressed-sparse-row adjacency** built lazily on first use:
//! [`SearchSpace::neighbors`] then returns a borrowed `&[u32]` slice —
//! zero probes and zero allocation per call — while the probing visitor
//! [`SearchSpace::for_each_neighbor`] remains available for one-shot
//! traversals (and is what the CSR build itself uses, so the two paths
//! agree element-for-element by construction).

use super::constraint::{CompiledConstraint, Constraint, EvalScratch};
use super::param::{TunableParam, Value};
use crate::util::hash::FastMap;
use crate::util::rng::Rng;
use crate::bail;
use crate::error::{Result, TuneError};
use std::sync::OnceLock;

/// Encoded configuration: per-dimension value indices.
pub type Encoded = Vec<u16>;

/// Largest Cartesian product served by the rank/select bitset under
/// [`IndexKind::Auto`]; past this the compressed sampled-select index
/// takes over (the old hard 2^26 ceiling is gone). 2^26 ranks cost at
/// most 8 MiB of bits + 4 MiB of prefix counts.
const BITSET_MAX_RANKS: u128 = 1 << 26;

/// Largest `len() * ndim` (in u16 cells, 64 MiB) for which
/// [`FlatPolicy::Auto`] materializes the row-major `flat` decode buffer;
/// beyond this, decode is stride-based from the packed rank.
const FLAT_MAX_CELLS: usize = 1 << 25;

/// Which rank-index variant backs [`SearchSpace::index_of_rank`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum IndexKind {
    /// Bitset up to [`BITSET_MAX_RANKS`] Cartesian ranks, compressed
    /// sampled-select beyond. The right choice everywhere; the explicit
    /// variants exist for tests and benchmarks.
    #[default]
    Auto,
    /// Rank/select bitset over Cartesian ranks. Errors at build time past
    /// 2^26 Cartesian ranks (memory is proportional to the Cartesian
    /// product, not the valid count).
    Bitset,
    /// `u64 → usize` hash map (reference implementation).
    Map,
    /// Bucketed sampled-select over the sorted valid ranks; memory is
    /// proportional to the valid count only.
    Compressed,
}

/// Whether to materialize the row-major `flat` decode buffer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FlatPolicy {
    /// Materialize up to [`FLAT_MAX_CELLS`] cells, elide beyond.
    #[default]
    Auto,
    Materialize,
    Elide,
}

/// Build-time knobs for [`SearchSpace::build_with`].
#[derive(Clone, Copy, Debug, Default)]
pub struct BuildOptions {
    pub index: IndexKind,
    pub flat: FlatPolicy,
}

/// Per-depth prefix-pruning counters recorded during enumeration.
#[derive(Clone, Debug, Default)]
pub struct BuildStats {
    /// Prefix assignments rejected at each odometer depth; a rejection at
    /// depth `d` prunes the whole `Π dims[d+1..]`-config subtree without
    /// visiting it.
    pub prefix_rejections: Vec<u64>,
    /// Total Cartesian configs ruled out by those rejections (counting 1
    /// for a leaf-depth rejection).
    pub pruned_configs: u128,
}

/// Neighborhood definitions for local-search moves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Neighborhood {
    /// Change one dimension to any other value.
    Hamming,
    /// Change one dimension to an adjacent value index (±1).
    Adjacent,
}

impl Neighborhood {
    /// Slot in the per-space CSR graph array.
    fn slot(self) -> usize {
        match self {
            Neighborhood::Hamming => 0,
            Neighborhood::Adjacent => 1,
        }
    }
}

/// Precomputed compressed-sparse-row adjacency for one neighborhood:
/// the neighbors of config `i` are `targets[offsets[i]..offsets[i + 1]]`,
/// in the same dimension-major order `for_each_neighbor` visits them.
struct CsrGraph {
    offsets: Vec<usize>,
    targets: Vec<u32>,
}

/// Validity index over packed Cartesian ranks.
enum RankIndex {
    /// Bitset with per-word rank (popcount prefix) for O(1) select.
    Bitset { words: Vec<u64>, prefix: Vec<u32> },
    /// Hash-map reference implementation.
    Map(FastMap<u64, usize>),
    /// Bucketed sampled-select over the sorted `ranks` array: a rank's
    /// bucket is `rank >> shift`, and `starts[b]..starts[b + 1]` bounds
    /// the slice of `ranks` falling in bucket `b`. Bucket count is ~len/4
    /// (average occupancy ≤ 4), so a lookup is one shift plus a tiny
    /// binary search — no bitset, no hashing, and ~2 bytes per valid
    /// config regardless of the Cartesian size.
    Compressed { starts: Vec<u64>, shift: u32 },
}

/// Build the compressed sampled-select index over sorted valid ranks.
/// `cart` is the (already range-checked) Cartesian size, `>= 1`.
fn build_compressed(ranks: &[u64], cart: u128) -> RankIndex {
    let cart_m1 = (cart - 1) as u64;
    // Bits needed to address any rank; 0 when the space has one rank.
    let rank_bits = 64 - cart_m1.leading_zeros();
    let ceil_log2 = |x: u64| if x <= 1 { 0 } else { 64 - (x - 1).leading_zeros() };
    // ~len/4 power-of-two buckets.
    let bucket_bits = ceil_log2(ranks.len().max(1) as u64).saturating_sub(2);
    let shift = rank_bits.saturating_sub(bucket_bits);
    let nbuckets = (cart_m1 >> shift) as usize + 1;
    let mut starts = vec![0u64; nbuckets + 1];
    for &r in ranks {
        starts[(r >> shift) as usize + 1] += 1;
    }
    for b in 1..starts.len() {
        starts[b] += starts[b - 1];
    }
    RankIndex::Compressed { starts, shift }
}

/// A fully enumerated, constraint-filtered search space.
///
/// Valid configurations are indexed `0..len()`; optimizers address
/// configurations by index and decode only when needed.
pub struct SearchSpace {
    pub name: String,
    pub params: Vec<TunableParam>,
    pub constraints: Vec<Constraint>,
    /// Row-major SoA of all valid encoded configs (stride = ndim):
    /// contiguous storage for decode and the snap() distance scan.
    /// `None` when elided per [`FlatPolicy`]; decode then goes
    /// stride-based through [`SearchSpace::digit`].
    flat: Option<Vec<u16>>,
    /// Packed Cartesian rank of each valid config (ascending).
    ranks: Vec<u64>,
    index: RankIndex,
    /// Prefix-pruning counters from the build enumeration.
    stats: BuildStats,
    /// Per-dimension cardinalities.
    dims: Vec<usize>,
    /// Mixed-radix strides: `strides[d] = Π dims[d+1..]`.
    strides: Vec<u64>,
    /// Lazily built CSR neighbor graphs, one per [`Neighborhood`]
    /// (`[Hamming, Adjacent]`). Local-search-heavy optimizers replay the
    /// same neighborhoods across many descents and repeats; paying the
    /// one-time Σ|N(v)| probe cost turns every later `neighbors` call
    /// into a borrowed slice — zero probes, zero allocation.
    csr: [OnceLock<CsrGraph>; 2],
}

impl SearchSpace {
    /// Largest space for which the lazy CSR neighbor-graph build behind
    /// [`SearchSpace::neighbors`] is presumed to amortize (≈30 MiB of
    /// targets at typical degrees). Callers that might touch bigger
    /// spaces only a handful of times should consult
    /// [`SearchSpace::csr_worthwhile`] and fall back to
    /// [`SearchSpace::neighbors_into`].
    pub const CSR_AMORTIZE_MAX_CONFIGS: usize = 1 << 18;

    /// True when this space is small enough that the one-time CSR build
    /// amortizes over replayed neighborhoods (the local-search engine's
    /// criterion for choosing the slice path over per-pass probing).
    pub fn csr_worthwhile(&self) -> bool {
        self.len() <= Self::CSR_AMORTIZE_MAX_CONFIGS
    }

    /// Enumerate the valid configurations of `params` under `constraints`
    /// with default options (auto index, auto flat policy).
    pub fn build(
        name: &str,
        params: Vec<TunableParam>,
        constraints: Vec<Constraint>,
    ) -> Result<SearchSpace> {
        Self::build_with(name, params, constraints, BuildOptions::default())
    }

    /// Enumerate with explicit index/flat choices (tests and benchmarks;
    /// [`SearchSpace::build`] is the everyday entry point).
    pub fn build_with(
        name: &str,
        params: Vec<TunableParam>,
        constraints: Vec<Constraint>,
        opts: BuildOptions,
    ) -> Result<SearchSpace> {
        let n = params.len();
        if n == 0 {
            bail!("search space {name:?} has no parameters");
        }
        if n > u16::MAX as usize {
            bail!("too many parameters");
        }
        let dims: Vec<usize> = params.iter().map(|p| p.cardinality()).collect();
        for (d, &card) in dims.iter().enumerate() {
            if card > (1 << 16) {
                return Err(TuneError::InvalidInput(format!(
                    "search space {name:?}: parameter {:?} has {card} values, \
                     past the 2^16 u16-encoding limit",
                    params[d].name
                )));
            }
        }
        // Checked product: the packed-rank arithmetic in pack()/strides is
        // u64, so anything past u64::MAX must be a hard typed error, not a
        // silent overflow (and the product itself must not overflow u128).
        let mut cart: u128 = 1;
        for &d in &dims {
            cart = match cart.checked_mul(d as u128) {
                Some(c) => c,
                None => {
                    return Err(TuneError::InvalidInput(format!(
                        "search space {name:?}: Cartesian product exceeds the \
                         2^64 packed-rank limit (overflows u128)"
                    )))
                }
            };
        }
        if cart > u64::MAX as u128 {
            return Err(TuneError::InvalidInput(format!(
                "search space {name:?}: Cartesian product {cart} exceeds the \
                 2^64 packed-rank limit"
            )));
        }
        let kind = match opts.index {
            IndexKind::Auto => {
                if cart <= BITSET_MAX_RANKS {
                    IndexKind::Bitset
                } else {
                    IndexKind::Compressed
                }
            }
            k => k,
        };
        if kind == IndexKind::Bitset && cart > BITSET_MAX_RANKS {
            return Err(TuneError::InvalidInput(format!(
                "search space {name:?}: explicit bitset index over {cart} \
                 Cartesian ranks (> 2^26); use Auto or Compressed"
            )));
        }
        let mut strides = vec![0u64; n];
        let mut acc = 1u64;
        for d in (0..n).rev() {
            strides[d] = acc;
            acc = acc.saturating_mul(dims[d] as u64);
        }

        // Lower every constraint to digit-addressed bytecode (this also
        // rejects references to unknown parameters) and bind each to the
        // earliest odometer depth at which all of its variables are
        // assigned.
        let compiled: Vec<CompiledConstraint> = constraints
            .iter()
            .map(|c| c.compile(&params))
            .collect::<Result<_>>()?;
        let mut by_depth: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (ci, cc) in compiled.iter().enumerate() {
            by_depth[cc.max_dim].push(ci);
        }

        let mut flat: Vec<u16> = Vec::new();
        // Auto flat policy materializes optimistically and drops the
        // buffer the moment it crosses the threshold, bounding both the
        // final footprint and the build's transient peak.
        let mut keep_flat = opts.flat != FlatPolicy::Elide;
        let auto_flat = opts.flat == FlatPolicy::Auto;
        let mut ranks: Vec<u64> = Vec::new();
        let mut stats = BuildStats {
            prefix_rejections: vec![0u64; n],
            pruned_configs: 0,
        };
        let mut scratch = EvalScratch::default();
        let mut cursor: Encoded = vec![0; n];
        let mut depth = 0usize;
        'outer: loop {
            // Check constraints that become fully bound at this depth.
            let cursor_ref = &cursor;
            let assignment_ok = by_depth[depth].iter().all(|&ci| {
                compiled[ci]
                    .eval_encoded(|d| cursor_ref[d], &mut scratch)
                    .unwrap_or(false)
            });

            if assignment_ok {
                if depth + 1 == n {
                    if keep_flat {
                        flat.extend_from_slice(&cursor);
                        if auto_flat && flat.len() > FLAT_MAX_CELLS {
                            flat = Vec::new();
                            keep_flat = false;
                        }
                    }
                    ranks.push(
                        cursor
                            .iter()
                            .zip(&strides)
                            .map(|(&v, &s)| v as u64 * s)
                            .sum(),
                    );
                } else {
                    depth += 1;
                    cursor[depth] = 0;
                    continue 'outer;
                }
            } else {
                stats.prefix_rejections[depth] += 1;
                stats.pruned_configs += strides[depth] as u128;
            }

            // Advance odometer at current depth, backtracking when exhausted.
            loop {
                let next = cursor[depth] as usize + 1;
                if next < dims[depth] {
                    cursor[depth] = next as u16;
                    break;
                }
                if depth == 0 {
                    break 'outer;
                }
                depth -= 1;
            }
        }

        // Lexicographic enumeration ⇒ ranks ascend, so every index
        // variant's select recovers exactly the enumeration index.
        debug_assert!(ranks.windows(2).all(|w| w[0] < w[1]));
        let index = match kind {
            IndexKind::Bitset => {
                let nwords = (cart as usize + 63) / 64;
                let mut words = vec![0u64; nwords.max(1)];
                for &r in &ranks {
                    words[(r >> 6) as usize] |= 1u64 << (r & 63);
                }
                let mut prefix = Vec::with_capacity(words.len());
                let mut seen = 0u32;
                for &w in &words {
                    prefix.push(seen);
                    seen += w.count_ones();
                }
                RankIndex::Bitset { words, prefix }
            }
            IndexKind::Map => {
                RankIndex::Map(ranks.iter().enumerate().map(|(i, &r)| (r, i)).collect())
            }
            IndexKind::Compressed => build_compressed(&ranks, cart),
            IndexKind::Auto => unreachable!("Auto resolved above"),
        };
        Ok(SearchSpace {
            name: name.to_string(),
            params,
            constraints,
            flat: keep_flat.then_some(flat),
            ranks,
            index,
            stats,
            dims,
            strides,
            csr: [OnceLock::new(), OnceLock::new()],
        })
    }

    /// Number of valid configurations.
    pub fn len(&self) -> usize {
        self.ranks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Size of the unconstrained Cartesian product.
    pub fn cartesian_size(&self) -> u128 {
        self.dims.iter().map(|&d| d as u128).product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Which rank-index variant this space was built with (never `Auto`).
    pub fn index_kind(&self) -> IndexKind {
        match self.index {
            RankIndex::Bitset { .. } => IndexKind::Bitset,
            RankIndex::Map(_) => IndexKind::Map,
            RankIndex::Compressed { .. } => IndexKind::Compressed,
        }
    }

    /// True when the row-major `flat` decode buffer is materialized.
    /// When false, use [`SearchSpace::digit`] / [`SearchSpace::encoded_into`]
    /// instead of [`SearchSpace::encoded`].
    pub fn has_flat(&self) -> bool {
        self.flat.is_some()
    }

    /// Prefix-pruning counters recorded while enumerating this space.
    pub fn build_stats(&self) -> &BuildStats {
        &self.stats
    }

    /// Encoded configuration at a valid index (slice into the SoA buffer).
    ///
    /// Panics when the flat buffer was elided ([`FlatPolicy`]); elide-safe
    /// callers use [`SearchSpace::digit`] or [`SearchSpace::encoded_into`].
    pub fn encoded(&self, idx: usize) -> &[u16] {
        let n = self.dims.len();
        match &self.flat {
            Some(f) => &f[idx * n..(idx + 1) * n],
            // lint: allow(W03, reason = "documented panic: flat buffer was elided")
            None => panic!(
                "encoded() on search space {:?} whose flat buffer is elided; \
                 use digit()/encoded_into()",
                self.name
            ),
        }
    }

    /// Value index of dimension `d` in configuration `idx`: one flat read
    /// when materialized, one divide + modulo off the packed rank when
    /// elided. The elide-safe scalar decode primitive.
    #[inline]
    pub fn digit(&self, idx: usize, d: usize) -> u16 {
        match &self.flat {
            Some(f) => f[idx * self.dims.len() + d],
            None => ((self.ranks[idx] / self.strides[d]) % self.dims[d] as u64) as u16,
        }
    }

    /// Decode a configuration into a caller-owned buffer (cleared first).
    /// Works with or without the flat buffer.
    pub fn encoded_into(&self, idx: usize, out: &mut Encoded) {
        out.clear();
        match &self.flat {
            Some(f) => {
                let n = self.dims.len();
                out.extend_from_slice(&f[idx * n..(idx + 1) * n]);
            }
            None => {
                let rank = self.ranks[idx];
                out.extend(
                    self.strides
                        .iter()
                        .zip(&self.dims)
                        .map(|(&s, &d)| ((rank / s) % d as u64) as u16),
                );
            }
        }
    }

    /// Owned decode of a configuration (elide-safe `encoded().to_vec()`).
    pub fn encoded_vec(&self, idx: usize) -> Encoded {
        let mut out = Encoded::with_capacity(self.dims.len());
        self.encoded_into(idx, &mut out);
        out
    }

    /// Packed Cartesian rank of a valid index.
    #[inline]
    pub fn rank_of(&self, idx: usize) -> u64 {
        self.ranks[idx]
    }

    /// Pack an encoded configuration into its Cartesian rank; `None` if any
    /// dimension is out of range (an out-of-range value must not alias
    /// another configuration's rank).
    #[inline]
    pub fn pack(&self, enc: &[u16]) -> Option<u64> {
        if enc.len() != self.dims.len() {
            return None;
        }
        let mut rank = 0u64;
        for (d, &v) in enc.iter().enumerate() {
            if (v as usize) >= self.dims[d] {
                return None;
            }
            rank += v as u64 * self.strides[d];
        }
        Some(rank)
    }

    /// Valid-config index of a packed Cartesian rank (None if invalid).
    /// Two array reads + a popcount on the bitset path; no allocation.
    #[inline]
    pub fn index_of_rank(&self, rank: u64) -> Option<usize> {
        match &self.index {
            RankIndex::Bitset { words, prefix } => {
                let w = (rank >> 6) as usize;
                let bit = 1u64 << (rank & 63);
                let word = *words.get(w)?;
                if word & bit == 0 {
                    None
                } else {
                    Some(prefix[w] as usize + (word & (bit - 1)).count_ones() as usize)
                }
            }
            RankIndex::Map(m) => m.get(&rank).copied(),
            RankIndex::Compressed { starts, shift } => {
                let b = (rank >> shift) as usize;
                let lo = *starts.get(b)? as usize;
                let hi = *starts.get(b + 1)? as usize;
                match self.ranks[lo..hi].binary_search(&rank) {
                    Ok(pos) => Some(lo + pos),
                    Err(_) => None,
                }
            }
        }
    }

    /// Index of an encoded configuration (None if invalid).
    #[inline]
    pub fn index_of(&self, enc: &[u16]) -> Option<usize> {
        self.index_of_rank(self.pack(enc)?)
    }

    /// Index of the configuration equal to `idx` with dimension `d` set to
    /// `v` — a single stride-delta on the packed rank, no probe buffer.
    #[inline]
    pub fn with_dim(&self, idx: usize, d: usize, v: u16) -> Option<usize> {
        if (v as usize) >= self.dims[d] {
            return None;
        }
        let orig = self.digit(idx, d) as u64;
        let rank = self.ranks[idx] - orig * self.strides[d] + v as u64 * self.strides[d];
        self.index_of_rank(rank)
    }

    /// Decode to parameter values.
    pub fn values(&self, idx: usize) -> Vec<Value> {
        self.params
            .iter()
            .enumerate()
            .map(|(d, p)| p.values[self.digit(idx, d) as usize].clone())
            .collect()
    }

    /// name=value map for a configuration (for JSON output).
    pub fn named_values(&self, idx: usize) -> Vec<(String, Value)> {
        self.params
            .iter()
            .enumerate()
            .map(|(d, p)| {
                (
                    p.name.clone(),
                    p.values[self.digit(idx, d) as usize].clone(),
                )
            })
            .collect()
    }

    /// Stable key string like `64,8,uniform` for hashing/serialization.
    pub fn key(&self, idx: usize) -> String {
        self.values(idx)
            .iter()
            .map(|v| v.key())
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Stable fingerprint of this space's structure (parameter names and
    /// exact value grids, plus the enumerated size). Persisted with
    /// campaign results as provenance: a later schema/grid change
    /// invalidates stale caches instead of silently misdecoding their
    /// config indices against a different grid.
    pub fn fingerprint(&self) -> String {
        // FNV-1a over the parameter names and rendered value keys.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |s: &str| {
            for &b in s.as_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0100_0000_01b3);
            }
            h ^= 0x1f;
            h = h.wrapping_mul(0x0100_0000_01b3);
        };
        for p in &self.params {
            eat(&p.name);
            for v in &p.values {
                eat(&v.key());
            }
        }
        format!("{h:016x}-{}", self.len())
    }

    /// Uniform random valid configuration.
    pub fn random(&self, rng: &mut Rng) -> usize {
        rng.below(self.len())
    }

    /// Distinct random sample of k valid configurations.
    pub fn sample(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        rng.sample_indices(self.len(), k.min(self.len()))
    }

    /// Visit the neighbor indices of a configuration under a neighborhood,
    /// in dimension-major order, without allocating. Each candidate is one
    /// stride-delta on the packed rank plus an `index_of_rank` check.
    ///
    /// Results are valid configurations only. For `Adjacent`, if neither
    /// ±1 of a dimension yields a valid config, that dimension contributes
    /// nothing (matching Kernel Tuner's 'strictly-adjacent' behavior).
    pub fn for_each_neighbor(
        &self,
        idx: usize,
        hood: Neighborhood,
        mut visit: impl FnMut(usize),
    ) {
        let base = self.ranks[idx];
        for d in 0..self.dims.len() {
            let orig = self.digit(idx, d) as u64;
            let stride = self.strides[d];
            // Rank with dimension d zeroed; candidates are floor + v*stride.
            let floor = base - orig * stride;
            match hood {
                Neighborhood::Hamming => {
                    for v in 0..self.dims[d] as u64 {
                        if v == orig {
                            continue;
                        }
                        if let Some(i) = self.index_of_rank(floor + v * stride) {
                            visit(i);
                        }
                    }
                }
                Neighborhood::Adjacent => {
                    if orig > 0 {
                        if let Some(i) = self.index_of_rank(floor + (orig - 1) * stride) {
                            visit(i);
                        }
                    }
                    if orig + 1 < self.dims[d] as u64 {
                        if let Some(i) = self.index_of_rank(floor + (orig + 1) * stride) {
                            visit(i);
                        }
                    }
                }
            }
        }
    }

    /// Neighbor indices collected into a caller-owned buffer (cleared
    /// first), so tight local-search loops can reuse one allocation.
    /// Probes the packed-rank index directly — does *not* build the CSR
    /// graph (use [`SearchSpace::neighbors`] for replayed neighborhoods).
    pub fn neighbors_into(&self, idx: usize, hood: Neighborhood, out: &mut Vec<usize>) {
        out.clear();
        self.for_each_neighbor(idx, hood, |i| out.push(i));
    }

    /// The CSR graph for a neighborhood, built on first use from the
    /// probing visitor (so slice order equals `for_each_neighbor` order).
    fn csr(&self, hood: Neighborhood) -> &CsrGraph {
        self.csr[hood.slot()].get_or_init(|| {
            assert!(
                self.len() <= u32::MAX as usize,
                "search space {:?} too large for a CSR neighbor graph",
                self.name
            );
            let mut offsets = Vec::with_capacity(self.len() + 1);
            let mut targets: Vec<u32> = Vec::new();
            offsets.push(0);
            for idx in 0..self.len() {
                self.for_each_neighbor(idx, hood, |i| targets.push(i as u32));
                offsets.push(targets.len());
            }
            CsrGraph { offsets, targets }
        })
    }

    /// Neighbor indices of a configuration as a borrowed slice into the
    /// precomputed CSR graph for this `(space, neighborhood)` — zero
    /// probes and zero allocation per call after the lazy one-time build.
    /// Order matches [`SearchSpace::for_each_neighbor`] exactly.
    ///
    /// The first call pays the whole-space build: O(Σ|N(v)|) probes and
    /// ~4·Σ|N(v)| bytes, worthwhile only when neighborhoods are replayed.
    /// Callers that may touch very large spaces a handful of times should
    /// prefer [`SearchSpace::neighbors_into`] (as the local-search engine
    /// does past its size threshold).
    pub fn neighbors(&self, idx: usize, hood: Neighborhood) -> &[u32] {
        let csr = self.csr(hood);
        &csr.targets[csr.offsets[idx]..csr.offsets[idx + 1]]
    }

    /// A random valid neighbor, falling back to a random config if the
    /// neighborhood is empty (keeps stochastic optimizers moving).
    ///
    /// Hot path for annealing-type walks: O(1) rejection sampling (pick a
    /// dimension, pick a different value, check validity via one packed
    /// stride-delta) with a bounded number of tries before reservoir
    /// sampling the enumerated neighborhood — no allocation either way.
    /// Not perfectly uniform over the neighborhood, but each valid
    /// neighbor has positive probability — the property the walks need.
    pub fn random_neighbor(&self, idx: usize, hood: Neighborhood, rng: &mut Rng) -> usize {
        let ndim = self.dims.len();
        for _ in 0..16 {
            let d = rng.below(ndim);
            if self.dims[d] < 2 {
                continue;
            }
            let orig = self.digit(idx, d);
            let cand = match hood {
                Neighborhood::Hamming => {
                    let mut v = rng.below(self.dims[d]) as u16;
                    if v == orig {
                        v = (v + 1) % self.dims[d] as u16;
                    }
                    v
                }
                Neighborhood::Adjacent => {
                    let up = rng.chance(0.5);
                    if up && (orig as usize) + 1 < self.dims[d] {
                        orig + 1
                    } else if !up && orig > 0 {
                        orig - 1
                    } else {
                        continue;
                    }
                }
            };
            if let Some(i) = self.with_dim(idx, d, cand) {
                return i;
            }
        }
        // Rare: dense constraints around this point; reservoir-sample the
        // full neighborhood without materializing it.
        let mut chosen = None;
        let mut count = 0usize;
        self.for_each_neighbor(idx, hood, |i| {
            count += 1;
            if rng.below(count) == 0 {
                chosen = Some(i);
            }
        });
        chosen.unwrap_or_else(|| self.random(rng))
    }

    /// Nearest-ish valid configuration to an arbitrary encoded point
    /// (used by continuous optimizers like PSO that propose off-lattice
    /// points).
    ///
    /// Hot path (PSO snaps every particle move): round to the lattice —
    /// packing the rank on the fly, no scratch buffer — and accept if
    /// valid; otherwise pick the closest of 64 random valid candidates by
    /// L1 distance over the SoA buffer (exact nearest would be
    /// O(|space|)). A jittered local repair with rank probes was tried and
    /// measured 2x slower: constraint patterns like divisibility are
    /// rarely fixed by ±1 jitter.
    ///
    /// Panics on an empty search space (there is nothing valid to return).
    pub fn snap(&self, target: &[f64], rng: &mut Rng) -> usize {
        assert!(
            !self.is_empty(),
            "snap() on empty search space {:?}",
            self.name
        );
        // Round to the lattice first; if valid, done.
        if target.len() == self.dims.len() {
            let mut rank = 0u64;
            for (d, &t) in target.iter().enumerate() {
                // NaN clamps to NaN and casts to 0 — same rounding the
                // old Vec-based path applied.
                let v = t.round().clamp(0.0, (self.dims[d] - 1) as f64) as u64;
                rank += v * self.strides[d];
            }
            if let Some(i) = self.index_of_rank(rank) {
                return i;
            }
        }
        // Distance-biased random-candidate search over decoded rows.
        let mut best = usize::MAX;
        let mut best_dist = f64::INFINITY;
        let n = self.len();
        for _ in 0..64.min(n) {
            let cand = rng.below(n);
            let dist = self.cand_dist_f64(cand, target);
            if dist < best_dist {
                best_dist = dist;
                best = cand;
            }
        }
        if best == usize::MAX {
            // Every candidate distance was NaN (NaN target): any valid
            // config beats returning an out-of-range sentinel.
            return self.random(rng);
        }
        best
    }

    /// Snap an encoded (possibly invalid) lattice point to a valid config:
    /// the exact index when valid, else the closest of 64 random valid
    /// candidates by integer L1 distance. Allocation-free variant of
    /// [`SearchSpace::snap`] for integer proposals (GA children).
    ///
    /// Panics on an empty search space.
    pub fn snap_encoded(&self, enc: &[u16], rng: &mut Rng) -> usize {
        assert!(
            !self.is_empty(),
            "snap_encoded() on empty search space {:?}",
            self.name
        );
        if let Some(i) = self.index_of(enc) {
            return i;
        }
        let mut best = usize::MAX;
        let mut best_dist = u64::MAX;
        let n = self.len();
        for _ in 0..64.min(n) {
            let cand = rng.below(n);
            let dist = self.cand_dist_u16(cand, enc);
            if dist < best_dist {
                best_dist = dist;
                best = cand;
            }
        }
        debug_assert_ne!(best, usize::MAX);
        best
    }

    /// L1 distance of config `cand` to a float target: flat-row scan when
    /// materialized, stride decode off the packed rank when elided (same
    /// digits either way, so snap picks identical candidates).
    fn cand_dist_f64(&self, cand: usize, target: &[f64]) -> f64 {
        let ndim = self.dims.len();
        match &self.flat {
            Some(f) => f[cand * ndim..(cand + 1) * ndim]
                .iter()
                .zip(target)
                .map(|(&v, &t)| (v as f64 - t).abs())
                .sum(),
            None => {
                let rank = self.ranks[cand];
                self.strides
                    .iter()
                    .zip(&self.dims)
                    .zip(target)
                    .map(|((&s, &d), &t)| (((rank / s) % d as u64) as f64 - t).abs())
                    .sum()
            }
        }
    }

    /// Integer L1 distance of config `cand` to an encoded target.
    fn cand_dist_u16(&self, cand: usize, enc: &[u16]) -> u64 {
        let ndim = self.dims.len();
        match &self.flat {
            Some(f) => f[cand * ndim..(cand + 1) * ndim]
                .iter()
                .zip(enc)
                .map(|(&v, &t)| (v as i64 - t as i64).unsigned_abs())
                .sum(),
            None => {
                let rank = self.ranks[cand];
                self.strides
                    .iter()
                    .zip(&self.dims)
                    .zip(enc)
                    .map(|((&s, &d), &t)| {
                        (((rank / s) % d as u64) as i64 - t as i64).unsigned_abs()
                    })
                    .sum()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space_2d() -> SearchSpace {
        SearchSpace::build(
            "t",
            vec![
                TunableParam::new("a", vec![1i64, 2, 4, 8]),
                TunableParam::new("b", vec![1i64, 2, 4]),
            ],
            vec![Constraint::parse("a * b <= 8").unwrap()],
        )
        .unwrap()
    }

    #[test]
    fn enumeration_respects_constraints() {
        let s = space_2d();
        // valid pairs: (1,1)(1,2)(1,4)(2,1)(2,2)(2,4)(4,1)(4,2)(8,1) = 9
        assert_eq!(s.len(), 9);
        assert_eq!(s.cartesian_size(), 12);
        for i in 0..s.len() {
            let v = s.values(i);
            let a = v[0].as_i64().unwrap();
            let b = v[1].as_i64().unwrap();
            assert!(a * b <= 8);
        }
    }

    #[test]
    fn index_roundtrip() {
        let s = space_2d();
        for i in 0..s.len() {
            assert_eq!(s.index_of(s.encoded(i)), Some(i));
            assert_eq!(s.index_of_rank(s.rank_of(i)), Some(i));
        }
        assert_eq!(s.index_of(&[3u16, 2]), None); // (8,4) invalid
    }

    #[test]
    fn pack_rejects_out_of_range() {
        let s = space_2d();
        // Out-of-range dimension values must not alias another config.
        assert_eq!(s.pack(&[0u16, 3]), None);
        assert_eq!(s.index_of(&[0u16, 3]), None);
        assert_eq!(s.index_of(&[4u16, 0]), None);
        // Wrong arity misses rather than panicking.
        assert_eq!(s.index_of(&[0u16]), None);
        assert_eq!(s.index_of(&[0u16, 0, 0]), None);
    }

    #[test]
    fn with_dim_matches_index_of() {
        let s = space_2d();
        for i in 0..s.len() {
            for d in 0..s.dims().len() {
                for v in 0..s.dims()[d] as u16 {
                    let mut e = s.encoded(i).to_vec();
                    e[d] = v;
                    assert_eq!(s.with_dim(i, d, v), s.index_of(&e), "idx {i} d {d} v {v}");
                }
                assert_eq!(s.with_dim(i, d, s.dims()[d] as u16), None);
            }
        }
    }

    #[test]
    fn prefix_pruning_equals_naive() {
        // Multi-constraint space: compare against naive filtering.
        let params = vec![
            TunableParam::new("x", vec![0i64, 1, 2, 3, 4, 5]),
            TunableParam::new("y", vec![0i64, 1, 2, 3, 4, 5]),
            TunableParam::new("z", vec![0i64, 1, 2]),
        ];
        let cs = vec![
            Constraint::parse("x % 2 == 0").unwrap(),
            Constraint::parse("x + y <= 6").unwrap(),
            Constraint::parse("z < 2 || y == 0").unwrap(),
        ];
        let s = SearchSpace::build("t", params.clone(), cs.clone()).unwrap();
        let mut naive = 0;
        for x in 0..6i64 {
            for y in 0..6i64 {
                for z in 0..3i64 {
                    if x % 2 == 0 && x + y <= 6 && (z < 2 || y == 0) {
                        naive += 1;
                    }
                }
            }
        }
        assert_eq!(s.len(), naive);
    }

    #[test]
    fn neighbors_hamming_and_adjacent() {
        let s = space_2d();
        let idx = s.index_of(&[0u16, 0]).unwrap(); // (1,1)
        let h = s.neighbors(idx, Neighborhood::Hamming);
        // change a: (2,1)(4,1)(8,1); change b: (1,2)(1,4) => 5
        assert_eq!(h.len(), 5);
        let adj = s.neighbors(idx, Neighborhood::Adjacent);
        // a->2 (valid), b->2 (valid) => 2
        assert_eq!(adj.len(), 2);
        // All neighbors valid and distinct from self.
        for &n in h.iter().chain(adj.iter()) {
            assert_ne!(n as usize, idx);
            assert!((n as usize) < s.len());
        }
        // Buffer reuse (probing) path agrees with the CSR slice path.
        let mut buf = vec![999usize; 3];
        s.neighbors_into(idx, Neighborhood::Hamming, &mut buf);
        let h_usize: Vec<usize> = h.iter().map(|&n| n as usize).collect();
        assert_eq!(buf, h_usize);
    }

    #[test]
    fn csr_slices_match_visitor_on_every_config() {
        let s = space_2d();
        let mut visited = Vec::new();
        for hood in [Neighborhood::Hamming, Neighborhood::Adjacent] {
            for i in 0..s.len() {
                visited.clear();
                s.for_each_neighbor(i, hood, |n| visited.push(n));
                let slice: Vec<usize> =
                    s.neighbors(i, hood).iter().map(|&n| n as usize).collect();
                assert_eq!(slice, visited, "config {i} {hood:?}");
            }
        }
    }

    #[test]
    fn sampling_in_range() {
        let s = space_2d();
        let mut rng = Rng::new(1);
        let sample = s.sample(&mut rng, 5);
        assert_eq!(sample.len(), 5);
        assert!(sample.iter().all(|&i| i < s.len()));
        for _ in 0..100 {
            assert!(s.random(&mut rng) < s.len());
        }
    }

    #[test]
    fn snap_valid() {
        let s = space_2d();
        let mut rng = Rng::new(2);
        let i = s.snap(&[2.9, 1.8], &mut rng);
        assert!(i < s.len());
        // (8,4) rounds to invalid; snap must still return a valid config
        let i = s.snap(&[3.0, 2.0], &mut rng);
        assert!(i < s.len());
    }

    #[test]
    fn snap_nan_target_still_valid() {
        // Regression: a NaN component used to poison every candidate
        // distance and leak usize::MAX out of snap().
        let s = space_2d();
        let mut rng = Rng::new(5);
        for target in [
            [f64::NAN, f64::NAN],
            [f64::NAN, 1.0],
            [f64::INFINITY, f64::NEG_INFINITY],
        ] {
            let i = s.snap(&target, &mut rng);
            assert!(i < s.len(), "target {target:?} -> {i}");
        }
    }

    #[test]
    #[should_panic(expected = "empty search space")]
    fn snap_on_empty_space_panics() {
        // All configs violate the constraint -> empty (but buildable) space.
        let s = SearchSpace::build(
            "empty",
            vec![TunableParam::new("a", vec![1i64, 2])],
            vec![Constraint::parse("a > 10").unwrap()],
        )
        .unwrap();
        assert!(s.is_empty());
        let mut rng = Rng::new(1);
        s.snap(&[0.0], &mut rng);
    }

    #[test]
    fn snap_encoded_matches_snap_semantics() {
        let s = space_2d();
        let mut rng = Rng::new(9);
        for i in 0..s.len() {
            // Exact valid lattice point -> identity.
            assert_eq!(s.snap_encoded(s.encoded(i), &mut rng), i);
        }
        // Invalid point still lands on a valid config.
        let i = s.snap_encoded(&[3u16, 2], &mut rng);
        assert!(i < s.len());
    }

    #[test]
    fn unknown_constraint_var_rejected() {
        let r = SearchSpace::build(
            "t",
            vec![TunableParam::new("a", vec![1i64])],
            vec![Constraint::parse("nope == 1").unwrap()],
        );
        assert!(r.is_err());
    }

    #[test]
    fn key_stable() {
        let s = space_2d();
        let i = s.index_of(&[1u16, 2]).unwrap();
        assert_eq!(s.key(i), "2,4");
    }

    fn space_2d_with(opts: BuildOptions) -> SearchSpace {
        SearchSpace::build_with(
            "t",
            vec![
                TunableParam::new("a", vec![1i64, 2, 4, 8]),
                TunableParam::new("b", vec![1i64, 2, 4]),
            ],
            vec![Constraint::parse("a * b <= 8").unwrap()],
            opts,
        )
        .unwrap()
    }

    #[test]
    fn build_rejects_past_u64_product() {
        // 8 params × 256 values = 2^64, one past the u64 rank range:
        // must be a typed InvalidInput, not silent rank wraparound.
        let params: Vec<TunableParam> = (0..8)
            .map(|i| TunableParam::int_range(&format!("p{i}"), 0, 255, 1))
            .collect();
        let err = SearchSpace::build("huge", params, vec![]).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err:?}");

        // 16 params × 256 values = 2^128: the product overflows u128
        // itself; the checked fold must catch it rather than panic/wrap.
        let params: Vec<TunableParam> = (0..16)
            .map(|i| TunableParam::int_range(&format!("p{i}"), 0, 255, 1))
            .collect();
        let err = SearchSpace::build("huger", params, vec![]).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err:?}");
    }

    #[test]
    fn build_rejects_cardinality_past_u16() {
        // 2^16 + 1 values cannot be encoded in a u16 digit.
        let p = TunableParam::int_range("a", 0, 1 << 16, 1);
        let err = SearchSpace::build("wide", vec![p], vec![]).unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err:?}");
    }

    #[test]
    fn index_variants_and_flat_policies_agree() {
        let base = space_2d();
        assert_eq!(base.index_kind(), IndexKind::Bitset);
        assert!(base.has_flat());
        for index in [IndexKind::Bitset, IndexKind::Map, IndexKind::Compressed] {
            for flat in [FlatPolicy::Materialize, FlatPolicy::Elide] {
                let s = space_2d_with(BuildOptions { index, flat });
                assert_eq!(s.index_kind(), index);
                assert_eq!(s.has_flat(), flat == FlatPolicy::Materialize);
                assert_eq!(s.len(), base.len());
                for i in 0..base.len() {
                    assert_eq!(s.rank_of(i), base.rank_of(i));
                    assert_eq!(s.index_of_rank(s.rank_of(i)), Some(i));
                    assert_eq!(s.encoded_vec(i), base.encoded(i).to_vec());
                    assert_eq!(s.values(i), base.values(i));
                    for d in 0..base.dims().len() {
                        assert_eq!(s.digit(i, d), base.encoded(i)[d]);
                        for v in 0..=base.dims()[d] as u16 {
                            assert_eq!(
                                s.with_dim(i, d, v),
                                base.with_dim(i, d, v),
                                "{index:?}/{flat:?} idx {i} d {d} v {v}"
                            );
                        }
                    }
                    // Same-seed stochastic paths are bitwise-identical.
                    let (mut r1, mut r2) = (Rng::new(42), Rng::new(42));
                    assert_eq!(
                        s.random_neighbor(i, Neighborhood::Hamming, &mut r1),
                        base.random_neighbor(i, Neighborhood::Hamming, &mut r2)
                    );
                    let (mut r1, mut r2) = (Rng::new(7), Rng::new(7));
                    assert_eq!(s.snap(&[2.7, 0.2], &mut r1), base.snap(&[2.7, 0.2], &mut r2));
                }
                // Invalid / out-of-range probes agree too.
                assert_eq!(s.index_of(&[3u16, 2]), None);
                assert_eq!(s.index_of(&[9u16, 0]), None);
            }
        }
    }

    #[test]
    #[should_panic(expected = "flat buffer is elided")]
    fn encoded_panics_when_flat_elided() {
        let s = space_2d_with(BuildOptions {
            index: IndexKind::Auto,
            flat: FlatPolicy::Elide,
        });
        let _ = s.encoded(0);
    }

    #[test]
    fn compressed_index_past_bitset_ceiling() {
        // 65536 × 65536 × 16 = 2^36 Cartesian ranks — far past the old
        // 2^26 bitset ceiling — kept enumerable by hard prefix pruning.
        let params = vec![
            TunableParam::int_range("a", 0, 65535, 1),
            TunableParam::int_range("b", 0, 65535, 1),
            TunableParam::int_range("c", 0, 15, 1),
        ];
        let cs = vec![
            Constraint::parse("a % 4096 == 0").unwrap(),
            Constraint::parse("b % 4096 == 0").unwrap(),
        ];
        let s = SearchSpace::build("big", params.clone(), cs.clone()).unwrap();
        assert_eq!(s.index_kind(), IndexKind::Compressed);
        assert_eq!(s.cartesian_size(), 1u128 << 36);
        assert_eq!(s.len(), 16 * 16 * 16);
        for i in (0..s.len()).step_by(97) {
            assert_eq!(s.index_of_rank(s.rank_of(i)), Some(i));
            assert_eq!(s.index_of(&s.encoded_vec(i)), Some(i));
            for d in 0..3 {
                let v = s.digit(i, d);
                assert_eq!(s.with_dim(i, d, v), Some(i));
            }
        }
        // Pruning ruled out nearly the whole Cartesian product.
        let stats = s.build_stats();
        assert_eq!(stats.prefix_rejections[0], 65536 - 16);
        assert!(stats.pruned_configs > 1u128 << 35);
        // An explicit bitset at this size must be a typed error, not an
        // 8 GiB allocation.
        let err = SearchSpace::build_with(
            "big",
            params,
            cs,
            BuildOptions {
                index: IndexKind::Bitset,
                flat: FlatPolicy::Auto,
            },
        )
        .unwrap_err();
        assert!(matches!(err, TuneError::InvalidInput(_)), "{err:?}");
    }

    #[test]
    fn compressed_on_empty_and_single_spaces() {
        let s = SearchSpace::build_with(
            "empty",
            vec![TunableParam::new("a", vec![1i64, 2])],
            vec![Constraint::parse("a > 10").unwrap()],
            BuildOptions {
                index: IndexKind::Compressed,
                flat: FlatPolicy::Auto,
            },
        )
        .unwrap();
        assert_eq!(s.len(), 0);
        assert_eq!(s.index_of(&[0u16]), None);
        let s = SearchSpace::build_with(
            "one",
            vec![TunableParam::new("a", vec![5i64])],
            vec![],
            BuildOptions {
                index: IndexKind::Compressed,
                flat: FlatPolicy::Elide,
            },
        )
        .unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.index_of(&[0u16]), Some(0));
        assert_eq!(s.encoded_vec(0), vec![0u16]);
        assert_eq!(s.key(0), "5");
    }
}
