//! Deterministic synthetic constrained-space generator.
//!
//! Real auto-tuning spaces are heavily constrained and far larger than the
//! seed kernels; `spacegen` manufactures such spaces on demand so builds,
//! CSR graphs, SimTables and whole tuning campaigns can be exercised at
//! million-to-billion-Cartesian-rank scale with a *tunable validity
//! fraction*. Everything is a pure function of the [`SpaceGenSpec`]
//! (dims × validity × family × seed): the same spec always produces the
//! same parameters, constraint strings and therefore the same enumerated
//! space, so benchmarks and tests are reproducible across machines.
//!
//! Two constraint shapes (and their combination) cover the interesting
//! regimes:
//!
//! * [`ConstraintFamily::Hash`] — one multiplicative-hash residue test
//!   over *all* dimensions, `(Σ p_d·c_d) % M < K` with prime `M`. Binds
//!   only at leaf depth (no prefix pruning): the worst case, measuring raw
//!   enumeration + compiled-eval bandwidth, with achieved validity ≈ K/M.
//! * [`ConstraintFamily::Product`] — adjacent-pair bounds
//!   `p_j * p_{j+1} <= B_j`, each binding as soon as its second dimension
//!   is assigned: the best case for prefix pruning, with every `B_j`
//!   chosen by exact quantile so the per-pair validities multiply out to
//!   the requested fraction.
//! * [`ConstraintFamily::Mixed`] — both at √validity each.

use super::constraint::Constraint;
use super::param::TunableParam;
use super::space::{BuildOptions, SearchSpace};
use crate::bail;
use crate::error::{Context, Result};
use crate::util::rng::{mix64, Rng};

/// Prime modulus of the hash-family residue constraint.
const HASH_MODULUS: i64 = 1_048_573;

/// Constraint shape of a generated space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConstraintFamily {
    /// One leaf-bound residue test over all dimensions (no pruning).
    Hash,
    /// Adjacent-pair product bounds (prefix pruning at every depth).
    Product,
    /// Hash and product at √validity each.
    Mixed,
}

impl ConstraintFamily {
    pub fn parse(s: &str) -> Result<ConstraintFamily> {
        Ok(match s {
            "hash" => ConstraintFamily::Hash,
            "product" => ConstraintFamily::Product,
            "mixed" => ConstraintFamily::Mixed,
            other => bail!("unknown constraint family {other:?} (hash|product|mixed)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ConstraintFamily::Hash => "hash",
            ConstraintFamily::Product => "product",
            ConstraintFamily::Mixed => "mixed",
        }
    }
}

/// Full specification of a synthetic constrained space.
#[derive(Clone, Debug)]
pub struct SpaceGenSpec {
    /// Per-dimension cardinalities (Cartesian size = their product).
    pub dims: Vec<usize>,
    /// Target fraction of the Cartesian product that is valid, in (0, 1].
    pub validity: f64,
    pub family: ConstraintFamily,
    pub seed: u64,
}

impl SpaceGenSpec {
    pub fn new(
        dims: Vec<usize>,
        validity: f64,
        family: ConstraintFamily,
        seed: u64,
    ) -> SpaceGenSpec {
        SpaceGenSpec {
            dims,
            validity,
            family,
            seed,
        }
    }

    /// Parse an `AxBxC`-style dims string, e.g. `32x32x16x8`.
    pub fn parse_dims(s: &str) -> Result<Vec<usize>> {
        let dims: Vec<usize> = s
            .split('x')
            .map(|part| {
                part.parse::<usize>()
                    .with_context(|| format!("bad dimension {part:?} in dims {s:?}"))
            })
            .collect::<Result<_>>()?;
        if dims.is_empty() || dims.iter().any(|&d| d == 0) {
            bail!("dims {s:?} must be nonempty positive integers");
        }
        Ok(dims)
    }

    /// Stable space name, e.g. `gen-hash-32x32x16-s7`.
    pub fn name(&self) -> String {
        let dims = self
            .dims
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join("x");
        format!("gen-{}-{}-s{}", self.family.name(), dims, self.seed)
    }

    /// The generated parameters: `p{d}` over `1..=dims[d]` (values start
    /// at 1 so product constraints are meaningful; the encoded digit of a
    /// value `v` is `v - 1`).
    pub fn params(&self) -> Vec<TunableParam> {
        self.dims
            .iter()
            .enumerate()
            .map(|(d, &card)| TunableParam::int_range(&format!("p{d}"), 1, card as i64, 1))
            .collect()
    }

    /// The generated constraint set for the requested family/validity.
    pub fn constraints(&self) -> Result<Vec<Constraint>> {
        let v = self.validity;
        if !(v > 0.0 && v <= 1.0) {
            bail!("validity {v} out of (0, 1]");
        }
        let mut sources = Vec::new();
        match self.family {
            ConstraintFamily::Hash => self.push_hash(v, &mut sources),
            ConstraintFamily::Product => self.push_product(v, &mut sources)?,
            ConstraintFamily::Mixed => {
                let split = v.sqrt();
                self.push_hash(split, &mut sources);
                self.push_product(split, &mut sources)?;
            }
        }
        sources
            .iter()
            .map(|s| Constraint::parse(s))
            .collect::<Result<_>>()
    }

    /// `(p0*c0 + p1*c1 + ...) % M < K`: pseudo-random odd-ish coefficients
    /// from the seed, `K = round(validity * M)`. Exact i64 arithmetic —
    /// digits ≤ 2^16 and coefficients < 2^20, so no overflow for any
    /// realistic dimension count.
    fn push_hash(&self, validity: f64, out: &mut Vec<String>) {
        let mut rng = Rng::new(mix64(self.seed, 0x7370_6163_6567_656e)); // "spacegen"
        let terms: Vec<String> = self
            .dims
            .iter()
            .enumerate()
            .map(|(d, _)| {
                let c = 1 + (rng.next_u64() % (HASH_MODULUS as u64 - 1)) as i64;
                format!("p{d} * {c}")
            })
            .collect();
        let k = ((validity * HASH_MODULUS as f64).round() as i64).clamp(1, HASH_MODULUS);
        out.push(format!("({}) % {HASH_MODULUS} < {k}", terms.join(" + ")));
    }

    /// Adjacent-pair bounds `p{j} * p{j+1} <= B_j`, each `B_j` the exact
    /// quantile of the pair-product distribution such that the per-pair
    /// validities multiply out to the requested overall fraction.
    fn push_product(&self, validity: f64, out: &mut Vec<String>) -> Result<()> {
        let npairs = self.dims.len().saturating_sub(1);
        if npairs == 0 {
            bail!("product constraint family needs at least 2 dimensions");
        }
        let per_pair = validity.powf(1.0 / npairs as f64);
        for j in 0..npairs {
            let (da, db) = (self.dims[j] as u64, self.dims[j + 1] as u64);
            let target = (per_pair * (da as f64) * (db as f64)).round().max(1.0) as u64;
            let bound = pair_product_quantile(da, db, target);
            out.push(format!("p{j} * p{} <= {bound}", j + 1));
        }
        Ok(())
    }

    /// Enumerate the space with default build options.
    pub fn build(&self) -> Result<SearchSpace> {
        self.build_with(BuildOptions::default())
    }

    /// Enumerate with explicit index/flat choices.
    pub fn build_with(&self, opts: BuildOptions) -> Result<SearchSpace> {
        SearchSpace::build_with(&self.name(), self.params(), self.constraints()?, opts)
    }
}

/// Number of pairs `(a, b) ∈ [1,da]×[1,db]` with `a*b <= bound`.
fn pairs_within(da: u64, db: u64, bound: u64) -> u64 {
    (1..=da).map(|a| db.min(bound / a)).sum()
}

/// Smallest bound whose `pairs_within` count reaches `target`.
fn pair_product_quantile(da: u64, db: u64, target: u64) -> u64 {
    let target = target.min(da * db);
    let (mut lo, mut hi) = (1u64, da * db);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pairs_within(da, db, mid) >= target {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_dims_and_family() {
        assert_eq!(SpaceGenSpec::parse_dims("32x32x16").unwrap(), vec![32, 32, 16]);
        assert!(SpaceGenSpec::parse_dims("32x0x16").is_err());
        assert!(SpaceGenSpec::parse_dims("").is_err());
        assert!(SpaceGenSpec::parse_dims("32xpotato").is_err());
        assert_eq!(ConstraintFamily::parse("hash").unwrap(), ConstraintFamily::Hash);
        assert!(ConstraintFamily::parse("nope").is_err());
    }

    #[test]
    fn deterministic_across_builds() {
        let spec = SpaceGenSpec::new(vec![16, 16, 8], 0.05, ConstraintFamily::Mixed, 7);
        let a = spec.build().unwrap();
        let b = spec.build().unwrap();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert!(!a.is_empty());
    }

    #[test]
    fn hash_family_hits_target_validity() {
        // 32×32×32 = 32768 Cartesian ranks at 5% → expect ~1638 valid.
        let spec = SpaceGenSpec::new(vec![32, 32, 32], 0.05, ConstraintFamily::Hash, 3);
        let s = spec.build().unwrap();
        let achieved = s.len() as f64 / 32768.0;
        assert!(
            (0.025..=0.10).contains(&achieved),
            "achieved validity {achieved} far from 0.05 (len {})",
            s.len()
        );
        // Leaf-bound: no prefix pruning above the last dimension.
        assert_eq!(s.build_stats().prefix_rejections[0], 0);
        assert_eq!(s.build_stats().prefix_rejections[1], 0);
    }

    #[test]
    fn product_family_prunes_prefixes() {
        let spec = SpaceGenSpec::new(vec![64, 64, 64], 0.01, ConstraintFamily::Product, 11);
        let s = spec.build().unwrap();
        let cart = 64.0 * 64.0 * 64.0;
        let achieved = s.len() as f64 / cart;
        // Pair constraints share dimensions, so validities don't multiply
        // exactly — a loose band is the contract here.
        assert!(
            (0.002..=0.08).contains(&achieved),
            "achieved validity {achieved} far from 0.01 (len {})",
            s.len()
        );
        // Pair constraints bind at depth 1, so whole subtrees are pruned.
        let stats = s.build_stats();
        assert!(stats.prefix_rejections[1] > 0);
        assert!(stats.pruned_configs > 0);
    }

    #[test]
    fn pair_quantile_is_exact() {
        for (da, db, target) in [(8u64, 8, 13), (64, 16, 1), (16, 64, 1024), (5, 7, 35)] {
            let b = pair_product_quantile(da, db, target);
            assert!(pairs_within(da, db, b) >= target.min(da * db));
            if b > 1 {
                assert!(pairs_within(da, db, b - 1) < target.min(da * db));
            }
        }
    }
}
