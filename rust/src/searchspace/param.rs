//! Tunable parameter definitions and values.

use std::fmt;

/// A parameter value: auto-tuning parameters mix integers (tile sizes),
/// floats (hyperparameters like temperatures), strings (method names) and
/// booleans (feature toggles).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
}

impl Value {
    /// Numeric view (bools are 0/1); None for strings.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(x) => Some(*x),
            Value::Bool(b) => Some(*b as i64 as f64),
            Value::Str(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(x) if x.fract() == 0.0 => Some(*x as i64),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Stable key string (used in JSON output and config hashing).
    pub fn key(&self) -> String {
        match self {
            Value::Int(i) => i.to_string(),
            Value::Float(x) => format!("{x}"),
            Value::Str(s) => s.clone(),
            Value::Bool(b) => b.to_string(),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.key())
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Float(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

/// A tunable parameter: a name and its ordered list of allowed values.
#[derive(Clone, Debug)]
pub struct TunableParam {
    pub name: String,
    pub values: Vec<Value>,
}

impl TunableParam {
    pub fn new<V: Into<Value>>(name: &str, values: Vec<V>) -> TunableParam {
        let values: Vec<Value> = values.into_iter().map(Into::into).collect();
        assert!(!values.is_empty(), "parameter {name} has no values");
        TunableParam {
            name: name.to_string(),
            values,
        }
    }

    /// Integer range helper: `lo..=hi` step `step`.
    pub fn int_range(name: &str, lo: i64, hi: i64, step: i64) -> TunableParam {
        assert!(step > 0);
        let values: Vec<Value> = (lo..=hi).step_by(step as usize).map(Value::Int).collect();
        TunableParam::new(name, values)
    }

    pub fn cardinality(&self) -> usize {
        self.values.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(Value::Bool(true).as_f64(), Some(1.0));
        assert_eq!(Value::Str("x".into()).as_f64(), None);
        assert_eq!(Value::Float(4.0).as_i64(), Some(4));
        assert_eq!(Value::Float(4.5).as_i64(), None);
    }

    #[test]
    fn int_range_inclusive() {
        let p = TunableParam::int_range("x", 2, 10, 4);
        assert_eq!(
            p.values,
            vec![Value::Int(2), Value::Int(6), Value::Int(10)]
        );
    }

    #[test]
    #[should_panic(expected = "no values")]
    fn empty_values_panics() {
        TunableParam::new::<i64>("x", vec![]);
    }
}
