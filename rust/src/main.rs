//! tunetuner CLI — the leader entrypoint.
//!
//! ```text
//! tunetuner info
//! tunetuner bruteforce [--kernels k1,k2] [--devices d1,d2]
//! tunetuner tune <kernel> <device> [--algo NAME] [--hp k=v,k=v] [--repeats N]
//! tunetuner hypertune <algo> [--kind limited|extended]
//! tunetuner sweep [--repeats N] [--json]
//! tunetuner metasweep [--strategy S] [--budget N] [--json]
//! tunetuner sensitivity <algo>
//! tunetuner experiment <table2|table3|table4|fig2..fig9|all>
//! tunetuner spacegen <AxBxC> [--validity F] [--family hash|product|mixed]
//! tunetuner bench-trend [--dir D] [--threshold PCT] [--gate]
//! ```
//!
//! Global flags: `--scale quick|paper`, `--seed N`, `--hub DIR`,
//! `--results DIR`, `--artifacts DIR`, `--backend pjrt|native`,
//! `--verbose`, `--quiet`, `--inject-faults SPEC` (deterministic chaos
//! testing, also via `TUNETUNER_FAULTS`; see [`tunetuner::faults`]).

// Same style-lint policy as the library crate (see rust/src/lib.rs).
#![allow(clippy::needless_range_loop, clippy::collapsible_if, clippy::collapsible_else_if)]

use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::sync::Arc;
use tunetuner::analysis;
use tunetuner::campaign::{Campaign, LogObserver, Observer};
use tunetuner::dataset::hub::{Hub, HUB_SEED};
use tunetuner::experiments::{self, Ctx, Scale};
use tunetuner::gpu::specs::all_devices;
use tunetuner::hypertuning;
use tunetuner::kernels;
use tunetuner::optimizers;
use tunetuner::optimizers::HyperParams;
use tunetuner::report::{bench_trend, Report};
use tunetuner::runtime::Engine;
use tunetuner::searchspace::{
    BuildOptions, ConstraintFamily, FlatPolicy, IndexKind, SpaceGenSpec, Value,
};
use tunetuner::util::cli::Args;
use tunetuner::util::log::{self, Level};
use tunetuner::{log_debug, log_info, log_warn};

fn main() {
    log::init_from_env();
    let args = Args::from_env();
    if args.flag("verbose") {
        log::set_level(Level::Debug);
    } else if args.flag("quiet") {
        log::set_level(Level::Warn);
    }
    if let Err(e) = install_faults(&args).and_then(|()| dispatch(&args)) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Install the process-global deterministic fault plan from
/// `--inject-faults SPEC` (or the `TUNETUNER_FAULTS` environment
/// variable) before any subcommand runs — save faults take effect on
/// every artifact write, job faults on every campaign the drivers
/// launch. No spec, no fault plan: the hot path stays untouched.
fn install_faults(args: &Args) -> Result<()> {
    let spec = args
        .opt("inject-faults")
        .map(str::to_string)
        .or_else(|| std::env::var("TUNETUNER_FAULTS").ok());
    if let Some(spec) = spec {
        tunetuner::faults::install(tunetuner::faults::FaultPlan::parse(&spec)?);
        log_warn!("deterministic fault injection active: {spec}");
    }
    Ok(())
}

fn engine(args: &Args) -> Arc<Engine> {
    let artifacts = PathBuf::from(args.opt_or(
        "artifacts",
        Engine::default_artifacts_dir().to_str().unwrap_or("artifacts"),
    ));
    match args.opt_or("backend", "pjrt").as_str() {
        "native" => Arc::new(Engine::native()),
        _ => Arc::new(Engine::auto(&artifacts)),
    }
}

fn ctx(args: &Args) -> Result<Ctx> {
    let scale_name = args.opt_or("scale", "quick");
    let scale = Scale::parse(&scale_name)?;
    let hub = Hub::new(args.opt_or("hub", Hub::default_root().to_str().unwrap_or("hub")));
    let results = PathBuf::from(args.opt_or("results", "results"));
    Ok(Ctx::new(
        hub,
        engine(args),
        results,
        scale,
        &scale_name,
        args.opt_u64("seed", 42),
    )
    .with_faults(tunetuner::faults::global()))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(args),
        Some("bruteforce") => cmd_bruteforce(args),
        Some("tune") => cmd_tune(args),
        Some("hypertune") => cmd_hypertune(args),
        Some("sweep") => cmd_sweep(args),
        Some("metasweep") => cmd_metasweep(args),
        Some("sensitivity") => cmd_sensitivity(args),
        Some("experiment") => cmd_experiment(args),
        Some("spacegen") => cmd_spacegen(args),
        Some("bench-trend") => cmd_bench_trend(args),
        Some("lint") => cmd_lint(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
tunetuner: hyperparameter optimization for auto-tuning (eScience'25 reproduction)

subcommands:
  info                      engine/backends, kernels, devices, space sizes
  bruteforce                build the benchmark hub (all 24 spaces by default)
      [--kernels a,b] [--devices c,d]
  tune <kernel> <device>    run one tuning campaign (simulation mode)
      [--algo pso] [--hp popsize=30,c1=2.0] [--repeats 5] [--budget-cutoff 0.95]
      [--json]  print the campaign-result envelope instead of tables
  hypertune <algo>          tune the tuner (limited: exhaustive; extended: meta)
      [--kind limited|extended] [--json]
  sweep                     hypertune every grid-bearing registry optimizer
      [--repeats N]  override the scale's repeat count (results tagged _rN)
      [--json]  print the tunetuner-sweep envelope instead of the report
  metasweep                 race meta-strategies against the exhaustive sweep
      [--strategy random,tpe,halving,portfolio] [--budget COST] [--eta 4]
      [--min-repeats 1] [--repeats N]
      [--synthetic AxBxC] [--validity 0.05] [--family hash|product|mixed]
      [--gen-seed 7]  hub-free run on a generated space (nothing persisted)
      [--envelope PATH]  (synthetic only) checkpoint/resume envelope: finished
          legs replay bitwise from PATH, which is rewritten after every leg
      [--min-recovery PCT] [--max-cost PCT]  gate: exit 1 when any raced
          strategy recovers less / spends more than the given percentages
      [--json]  print the tunetuner-metasweep envelope instead of the report
  sensitivity <algo>        Kruskal-Wallis + mutual-information screen
  experiment <id>           regenerate a paper table/figure (or 'all')
  spacegen <AxBxC>          build a synthetic constrained space (e.g. 4096x4096x64)
      [--validity 0.01] [--family hash|product|mixed] [--gen-seed 7]
      [--index auto|bitset|map|compressed] [--flat auto|materialize|elide]
      [--campaign ALGO] [--evals 200]  run a simulated campaign on it
  bench-trend               cross-PR perf trajectory from BENCH_<pr>.json files
      [--dir .] [--threshold 25] [--gate]  (--gate: exit 1 on regression)
  lint                      static analysis: the repo's own invariants (W01..W05)
      [--root rust/src] [--deny all|none|W01,W03] [--json] [--out PATH]
      rules: W01 nondeterminism, W02 raw persistence, W03 panic discipline,
      W04 partial_cmp float ordering, W05 foreign/hard-seeded RNG; suppress a
      site with `// lint: allow(RULE, reason = "...")` (justification required)

global flags: --scale quick|paper  --seed N  --hub DIR  --results DIR
              --artifacts DIR  --backend pjrt|native  --verbose  --quiet
              --inject-faults SPEC  deterministic fault injection (chaos
                  testing; also via TUNETUNER_FAULTS): KIND@TARGET list like
                  'panic@pso.j0x*; nan@greedy_ils; truncate-save@s1'
";

fn cmd_info(args: &Args) -> Result<()> {
    let engine = engine(args);
    println!("tunetuner {}", tunetuner::version());
    println!("engine backend: {:?}", engine.backend());
    println!("\ndevices:");
    for d in all_devices() {
        println!(
            "  {:8} {:7} {:4} SM/CU, {:8.0} GFLOP/s, {:6.0} GB/s, warp {}",
            d.name, d.vendor, d.num_sm, d.peak_gflops, d.bandwidth_gbs, d.warp_size
        );
    }
    println!("\nkernels:");
    for k in kernels::all_kernels()? {
        println!(
            "  {:14} {:7} valid configs (of {} cartesian) — {}",
            k.name,
            k.space().len(),
            k.space().cartesian_size(),
            k.problem
        );
    }
    // Rendered straight from the optimizer registry's typed schemas, so
    // this listing can never drift from what `--hp` actually accepts.
    println!("\noptimizers (hyperparameter=default):");
    print!("{}", optimizers::schema_table());
    // Grid sizes come from the same declared schemas the derived search
    // spaces enumerate, so `sweep`/`metasweep` budgets can be sized from
    // this listing without building the spaces.
    println!("\nhypertuning grids (limited / extended configs):");
    for d in optimizers::hypertunable() {
        let extended = match d.extended_grid_size() {
            0 => "-".to_string(),
            n => n.to_string(),
        };
        println!("  {:22} {:>7} / {:>7}", d.name, d.limited_grid_size(), extended);
    }
    Ok(())
}

fn cmd_bruteforce(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let kernels_list = args.opt_or("kernels", "dedispersion,convolution,hotspot,gemm");
    let devices_list = args.opt_or("devices", "A100,A4000,A6000,MI250X,W6600,W7800");
    let ks: Vec<&str> = kernels_list.split(',').collect();
    let ds: Vec<&str> = devices_list.split(',').collect();
    let entries = c.hub.ensure(&ks, &ds, Arc::clone(&c.engine), HUB_SEED)?;
    for (k, d, secs) in entries {
        println!("{k:14} @ {d:8} {:8.1} simulated hours", secs / 3600.0);
    }
    Ok(())
}

fn parse_hp(spec: &str) -> HyperParams {
    let mut hp = HyperParams::new();
    for pair in spec.split(',').filter(|s| !s.is_empty()) {
        if let Some((k, v)) = pair.split_once('=') {
            let value = if let Ok(i) = v.parse::<i64>() {
                Value::Int(i)
            } else if let Ok(f) = v.parse::<f64>() {
                Value::Float(f)
            } else {
                Value::Str(v.to_string())
            };
            hp = hp.set(k, value);
        }
    }
    hp
}

fn cmd_tune(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let kernel_name = args
        .positional
        .first()
        .context("usage: tune <kernel> <device>")?;
    let device_name = args
        .positional
        .get(1)
        .context("usage: tune <kernel> <device>")?;
    let algo = args.opt_or("algo", "genetic_algorithm");
    let hp = parse_hp(&args.opt_or("hp", ""));
    let repeats = args.opt_usize("repeats", 5);
    let cutoff = args.opt_f64("budget-cutoff", 0.95);
    let json = args.flag("json");

    // One campaign on the (kernel × device) matrix: the hub cache is
    // built on demand, the methodology budget/baseline derived, and the
    // repeats executed on the persistent worker pool.
    let kernel = kernels::kernel_by_name(kernel_name)?;
    let mut campaign = Campaign::new(&algo)
        .hyperparams(hp)
        .cutoff(cutoff)
        .points(50)
        .matrix(
            &c.hub,
            Arc::clone(&c.engine),
            &[kernel.name],
            &[device_name.as_str()],
        )?
        .repeats(repeats)
        .seed(c.seed);
    if !json {
        campaign = campaign.observer(Arc::new(LogObserver));
    }
    let result = campaign.run()?;

    if json {
        println!("{}", result.to_json().to_pretty());
        return Ok(());
    }
    for so in &result.spaces {
        println!(
            "{}: best {:.6}s vs optimum {:.6}s | mean score {:.3} \
             ({:.0} unique evals avg, budget {:.1}s)",
            so.label,
            so.best_value,
            so.optimum,
            so.mean_score,
            so.mean_unique_evals,
            so.budget_seconds
        );
    }
    println!(
        "\n{} [{}]: aggregate score {:.3} over {} repeats \
         ({:.2}s wall-clock, {:.0}s simulated)",
        result.algo,
        result.hp_key,
        result.score(),
        result.repeats,
        result.wallclock_seconds,
        result.simulated_seconds
    );
    Ok(())
}

/// Progress reporter for hypertuning campaigns: one log line per scored
/// hyperparameter configuration (the per-run detail stays at debug via
/// `--verbose`).
struct HypertuneProgress;

impl Observer for HypertuneProgress {
    fn config_scored(&self, config_idx: usize, hp_key: &str, score: f64) {
        log_info!("config {config_idx} [{hp_key}]: score {score:.3}");
    }

    fn sweep_started(&self, optimizers: usize, repeats: usize) {
        log_info!("registry sweep: {optimizers} optimizers x {repeats} repeats");
    }

    fn sweep_optimizer_started(&self, idx: usize, algo: &str, configs: usize) {
        log_info!("sweep [{idx}] {algo}: {configs} hyperparameter configs");
    }

    fn sweep_optimizer_finished(&self, idx: usize, algo: &str, default: f64, best: f64) {
        log_info!("sweep [{idx}] {algo}: default {default:.3} -> best {best:.3}");
    }

    fn meta_sweep_started(&self, strategies: usize, repeats: usize) {
        log_info!("metasweep: {strategies} strategies, {repeats} full repeats");
    }

    fn meta_leg_started(&self, strategy: &str, target: &str, configs: usize, budget_cost: f64) {
        log_info!("metasweep {strategy}/{target}: {configs} configs, budget {budget_cost:.1}");
    }

    fn meta_leg_finished(
        &self,
        strategy: &str,
        target: &str,
        best_score: f64,
        spent_cost: f64,
        evals: usize,
    ) {
        log_info!(
            "metasweep {strategy}/{target}: best {best_score:.3} \
             ({evals} evals, {spent_cost:.1} full-repeat units)"
        );
    }

    fn leg_retried(&self, leg: &str, attempt: usize, max_attempts: usize, error: &str) {
        log_warn!("retrying {leg} (attempt {attempt}/{max_attempts}): {error}");
    }

    fn leg_failed(&self, leg: &str, error: &str, attempts: usize) {
        log_warn!("quarantined {leg} after {attempts} attempt(s): {error}");
    }

    fn checkpoint_saved(&self, path: &str, completed_legs: usize) {
        log_debug!("checkpoint: {completed_legs} legs -> {path}");
    }
}

fn cmd_hypertune(args: &Args) -> Result<()> {
    let json = args.flag("json");
    let mut c = ctx(args)?;
    if !json {
        c = c.with_observer(Arc::new(HypertuneProgress));
    }
    let algo = args
        .positional
        .first()
        .context("usage: hypertune <algo>")?
        .clone();
    let kind = args.opt_or("kind", "limited");
    let results = match kind.as_str() {
        "limited" => c.limited_results(&algo)?,
        "extended" => c.extended_results(&algo)?,
        other => bail!("unknown kind {other:?}"),
    };
    if json {
        println!("{}", results.to_json().to_pretty());
        return Ok(());
    }
    println!(
        "{algo} ({kind}): {} configurations evaluated, {} repeats",
        results.results.len(),
        results.repeats
    );
    println!("best:  {:.3}  {}", results.best().score, results.best().hp_key);
    println!(
        "mean:  {:.3}  {}",
        results.most_average().score,
        results.most_average().hp_key
    );
    println!("worst: {:.3}  {}", results.worst().score, results.worst().hp_key);
    println!(
        "wall-clock {:.1}s; simulated-live equivalent {:.1}h ({:.0}x speedup)",
        results.wallclock_seconds,
        results.simulated_seconds / 3600.0,
        results.simulated_seconds / results.wallclock_seconds.max(1e-9)
    );
    Ok(())
}

/// `--repeats` as an override: present means "use exactly this many", absent
/// means "defer to the scale's default" (`opt_usize` handles the parse
/// diagnostics; the default is unreachable when the option is present).
fn opt_repeats(args: &Args) -> Option<usize> {
    args.opt("repeats").map(|_| args.opt_usize("repeats", 0))
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let json = args.flag("json");
    let mut c = ctx(args)?;
    if !json {
        c = c.with_observer(Arc::new(HypertuneProgress));
    }
    // One campaign per (grid-bearing optimizer, hyperparameter config)
    // over the training spaces; per-optimizer exhaustive results are
    // persisted in the results dir, so an interrupted sweep resumes from
    // the algorithms already done.
    let result = c.registry_sweep_at(opt_repeats(args))?;
    if json {
        println!("{}", result.to_json().to_pretty());
    } else {
        hypertuning::render_sweep_report(&result, &c.report("sweep"))?;
    }
    // Quarantined legs exit nonzero — but only after the envelope was
    // saved and rendered, so the completed legs are never discarded.
    if !result.failed_legs.is_empty() {
        bail!(
            "{} sweep leg(s) quarantined after exhausting retries; \
             the saved envelope retains every completed leg",
            result.failed_legs.len()
        );
    }
    Ok(())
}

fn cmd_metasweep(args: &Args) -> Result<()> {
    let json = args.flag("json");
    let config = hypertuning::MetaSweepConfig {
        strategies: args
            .opt_or("strategy", "")
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(str::to_string)
            .collect(),
        budget: args.opt("budget").map(|_| args.opt_f64("budget", 0.0)),
        eta: args.opt_usize("eta", 4),
        min_repeats: args.opt_usize("min-repeats", 1),
    };
    let repeats_override = opt_repeats(args);

    let (result, report) = if let Some(dims) = args.opt("synthetic") {
        // Hub-free path: a generated constrained space with a synthetic
        // cost model stands in for the brute-forced kernel hub, so CI can
        // race the full strategy registry from a cold checkout. Nothing
        // is persisted; the reference sweep is recomputed each run.
        let spec = SpaceGenSpec::new(
            SpaceGenSpec::parse_dims(dims)?,
            args.opt_f64("validity", 0.05),
            ConstraintFamily::parse(&args.opt_or("family", "hash"))?,
            args.opt_u64("gen-seed", 7),
        );
        let space = Arc::new(spec.build()?);
        if space.is_empty() {
            bail!("synthetic space {} has no valid configurations", space.name);
        }
        let cache = Arc::new(tunetuner::dataset::synth_cache(&space, spec.seed, 3, 0.02));
        let train = vec![tunetuner::methodology::SpaceEval::new(space, cache, 0.95, 15)];
        let scale = Scale::parse(&args.opt_or("scale", "quick"))?;
        let repeats = repeats_override.unwrap_or(scale.tuning_repeats);
        let seed = args.opt_u64("seed", 42);
        let observer: Arc<dyn Observer> = if json {
            Arc::new(tunetuner::campaign::NullObserver)
        } else {
            Arc::new(HypertuneProgress)
        };
        // `--envelope PATH` turns the hub-free run into a durable,
        // resumable campaign: a prior envelope at PATH replays its
        // finished legs, the file is checkpointed after every completed
        // leg, and the final merge is saved back — so a killed or
        // fault-quarantined run resumes instead of starting over. The
        // reference sweep stays fault-free (it is the yardstick every
        // leg is measured against); job faults apply to the metasweep's
        // own campaigns.
        let envelope = args.opt("envelope").map(PathBuf::from);
        let prior = envelope
            .as_deref()
            .and_then(hypertuning::MetaSweepResult::load_tolerant);
        let checkpoint = envelope
            .as_ref()
            .map(|p| hypertuning::Checkpoint::new(p.clone(), 1));
        let reference = hypertuning::sweep_registry(&train, repeats, seed, Arc::clone(&observer))?;
        let result = hypertuning::metasweep_registry_checkpointed(
            &train,
            repeats,
            seed,
            &reference,
            &config,
            prior.as_ref(),
            checkpoint.as_ref(),
            tunetuner::faults::global(),
            observer,
        )?;
        if let Some(path) = &envelope {
            result.save(path)?;
        }
        let report = Report::new(&PathBuf::from(args.opt_or("results", "results")), "metasweep");
        (result, report)
    } else {
        let mut c = ctx(args)?;
        if !json {
            c = c.with_observer(Arc::new(HypertuneProgress));
        }
        let result = c.registry_metasweep(&config, repeats_override)?;
        let report = c.report("metasweep");
        (result, report)
    };

    if json {
        println!("{}", result.to_json().to_pretty());
    } else {
        hypertuning::render_metasweep_report(&result, &report)?;
    }

    // Quarantined legs exit nonzero — after the envelope was saved and
    // the failure table rendered, so completed legs are never discarded
    // and a faultless re-run resumes from them.
    if !result.failed_legs.is_empty() {
        bail!(
            "{} metasweep leg(s) quarantined after exhausting retries; \
             the saved envelope retains every completed leg",
            result.failed_legs.len()
        );
    }

    // CI gates: every raced strategy must clear both bars (expressed in
    // percent, matching the report's recovery/cost columns).
    let min_recovery = args.opt("min-recovery").map(|_| args.opt_f64("min-recovery", 0.0));
    let max_cost = args.opt("max-cost").map(|_| args.opt_f64("max-cost", 100.0));
    let mut failures = Vec::new();
    for run in &result.strategies {
        let recovery = run.recovery() * 100.0;
        let cost = run.cost_fraction() * 100.0;
        if let Some(floor) = min_recovery {
            if recovery < floor {
                failures.push(format!(
                    "{}: recovered {recovery:.1}% of the exhaustive improvement \
                     (gate: >= {floor:.0}%)",
                    run.strategy
                ));
            }
        }
        if let Some(cap) = max_cost {
            if cost > cap + 1e-9 {
                failures.push(format!(
                    "{}: spent {cost:.1}% of the exhaustive cost (gate: <= {cap:.0}%)",
                    run.strategy
                ));
            }
        }
    }
    if !failures.is_empty() {
        bail!("metasweep gate failed: {}", failures.join("; "));
    }
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let algo = args
        .positional
        .first()
        .context("usage: sensitivity <algo>")?
        .clone();
    let results = c.limited_results(&algo)?;
    let space = hypertuning::limited_space(&algo)?;
    println!("{:<18} {:>10} {:>10} {:>8}", "hyperparameter", "KW H", "p-value", "MI");
    for s in hypertuning::sensitivity::sensitivity(&results, &space) {
        let flag = if s.p > 0.05 { "  <- no meaningful effect" } else { "" };
        println!(
            "{:<18} {:>10.3} {:>10.4} {:>8.4}{flag}",
            s.param, s.h, s.p, s.mutual_information
        );
    }
    Ok(())
}

fn cmd_spacegen(args: &Args) -> Result<()> {
    let dims_str = args
        .positional
        .first()
        .context("usage: spacegen <AxBxC dims>")?;
    let spec = SpaceGenSpec::new(
        SpaceGenSpec::parse_dims(dims_str)?,
        args.opt_f64("validity", 0.01),
        ConstraintFamily::parse(&args.opt_or("family", "hash"))?,
        args.opt_u64("gen-seed", 7),
    );
    let index = match args.opt_or("index", "auto").as_str() {
        "auto" => IndexKind::Auto,
        "bitset" => IndexKind::Bitset,
        "map" => IndexKind::Map,
        "compressed" => IndexKind::Compressed,
        other => bail!("unknown index kind {other:?} (auto|bitset|map|compressed)"),
    };
    let flat = match args.opt_or("flat", "auto").as_str() {
        "auto" => FlatPolicy::Auto,
        "materialize" => FlatPolicy::Materialize,
        "elide" => FlatPolicy::Elide,
        other => bail!("unknown flat policy {other:?} (auto|materialize|elide)"),
    };
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let t0 = std::time::Instant::now();
    let space = spec.build_with(BuildOptions { index, flat })?;
    let build_secs = t0.elapsed().as_secs_f64();
    let cart = space.cartesian_size();
    let stats = space.build_stats();
    println!("space {}", space.name);
    println!("  cartesian ranks:   {cart}");
    println!(
        "  valid configs:     {} ({:.4}% of cartesian)",
        space.len(),
        100.0 * space.len() as f64 / cart as f64
    );
    println!("  index kind:        {:?}", space.index_kind());
    println!(
        "  flat buffer:       {}",
        if space.has_flat() { "materialized" } else { "elided" }
    );
    println!(
        "  pruned (prefix):   {} configs, rejections by depth {:?}",
        stats.pruned_configs, stats.prefix_rejections
    );
    println!("  build time:        {build_secs:.3}s");
    if space.is_empty() {
        return Ok(());
    }

    if let Some(algo) = args.opt("campaign") {
        let seed = args.opt_u64("seed", 42);
        let evals = args.opt_usize("evals", 200);
        let hp = parse_hp(&args.opt_or("hp", ""));
        let optimizer = optimizers::create(algo, &hp)?;
        let space = Arc::new(space);
        let cache = Arc::new(tunetuner::dataset::synth_cache(&space, spec.seed, 3, 0.02));
        let mut sim =
            tunetuner::runner::SimulationRunner::new(Arc::clone(&space), Arc::clone(&cache))?;
        // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
        let t1 = std::time::Instant::now();
        let mut tuning = tunetuner::runner::Tuning::new(
            &mut sim,
            tunetuner::runner::Budget::evals(evals.min(space.len())),
        );
        let mut rng = tunetuner::util::rng::Rng::new(seed);
        optimizer.run(&mut tuning, &mut rng);
        let trace = tuning.finish();
        println!(
            "campaign {algo} (seed {seed}, {} unique evals): best {:?} vs optimum {:.6} \
             in {:.2}s wall-clock",
            trace.unique_evals,
            trace.best(),
            cache.optimum(),
            t1.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

fn cmd_bench_trend(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.opt_or("dir", "."));
    // Threshold is given in percent (25 = fail when a group's mean is
    // more than 25% slower than the previous snapshot's).
    let threshold = args.opt_f64("threshold", 25.0) / 100.0;
    let snapshots = bench_trend::discover(&dir)?;
    print!("{}", bench_trend::render(&snapshots, threshold));
    let regressed: Vec<String> = bench_trend::latest_deltas(&snapshots)
        .iter()
        .filter(|d| d.regressed(threshold))
        .map(|d| {
            format!(
                "{} {:.2}x (PR {} -> PR {}, {} benches)",
                d.group, d.ratio, d.from_pr, d.to_pr, d.common
            )
        })
        .collect();
    if !regressed.is_empty() && args.flag("gate") {
        bail!(
            "perf gate: {} group(s) regressed past {:.0}%: {}",
            regressed.len(),
            threshold * 100.0,
            regressed.join("; ")
        );
    }
    Ok(())
}

/// Self-dogfooded static analysis: run the invariant rules over the
/// library source and fail on denied violations. CI runs
/// `lint --deny all --json --out lint_report.json` before tier-1.
fn cmd_lint(args: &Args) -> Result<()> {
    let root = PathBuf::from(args.opt_or("root", "rust/src"));
    let deny = analysis::DenySet::parse(&args.opt_or("deny", "all"))?;
    let report = analysis::lint_tree(&root)
        .with_context(|| format!("linting {}", root.display()))?;
    if args.flag("json") {
        println!("{}", analysis::report::to_json(&report).to_pretty());
    } else {
        print!("{}", analysis::report::render_text(&report));
    }
    if let Some(out) = args.opt("out") {
        analysis::report::save(&report, std::path::Path::new(out))?;
        log_info!("lint envelope written to {out}");
    }
    let denied = report
        .diagnostics
        .iter()
        .filter(|d| deny.denies(d.rule))
        .count();
    if denied > 0 {
        bail!("lint: {denied} denied violation(s) (see report above)");
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let c = ctx(args)?;
    let id = args
        .positional
        .first()
        .context("usage: experiment <id|all>")?
        .clone();
    if c.engine.backend() == tunetuner::runtime::EngineBackend::Native {
        log_warn!("running with the native oracle backend (no PJRT artifacts)");
    }
    // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
    let t0 = std::time::Instant::now();
    experiments::run(&c, &id)?;
    log_info!("experiment {id} done in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
