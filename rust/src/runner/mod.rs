//! Runners: how an optimization algorithm's configuration evaluations are
//! served.
//!
//! * [`live`] — the "real hardware" path: every evaluation goes through the
//!   PJRT device model, observation noise is drawn, and the simulated
//!   wall-clock advances by compile + run + overhead.
//! * [`sim`] — the paper's **simulation mode**: evaluations are replayed
//!   from a brute-forced cache file; the simulated clock advances exactly
//!   as live tuning would have, but the real cost is a table lookup. From
//!   the optimizer's point of view the two are indistinguishable (asserted
//!   by tests).
//!
//! [`Tuning`] wraps a runner with budget tracking, the within-run
//! configuration cache (revisits cost only framework overhead, as in
//! Kernel Tuner), and the trace recording used by the methodology scoring.

pub mod live;
pub mod sim;

pub use live::LiveRunner;
pub use sim::SimulationRunner;

use crate::searchspace::SearchSpace;

/// Result of evaluating one kernel configuration.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Mean of the observations (objective value, seconds); `INFINITY` for
    /// configurations that failed to launch.
    pub value: f64,
    /// Raw observations (empty for failed configurations).
    pub observations: Vec<f64>,
    /// Simulated seconds spent compiling this configuration.
    pub compile_time: f64,
    /// Simulated seconds spent executing all observations.
    pub run_time: f64,
    /// Simulated framework overhead.
    pub overhead: f64,
    /// Whether the configuration launched successfully.
    pub valid: bool,
}

impl EvalResult {
    pub fn total_cost(&self) -> f64 {
        self.compile_time + self.run_time + self.overhead
    }
}

/// Serves configuration evaluations for one (kernel, device) search space.
pub trait Runner: Send {
    fn space(&self) -> &SearchSpace;
    /// Evaluate a configuration by index.
    fn evaluate(&mut self, config_idx: usize) -> EvalResult;
    /// A short label for logs ("gemm@A100 live" etc.).
    fn label(&self) -> String;

    /// Allocation-free fast path for the tuning hot loop: returns
    /// `(value, total_cost)`. Defaults to `evaluate`; the simulation
    /// runner overrides it to skip cloning the observation vector (which
    /// the budget/trace accounting never reads).
    fn evaluate_lite(&mut self, config_idx: usize) -> (f64, f64) {
        let r = self.evaluate(config_idx);
        (r.value, r.total_cost())
    }
}

/// One point in a tuning trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub config: usize,
    /// Objective value (INFINITY for failures).
    pub value: f64,
    /// Simulated clock *after* this evaluation.
    pub clock: f64,
    /// Whether this evaluation was a cache hit (config revisit).
    pub cached: bool,
}

/// The record of one tuning run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// Total simulated seconds consumed.
    pub elapsed: f64,
    /// Number of *unique* configurations evaluated.
    pub unique_evals: usize,
}

impl Trace {
    /// Best (lowest) objective value at or before simulated time `t`,
    /// or None if nothing valid was found by then.
    pub fn best_at(&self, t: f64) -> Option<f64> {
        let mut best = f64::INFINITY;
        for p in &self.points {
            if p.clock > t {
                break;
            }
            if p.value < best {
                best = p.value;
            }
        }
        if best.is_finite() {
            Some(best)
        } else {
            None
        }
    }

    /// Final best value.
    pub fn best(&self) -> Option<f64> {
        let b = self
            .points
            .iter()
            .map(|p| p.value)
            .fold(f64::INFINITY, f64::min);
        if b.is_finite() {
            Some(b)
        } else {
            None
        }
    }
}

/// Budget limits for one tuning run.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Maximum simulated seconds.
    pub max_seconds: f64,
    /// Maximum unique configuration evaluations (usize::MAX = unlimited).
    pub max_unique_evals: usize,
    /// Maximum total proposals including cache hits. Guards against
    /// schedule-heavy optimizers spinning on (nearly free) revisits far
    /// past anything a real tuning run would do.
    pub max_proposals: usize,
}

impl Budget {
    pub fn seconds(s: f64) -> Budget {
        Budget {
            max_seconds: s,
            max_unique_evals: usize::MAX,
            max_proposals: usize::MAX,
        }
    }

    pub fn evals(n: usize) -> Budget {
        Budget {
            max_seconds: f64::INFINITY,
            max_unique_evals: n,
            max_proposals: usize::MAX,
        }
    }

    /// Cap total proposals (unique + cached).
    pub fn with_proposal_cap(mut self, cap: usize) -> Budget {
        self.max_proposals = cap;
        self
    }
}

/// A budget-tracked tuning session over a runner: the interface the
/// optimizers program against.
pub struct Tuning<'a> {
    runner: &'a mut dyn Runner,
    budget: Budget,
    trace: Trace,
    /// Within-run evaluation cache, directly indexed by config index:
    /// `cached_values[i]` is meaningful iff bit `i` of `seen` is set. No
    /// hashing on the revisit path — one bit test and one array read.
    seen: Vec<u64>,
    cached_values: Vec<f64>,
    /// Framework overhead charged on cache hits.
    cached_overhead: f64,
    /// Size of the search space (tuning is done once it is exhausted).
    space_len: usize,
}

impl<'a> Tuning<'a> {
    pub fn new(runner: &'a mut dyn Runner, budget: Budget) -> Tuning<'a> {
        let space_len = runner.space().len();
        Tuning {
            runner,
            budget,
            trace: Trace::default(),
            seen: vec![0u64; (space_len + 63) / 64],
            cached_values: vec![0.0; space_len],
            // Kernel Tuner semantics: a cache hit returns instantly and
            // consumes no tuning time. Runaway revisit loops are bounded
            // by Budget::max_proposals and the space-exhaustion check.
            cached_overhead: 0.0,
            space_len,
        }
    }

    pub fn space(&self) -> &SearchSpace {
        self.runner.space()
    }

    /// True once the budget is exhausted; optimizers must stop evaluating.
    /// Also true once every configuration has been evaluated: with free
    /// cache hits there is nothing left to learn (and an eval-count budget
    /// larger than the space could otherwise never be reached).
    pub fn done(&self) -> bool {
        self.trace.elapsed >= self.budget.max_seconds
            || self.trace.unique_evals >= self.budget.max_unique_evals
            || self.trace.points.len() >= self.budget.max_proposals
            || self.trace.unique_evals >= self.space_len
    }

    /// Remaining simulated seconds.
    pub fn remaining(&self) -> f64 {
        (self.budget.max_seconds - self.trace.elapsed).max(0.0)
    }

    /// Evaluate a configuration; INFINITY for failed configs. The
    /// simulated clock advances accordingly.
    pub fn eval(&mut self, config_idx: usize) -> f64 {
        let (word, bit) = (config_idx >> 6, 1u64 << (config_idx & 63));
        if self.seen[word] & bit != 0 {
            let v = self.cached_values[config_idx];
            self.trace.elapsed += self.cached_overhead;
            self.trace.points.push(TracePoint {
                config: config_idx,
                value: v,
                clock: self.trace.elapsed,
                cached: true,
            });
            return v;
        }
        let (value, cost) = self.runner.evaluate_lite(config_idx);
        self.trace.elapsed += cost;
        self.trace.unique_evals += 1;
        self.seen[word] |= bit;
        self.cached_values[config_idx] = value;
        self.trace.points.push(TracePoint {
            config: config_idx,
            value,
            clock: self.trace.elapsed,
            cached: false,
        });
        value
    }

    /// Current best value (INFINITY if nothing valid yet).
    pub fn best_value(&self) -> f64 {
        self.trace.best().unwrap_or(f64::INFINITY)
    }

    /// Finish and return the trace.
    pub fn finish(self) -> Trace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runtime::Engine;
    use std::sync::Arc;

    fn live_runner() -> LiveRunner {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        LiveRunner::new(
            kernel,
            &A100,
            Arc::new(Engine::native()),
            NoiseModel::default(),
            42,
        )
    }

    #[test]
    fn budget_stops_tuning() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(5));
        let mut i = 0;
        while !t.done() {
            t.eval(i % 10);
            i += 1;
        }
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 5);
    }

    #[test]
    fn revisits_are_cached() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(100));
        let v1 = t.eval(3);
        let clock1 = t.trace.elapsed;
        let v2 = t.eval(3);
        let clock2 = t.trace.elapsed;
        assert_eq!(v1, v2);
        assert!(clock2 - clock1 < 0.01, "cache hit must be ~free");
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 1);
        assert!(trace.points[1].cached);
    }

    #[test]
    fn best_at_respects_time() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(10));
        for i in 0..10 {
            t.eval(i);
        }
        let trace = t.finish();
        assert!(trace.best_at(0.0).is_none());
        let best_end = trace.best_at(trace.elapsed).unwrap();
        assert_eq!(Some(best_end), trace.best());
        // best is monotone over time
        let mut prev = f64::INFINITY;
        for k in 1..=10 {
            let t_k = trace.elapsed * k as f64 / 10.0;
            if let Some(b) = trace.best_at(t_k) {
                assert!(b <= prev + 1e-12);
                prev = b;
            }
        }
    }

    #[test]
    fn time_budget_stops() {
        let mut r = live_runner();
        // Tiny time budget: a single eval (compile ~seconds) exceeds it.
        let mut t = Tuning::new(&mut r, Budget::seconds(0.5));
        t.eval(0);
        assert!(t.done());
    }
}
