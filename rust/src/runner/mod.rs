//! Runners: how an optimization algorithm's configuration evaluations are
//! served.
//!
//! * [`live`] — the "real hardware" path: every evaluation goes through the
//!   PJRT device model, observation noise is drawn, and the simulated
//!   wall-clock advances by compile + run + overhead.
//! * [`sim`] — the paper's **simulation mode**: evaluations are replayed
//!   from a brute-forced cache file; the simulated clock advances exactly
//!   as live tuning would have, but the real cost is a table lookup. From
//!   the optimizer's point of view the two are indistinguishable (asserted
//!   by tests).
//!
//! [`Tuning`] wraps a runner with budget tracking, the within-run
//! configuration cache (revisits cost only framework overhead, as in
//! Kernel Tuner), and the trace recording used by the methodology scoring.
//! Its space-sized working buffers can be pooled across runs through
//! [`TuningScratch`] — a campaign's spaces×repeats jobs reuse one scratch
//! per executor worker instead of allocating and zeroing megabytes per
//! run.
//!
//! ## Batched evaluation
//!
//! Population optimizers propose whole candidate sets per generation;
//! [`Tuning::eval_batch`] serves them with one seen-bitset probe per
//! proposal and a single [`Runner::evaluate_batch_lite`] gather over the
//! deduplicated fresh configurations (for the simulation runner: a tight
//! indexed loop over the columnar `SimTable`). The semantics are defined
//! to be *exactly* those of the scalar loop
//! `for &i in idxs { if done() { break; } eval(i); }`:
//!
//! * **Dedup** — a config already evaluated (in this run or earlier in
//!   the same batch) is a revisit: it costs only the cached overhead and
//!   is served from the value cache, never re-gathered.
//! * **Partial batches** — budget and cutoff checks run per proposal in
//!   commit order; when the clock or a cap expires mid-batch, the tail
//!   is discarded and only the consumed prefix appears in the trace (and
//!   in the returned value slice). Unconsumed fresh configs have their
//!   optimistically set seen-bits rolled back.
//! * **Cost accounting** — the gather itself does no budget or runner
//!   accounting; the consumed prefix is reported to
//!   [`Runner::batch_committed`] in commit order, so clocks and lookup
//!   counters stay bit-identical to a scalar `evaluate_lite` sequence.
//!   (For *live* runners using the default scalar-loop gather, configs
//!   past a mid-batch clock expiry are still executed and then
//!   discarded — a divergence that can only occur on the final batch of
//!   a run and never changes the trace.)

pub mod live;
pub mod sim;

pub use live::LiveRunner;
pub use sim::SimulationRunner;

use crate::searchspace::SearchSpace;

/// Result of evaluating one kernel configuration.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Mean of the observations (objective value, seconds); `INFINITY` for
    /// configurations that failed to launch.
    pub value: f64,
    /// Raw observations (empty for failed configurations).
    pub observations: Vec<f64>,
    /// Simulated seconds spent compiling this configuration.
    pub compile_time: f64,
    /// Simulated seconds spent executing all observations.
    pub run_time: f64,
    /// Simulated framework overhead.
    pub overhead: f64,
    /// Whether the configuration launched successfully.
    pub valid: bool,
}

impl EvalResult {
    pub fn total_cost(&self) -> f64 {
        self.compile_time + self.run_time + self.overhead
    }
}

/// Serves configuration evaluations for one (kernel, device) search space.
pub trait Runner: Send {
    fn space(&self) -> &SearchSpace;
    /// Evaluate a configuration by index.
    fn evaluate(&mut self, config_idx: usize) -> EvalResult;
    /// A short label for logs ("gemm@A100 live" etc.).
    fn label(&self) -> String;

    /// Allocation-free fast path for the tuning hot loop: returns
    /// `(value, total_cost)`. Defaults to `evaluate`; the simulation
    /// runner overrides it to skip cloning the observation vector (which
    /// the budget/trace accounting never reads).
    fn evaluate_lite(&mut self, config_idx: usize) -> (f64, f64) {
        let r = self.evaluate(config_idx);
        (r.value, r.total_cost())
    }

    /// Batched fast path: evaluate every index in `idxs`, filling `out`
    /// (cleared first) with `(value, total_cost)` pairs in order. Called
    /// by [`Tuning::eval_batch`] with the deduplicated fresh configs of
    /// one proposal batch, already capped at the remaining unique-eval
    /// allowance. Implementations must do no budget accounting here —
    /// the tuning clock can expire mid-batch, discarding the tail; the
    /// consumed prefix is reported to [`Runner::batch_committed`]. The
    /// default is a scalar `evaluate_lite` loop, correct for any runner
    /// whose per-call accounting lives in `evaluate`/`evaluate_lite`
    /// (the discarded tail then only wastes work, never trace fidelity).
    fn evaluate_batch_lite(&mut self, idxs: &[usize], out: &mut Vec<(f64, f64)>) {
        out.clear();
        out.reserve(idxs.len());
        for &i in idxs {
            out.push(self.evaluate_lite(i));
        }
    }

    /// Accounting hook: the consumed prefix of the pairs produced by the
    /// preceding [`Runner::evaluate_batch_lite`] call, in commit order.
    /// Runners that override the gather to skip per-call accounting (the
    /// simulation runner) fold their clock/lookup counters here so the
    /// batched path stays bit-identical to a scalar `evaluate_lite`
    /// sequence. Default: no-op (the default gather already accounted).
    fn batch_committed(&mut self, _pairs: &[(f64, f64)]) {}
}

/// One point in a tuning trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub config: usize,
    /// Objective value (INFINITY for failures).
    pub value: f64,
    /// Simulated clock *after* this evaluation.
    pub clock: f64,
    /// Whether this evaluation was a cache hit (config revisit).
    pub cached: bool,
}

/// The record of one tuning run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// Total simulated seconds consumed.
    pub elapsed: f64,
    /// Number of *unique* configurations evaluated.
    pub unique_evals: usize,
}

impl Trace {
    /// Best (lowest) objective value at or before simulated time `t`,
    /// or None if nothing valid was found by then.
    pub fn best_at(&self, t: f64) -> Option<f64> {
        let mut best = f64::INFINITY;
        for p in &self.points {
            if p.clock > t {
                break;
            }
            if p.value < best {
                best = p.value;
            }
        }
        if best.is_finite() {
            Some(best)
        } else {
            None
        }
    }

    /// Final best value.
    pub fn best(&self) -> Option<f64> {
        let b = self
            .points
            .iter()
            .map(|p| p.value)
            .fold(f64::INFINITY, f64::min);
        if b.is_finite() {
            Some(b)
        } else {
            None
        }
    }
}

/// Budget limits for one tuning run.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Maximum simulated seconds.
    pub max_seconds: f64,
    /// Maximum unique configuration evaluations (usize::MAX = unlimited).
    pub max_unique_evals: usize,
    /// Maximum total proposals including cache hits. Guards against
    /// schedule-heavy optimizers spinning on (nearly free) revisits far
    /// past anything a real tuning run would do.
    pub max_proposals: usize,
}

impl Budget {
    pub fn seconds(s: f64) -> Budget {
        Budget {
            max_seconds: s,
            max_unique_evals: usize::MAX,
            max_proposals: usize::MAX,
        }
    }

    pub fn evals(n: usize) -> Budget {
        Budget {
            max_seconds: f64::INFINITY,
            max_unique_evals: n,
            max_proposals: usize::MAX,
        }
    }

    /// Cap total proposals (unique + cached).
    pub fn with_proposal_cap(mut self, cap: usize) -> Budget {
        self.max_proposals = cap;
        self
    }
}

/// Reusable per-run working memory for [`Tuning`]: the seen-bitset, the
/// directly indexed value cache, and the trace-point vector. A fresh
/// `Tuning` allocates (and zeroes) all three per run — megabytes per
/// (space, repeat) job on the big spaces. Pooling one scratch per
/// executor worker turns that into: re-zero the bitset (64× smaller than
/// the value cache, which needs no zeroing — reads are gated by the
/// bitset) and clear the point vector in place.
#[derive(Default)]
pub struct TuningScratch {
    seen: Vec<u64>,
    cached_values: Vec<f64>,
    points: Vec<TracePoint>,
    /// Batch-path buffers (see [`Tuning::eval_batch`]): deduplicated
    /// fresh configs of the current batch, their gathered
    /// `(value, total_cost)` pairs, the per-proposal classification
    /// (rank into `batch_fresh`, `u32::MAX` = revisit), and the returned
    /// value slice. Capacity persists across pooled runs like the rest.
    batch_fresh: Vec<usize>,
    batch_pairs: Vec<(f64, f64)>,
    batch_class: Vec<u32>,
    batch_values: Vec<f64>,
}

impl TuningScratch {
    pub fn new() -> TuningScratch {
        TuningScratch::default()
    }

    /// Reset for a run over `space_len` configurations: zero the bitset
    /// words, grow (never shrink) the value cache without zeroing, clear
    /// the points keeping their capacity.
    fn reset(&mut self, space_len: usize) {
        self.seen.clear();
        self.seen.resize((space_len + 63) / 64, 0);
        if self.cached_values.len() < space_len {
            self.cached_values.resize(space_len, 0.0);
        }
        self.points.clear();
        self.batch_fresh.clear();
        self.batch_pairs.clear();
        self.batch_class.clear();
        self.batch_values.clear();
    }

    /// Run `f` with this thread's pooled scratch. Executor workers are
    /// persistent threads, so this is one scratch per worker slot for the
    /// process lifetime — exactly the reuse `Campaign::run` wants. Falls
    /// back to a fresh scratch on re-entrant use (a nested tuning run on
    /// the same thread), which stays correct, just unpooled.
    pub fn with_pooled<R>(f: impl FnOnce(&mut TuningScratch) -> R) -> R {
        thread_local! {
            static POOLED: std::cell::RefCell<TuningScratch> =
                std::cell::RefCell::new(TuningScratch::new());
        }
        POOLED.with(|cell| match cell.try_borrow_mut() {
            Ok(mut scratch) => f(&mut scratch),
            Err(_) => f(&mut TuningScratch::new()),
        })
    }
}

/// The run's working buffers: owned by this `Tuning` (the standalone
/// constructor) or borrowed from a pooled [`TuningScratch`].
enum Scratch<'a> {
    Owned(TuningScratch),
    Borrowed(&'a mut TuningScratch),
}

impl Scratch<'_> {
    #[inline]
    fn get(&mut self) -> &mut TuningScratch {
        match self {
            Scratch::Owned(s) => s,
            Scratch::Borrowed(s) => s,
        }
    }
}

/// One probe into the seen-bitset: the word slot and the bit mask for
/// `idx`. Callers test `*slot & bit`, then set (`*slot |= bit`) or roll
/// back (`*slot &= !bit`) on the *same* slot — one indexed access per
/// proposal, shared by the scalar and batch paths.
#[inline]
fn seen_slot(seen: &mut [u64], idx: usize) -> (&mut u64, u64) {
    (&mut seen[idx >> 6], 1u64 << (idx & 63))
}

/// A budget-tracked tuning session over a runner: the interface the
/// optimizers program against.
pub struct Tuning<'a> {
    runner: &'a mut dyn Runner,
    budget: Budget,
    /// Simulated seconds consumed so far.
    elapsed: f64,
    /// Unique configurations evaluated so far.
    unique_evals: usize,
    /// Total proposals including cache hits (== recorded trace points).
    proposals: usize,
    /// Running best value — kept current in `eval`, so `best_value` is
    /// O(1) instead of a full trace scan per optimizer iteration.
    best: f64,
    /// Within-run evaluation cache, directly indexed by config index:
    /// `scratch.cached_values[i]` is meaningful iff bit `i` of
    /// `scratch.seen` is set. No hashing on the revisit path — one bit
    /// test and one array read.
    scratch: Scratch<'a>,
    /// Framework overhead charged on cache hits.
    cached_overhead: f64,
    /// Size of the search space (tuning is done once it is exhausted).
    space_len: usize,
    /// Test/bench hook: route [`Tuning::eval_batch`] through a scalar
    /// [`Tuning::eval`] loop instead of the gather fast path.
    scalar_batch_fallback: bool,
}

impl<'a> Tuning<'a> {
    pub fn new(runner: &'a mut dyn Runner, budget: Budget) -> Tuning<'a> {
        Tuning::build(runner, budget, None)
    }

    /// Like [`Tuning::new`], but running on borrowed scratch buffers —
    /// see [`TuningScratch`]. The scratch is reset here; its contents
    /// after [`finish`](Tuning::finish) are unspecified.
    pub fn with_scratch(
        runner: &'a mut dyn Runner,
        budget: Budget,
        scratch: &'a mut TuningScratch,
    ) -> Tuning<'a> {
        Tuning::build(runner, budget, Some(scratch))
    }

    fn build(
        runner: &'a mut dyn Runner,
        budget: Budget,
        scratch: Option<&'a mut TuningScratch>,
    ) -> Tuning<'a> {
        let space_len = runner.space().len();
        let mut scratch = match scratch {
            Some(s) => Scratch::Borrowed(s),
            None => Scratch::Owned(TuningScratch::new()),
        };
        scratch.get().reset(space_len);
        Tuning {
            runner,
            budget,
            elapsed: 0.0,
            unique_evals: 0,
            proposals: 0,
            best: f64::INFINITY,
            scratch,
            // Kernel Tuner semantics: a cache hit returns instantly and
            // consumes no tuning time. Runaway revisit loops are bounded
            // by Budget::max_proposals and the space-exhaustion check.
            cached_overhead: 0.0,
            space_len,
            scalar_batch_fallback: false,
        }
    }

    /// Route [`Tuning::eval_batch`] through a scalar [`Tuning::eval`]
    /// loop instead of the single-gather fast path. The two are pinned
    /// bitwise-identical (values, trace, clocks, runner accounting), so
    /// this exists only as the reference side of equivalence tests and
    /// the `tuning/batch_vs_scalar` bench.
    pub fn set_scalar_batch_fallback(&mut self, on: bool) {
        self.scalar_batch_fallback = on;
    }

    pub fn space(&self) -> &SearchSpace {
        self.runner.space()
    }

    /// True once the budget is exhausted; optimizers must stop evaluating.
    /// Also true once every configuration has been evaluated: with free
    /// cache hits there is nothing left to learn (and an eval-count budget
    /// larger than the space could otherwise never be reached).
    pub fn done(&self) -> bool {
        self.elapsed >= self.budget.max_seconds
            || self.unique_evals >= self.budget.max_unique_evals
            || self.proposals >= self.budget.max_proposals
            || self.unique_evals >= self.space_len
    }

    /// Remaining simulated seconds.
    pub fn remaining(&self) -> f64 {
        (self.budget.max_seconds - self.elapsed).max(0.0)
    }

    /// Simulated seconds consumed so far.
    pub fn elapsed(&self) -> f64 {
        self.elapsed
    }

    /// Evaluate a configuration; INFINITY for failed configs. The
    /// simulated clock advances accordingly.
    pub fn eval(&mut self, config_idx: usize) -> f64 {
        let Tuning {
            runner,
            scratch,
            elapsed,
            unique_evals,
            proposals,
            best,
            cached_overhead,
            ..
        } = self;
        let s = scratch.get();
        // One bitset probe per proposal: the slot is reused for the set
        // on the fresh path instead of re-indexing the word.
        let (slot, bit) = seen_slot(&mut s.seen, config_idx);
        if *slot & bit != 0 {
            // Revisit: the value already went through the running-best
            // fold when first evaluated.
            let v = s.cached_values[config_idx];
            *elapsed += *cached_overhead;
            *proposals += 1;
            s.points.push(TracePoint {
                config: config_idx,
                value: v,
                clock: *elapsed,
                cached: true,
            });
            return v;
        }
        *slot |= bit;
        let (value, cost) = runner.evaluate_lite(config_idx);
        *elapsed += cost;
        *unique_evals += 1;
        *proposals += 1;
        s.cached_values[config_idx] = value;
        if value < *best {
            *best = value;
        }
        s.points.push(TracePoint {
            config: config_idx,
            value,
            clock: *elapsed,
            cached: false,
        });
        value
    }

    /// Evaluate a whole proposal batch; returns the values of the
    /// *consumed prefix* (scratch-backed, allocation-free on the steady
    /// state). Semantics are exactly those of the scalar loop
    /// `for &i in idxs { if self.done() { break; } self.eval(i); }` —
    /// same trace points, same clocks, same runner accounting, same
    /// budget-expiry truncation — but the fresh configurations are
    /// served by one [`Runner::evaluate_batch_lite`] gather instead of
    /// per-call dispatch. See the module docs for the full contract.
    pub fn eval_batch(&mut self, idxs: &[usize]) -> &[f64] {
        if self.scalar_batch_fallback {
            return self.eval_batch_scalar(idxs);
        }
        let Tuning {
            runner,
            budget,
            elapsed,
            unique_evals,
            proposals,
            best,
            scratch,
            cached_overhead,
            space_len,
            ..
        } = self;
        let s = scratch.get();
        s.batch_fresh.clear();
        s.batch_class.clear();
        s.batch_values.clear();

        // Phase A: one seen-bitset probe per proposal. First occurrences
        // of unseen configs get their bit set optimistically, so
        // in-batch duplicates classify as revisits exactly as the scalar
        // loop would see them; bits of fresh configs the budget ends up
        // not consuming are rolled back after the commit.
        for &idx in idxs {
            let (slot, bit) = seen_slot(&mut s.seen, idx);
            if *slot & bit != 0 {
                s.batch_class.push(u32::MAX);
            } else {
                *slot |= bit;
                s.batch_class.push(s.batch_fresh.len() as u32);
                s.batch_fresh.push(idx);
            }
        }

        // Phase B: one gather over the surviving ranks, capped at the
        // remaining unique-eval allowance (the commit below can never
        // consume a fresh pair past that cap; clock and proposal caps
        // are checked per item in commit order).
        let allowance = budget
            .max_unique_evals
            .min(*space_len)
            .saturating_sub(*unique_evals);
        let gathered = s.batch_fresh.len().min(allowance);
        runner.evaluate_batch_lite(&s.batch_fresh[..gathered], &mut s.batch_pairs);

        // Phase C: ordered commit with the scalar path's exact budget
        // semantics — stop before the first proposal at which done()
        // holds (inlined here: self is destructured).
        let mut consumed_fresh = 0usize;
        for (k, &idx) in idxs.iter().enumerate() {
            let done = *elapsed >= budget.max_seconds
                || *unique_evals >= budget.max_unique_evals
                || *proposals >= budget.max_proposals
                || *unique_evals >= *space_len;
            if done {
                break;
            }
            let class = s.batch_class[k];
            if class == u32::MAX {
                let v = s.cached_values[idx];
                *elapsed += *cached_overhead;
                *proposals += 1;
                s.points.push(TracePoint {
                    config: idx,
                    value: v,
                    clock: *elapsed,
                    cached: true,
                });
                s.batch_values.push(v);
            } else {
                debug_assert_eq!(class as usize, consumed_fresh, "fresh commits in order");
                let (value, cost) = s.batch_pairs[class as usize];
                *elapsed += cost;
                *unique_evals += 1;
                *proposals += 1;
                s.cached_values[idx] = value;
                if value < *best {
                    *best = value;
                }
                s.points.push(TracePoint {
                    config: idx,
                    value,
                    clock: *elapsed,
                    cached: false,
                });
                s.batch_values.push(value);
                consumed_fresh = class as usize + 1;
            }
        }
        // Roll back the optimistic bits of fresh configs the budget did
        // not consume, so a later proposal of the same config is a real
        // evaluation again.
        for &idx in &s.batch_fresh[consumed_fresh..] {
            let (slot, bit) = seen_slot(&mut s.seen, idx);
            *slot &= !bit;
        }
        runner.batch_committed(&s.batch_pairs[..consumed_fresh]);
        &s.batch_values
    }

    /// The scalar reference side of [`Tuning::eval_batch`]: a plain
    /// `eval` loop with the same truncation and return contract.
    fn eval_batch_scalar(&mut self, idxs: &[usize]) -> &[f64] {
        let mut consumed = 0usize;
        for &i in idxs {
            if self.done() {
                break;
            }
            self.eval(i);
            consumed += 1;
        }
        let TuningScratch {
            batch_values,
            cached_values,
            ..
        } = self.scratch.get();
        batch_values.clear();
        for &i in &idxs[..consumed] {
            batch_values.push(cached_values[i]);
        }
        batch_values
    }

    /// Current best value (INFINITY if nothing valid yet). O(1): the
    /// running best maintained by `eval`.
    pub fn best_value(&self) -> f64 {
        self.best
    }

    /// Finish and return the trace. Owned scratch gives up its point
    /// vector; borrowed (pooled) scratch is copied out exact-size so the
    /// pool keeps its capacity for the next run.
    pub fn finish(self) -> Trace {
        let Tuning {
            scratch,
            elapsed,
            unique_evals,
            ..
        } = self;
        let points = match scratch {
            Scratch::Owned(s) => s.points,
            Scratch::Borrowed(s) => s.points.clone(),
        };
        Trace {
            points,
            elapsed,
            unique_evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runtime::Engine;
    use std::sync::Arc;

    fn live_runner() -> LiveRunner {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        LiveRunner::new(
            kernel,
            &A100,
            Arc::new(Engine::native()),
            NoiseModel::default(),
            42,
        )
    }

    #[test]
    fn budget_stops_tuning() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(5));
        let mut i = 0;
        while !t.done() {
            t.eval(i % 10);
            i += 1;
        }
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 5);
    }

    #[test]
    fn revisits_are_cached() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(100));
        let v1 = t.eval(3);
        let clock1 = t.elapsed();
        let v2 = t.eval(3);
        let clock2 = t.elapsed();
        assert_eq!(v1, v2);
        assert!(clock2 - clock1 < 0.01, "cache hit must be ~free");
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 1);
        assert!(trace.points[1].cached);
    }

    #[test]
    fn best_at_respects_time() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(10));
        for i in 0..10 {
            t.eval(i);
        }
        let trace = t.finish();
        assert!(trace.best_at(0.0).is_none());
        let best_end = trace.best_at(trace.elapsed).unwrap();
        assert_eq!(Some(best_end), trace.best());
        // best is monotone over time
        let mut prev = f64::INFINITY;
        for k in 1..=10 {
            let t_k = trace.elapsed * k as f64 / 10.0;
            if let Some(b) = trace.best_at(t_k) {
                assert!(b <= prev + 1e-12);
                prev = b;
            }
        }
    }

    #[test]
    fn time_budget_stops() {
        let mut r = live_runner();
        // Tiny time budget: a single eval (compile ~seconds) exceeds it.
        let mut t = Tuning::new(&mut r, Budget::seconds(0.5));
        t.eval(0);
        assert!(t.done());
    }

    /// A synthetic-space sim runner over a hand-built cache with a known
    /// value landscape including invalid (INFINITY) configurations.
    fn sim_runner_with_invalids() -> SimulationRunner {
        let space = crate::kernels::kernel_by_name("synthetic")
            .unwrap()
            .space_arc();
        let records: Vec<crate::dataset::cache::ConfigRecord> = (0..space.len())
            .map(|i| {
                let valid = i % 3 != 1;
                let v = if valid {
                    2.0 + ((i as f64) * 0.61).sin()
                } else {
                    f64::INFINITY
                };
                crate::dataset::cache::ConfigRecord {
                    key: space.key(i),
                    value: v,
                    observations: if valid { vec![v] } else { vec![] },
                    compile_time: 1.0 + (i % 5) as f64 * 0.25,
                    valid,
                }
            })
            .collect();
        let cache = Arc::new(crate::dataset::cache::CacheData::new(
            "synthetic",
            "x",
            "",
            0,
            1,
            0.0,
            vec!["a".into()],
            records,
        ));
        SimulationRunner::new_unchecked(space, cache)
    }

    /// The O(1) running best must track `trace.best()` through
    /// interleaved uncached, cached, and invalid evaluations.
    #[test]
    fn running_best_matches_trace_best() {
        let mut r = sim_runner_with_invalids();
        let n = r.space().len();
        let mut t = Tuning::new(&mut r, Budget::evals(usize::MAX));
        // Mix fresh indices, revisits, and invalid configs (idx % 3 == 1).
        let invalid_slots = ((n - 2) / 3).max(1);
        let seq: Vec<usize> = (0..60)
            .map(|i| match i % 4 {
                0 => (i * 7) % n,                   // fresh-ish walk
                1 => (i * 7) % n,                   // immediate revisit (cached)
                2 => 1 + 3 * (i % invalid_slots),   // guaranteed invalid config
                _ => seq_prev(i, n),                // revisit an earlier index
            })
            .collect();
        let mut expected = f64::INFINITY;
        for &i in &seq {
            let v = t.eval(i);
            if v < expected {
                expected = v;
            }
            assert_eq!(
                t.best_value().to_bits(),
                expected.to_bits(),
                "running best drifted at config {i}"
            );
        }
        let best = t.best_value();
        let trace = t.finish();
        assert_eq!(best, trace.best().unwrap_or(f64::INFINITY));
    }

    fn seq_prev(i: usize, n: usize) -> usize {
        (i.saturating_sub(4) * 7) % n
    }

    /// One pooled scratch reused across runs must replay bit-identically
    /// to fresh per-run allocation, run after run.
    #[test]
    fn pooled_scratch_is_bit_identical_to_fresh_alloc() {
        let mut scratch = TuningScratch::new();
        for seed in 0..4usize {
            let seq: Vec<usize> = (0..40).map(|i| (i * (seed + 3)) % 20).collect();
            let run = |t: &mut Tuning| {
                for &i in &seq {
                    t.eval(i);
                }
            };
            let mut r1 = sim_runner_with_invalids();
            let mut fresh = Tuning::new(&mut r1, Budget::evals(1000));
            run(&mut fresh);
            let fresh = fresh.finish();
            let mut r2 = sim_runner_with_invalids();
            let mut pooled = Tuning::with_scratch(&mut r2, Budget::evals(1000), &mut scratch);
            run(&mut pooled);
            let pooled = pooled.finish();
            assert_eq!(fresh.points.len(), pooled.points.len());
            assert_eq!(fresh.unique_evals, pooled.unique_evals);
            assert_eq!(fresh.elapsed.to_bits(), pooled.elapsed.to_bits());
            for (a, b) in fresh.points.iter().zip(&pooled.points) {
                assert_eq!(a.config, b.config);
                assert_eq!(a.value.to_bits(), b.value.to_bits());
                assert_eq!(a.clock.to_bits(), b.clock.to_bits());
                assert_eq!(a.cached, b.cached);
            }
        }
    }

    fn assert_traces_bitwise(a: &Trace, b: &Trace) {
        assert_eq!(a.points.len(), b.points.len());
        assert_eq!(a.unique_evals, b.unique_evals);
        assert_eq!(a.elapsed.to_bits(), b.elapsed.to_bits());
        for (p, q) in a.points.iter().zip(&b.points) {
            assert_eq!(p.config, q.config);
            assert_eq!(p.value.to_bits(), q.value.to_bits());
            assert_eq!(p.clock.to_bits(), q.clock.to_bits());
            assert_eq!(p.cached, q.cached);
        }
    }

    /// The gather fast path must be bit-identical to the scalar fallback
    /// across fresh configs, cross-batch revisits, in-batch duplicates,
    /// and empty batches — values, traces, clocks, runner accounting.
    #[test]
    fn eval_batch_matches_scalar_loop_bitwise() {
        let mut rb = sim_runner_with_invalids();
        let mut rs = sim_runner_with_invalids();
        let n = rb.space().len();
        let batches: Vec<Vec<usize>> = vec![
            (0..8).map(|i| (i * 3) % n).collect(),
            vec![5, 5, 7, 5, 1, 1],
            (0..12).map(|i| (i * 7 + 2) % n).collect(),
            vec![],
            (0..6).map(|i| (i * 11 + 4) % n).collect(),
        ];
        let mut tb = Tuning::new(&mut rb, Budget::evals(1000));
        let mut ts = Tuning::new(&mut rs, Budget::evals(1000));
        ts.set_scalar_batch_fallback(true);
        for batch in &batches {
            let vb: Vec<f64> = tb.eval_batch(batch).to_vec();
            let vs: Vec<f64> = ts.eval_batch(batch).to_vec();
            assert_eq!(vb.len(), vs.len(), "batch {batch:?}");
            for (a, b) in vb.iter().zip(&vs) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(tb.best_value().to_bits(), ts.best_value().to_bits());
            assert_eq!(tb.elapsed().to_bits(), ts.elapsed().to_bits());
        }
        assert_traces_bitwise(&tb.finish(), &ts.finish());
        assert_eq!(rb.lookups, rs.lookups);
        assert_eq!(
            rb.simulated_elapsed.to_bits(),
            rs.simulated_elapsed.to_bits()
        );
    }

    /// A batch larger than the remaining eval allowance consumes exactly
    /// the prefix, and the gather itself is capped (no wasted lookups).
    #[test]
    fn eval_batch_truncates_on_eval_budget() {
        let mut r = sim_runner_with_invalids();
        let mut t = Tuning::new(&mut r, Budget::evals(5));
        let batch: Vec<usize> = (0..9).map(|i| i * 2).collect();
        let vals = t.eval_batch(&batch).to_vec();
        assert_eq!(vals.len(), 5);
        assert!(t.done());
        assert!(t.eval_batch(&[1, 3]).is_empty(), "done batch is a no-op");
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 5);
        assert_eq!(trace.points.len(), 5);
        assert_eq!(r.lookups, 5, "gather must be capped at the allowance");
    }

    /// When the proposal cap cuts a batch, configs past the cut were
    /// gathered optimistically but never consumed: their seen-bits must
    /// roll back so a later direct `eval` treats them as fresh, exactly
    /// as the scalar loop (which never saw them) would.
    #[test]
    fn eval_batch_rolls_back_unconsumed_seen_bits() {
        let mut r = sim_runner_with_invalids();
        let mut t = Tuning::new(&mut r, Budget::evals(100).with_proposal_cap(3));
        let vals = t.eval_batch(&[0, 3, 6, 12]).to_vec();
        assert_eq!(vals.len(), 3);
        t.eval(12);
        let trace = t.finish();
        assert_eq!(trace.points.len(), 4);
        assert!(
            !trace.points[3].cached,
            "rolled-back config must evaluate fresh"
        );
        assert_eq!(trace.unique_evals, 4);
    }

    /// A simulated-clock budget expiring mid-batch truncates at exactly
    /// the same proposal as the scalar loop, bit for bit.
    #[test]
    fn eval_batch_time_budget_truncates_like_scalar() {
        let mut rb = sim_runner_with_invalids();
        let mut rs = sim_runner_with_invalids();
        let mut tb = Tuning::new(&mut rb, Budget::seconds(3.5));
        let mut ts = Tuning::new(&mut rs, Budget::seconds(3.5));
        ts.set_scalar_batch_fallback(true);
        let batch: Vec<usize> = (0..10).collect();
        let vb = tb.eval_batch(&batch).to_vec();
        let vs = ts.eval_batch(&batch).to_vec();
        assert_eq!(vb.len(), vs.len());
        assert!(vb.len() < batch.len(), "budget must truncate mid-batch");
        assert_traces_bitwise(&tb.finish(), &ts.finish());
    }

    /// The thread-local pool hands back the same buffers across calls and
    /// survives (falls back) under re-entrant use.
    #[test]
    fn with_pooled_reuses_and_handles_reentrancy() {
        let cap0 = TuningScratch::with_pooled(|s| {
            s.points.reserve(1024);
            s.points.capacity()
        });
        let cap1 = TuningScratch::with_pooled(|s| s.points.capacity());
        assert!(cap1 >= cap0, "pooled capacity must persist");
        // Nested use on the same thread gets a fresh scratch, not a panic.
        TuningScratch::with_pooled(|outer| {
            outer.points.clear();
            TuningScratch::with_pooled(|inner| {
                assert_eq!(inner.points.capacity(), 0, "nested call is unpooled");
            });
        });
    }
}
