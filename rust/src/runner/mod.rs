//! Runners: how an optimization algorithm's configuration evaluations are
//! served.
//!
//! * [`live`] — the "real hardware" path: every evaluation goes through the
//!   PJRT device model, observation noise is drawn, and the simulated
//!   wall-clock advances by compile + run + overhead.
//! * [`sim`] — the paper's **simulation mode**: evaluations are replayed
//!   from a brute-forced cache file; the simulated clock advances exactly
//!   as live tuning would have, but the real cost is a table lookup. From
//!   the optimizer's point of view the two are indistinguishable (asserted
//!   by tests).
//!
//! [`Tuning`] wraps a runner with budget tracking, the within-run
//! configuration cache (revisits cost only framework overhead, as in
//! Kernel Tuner), and the trace recording used by the methodology scoring.
//! Its space-sized working buffers can be pooled across runs through
//! [`TuningScratch`] — a campaign's spaces×repeats jobs reuse one scratch
//! per executor worker instead of allocating and zeroing megabytes per
//! run.

pub mod live;
pub mod sim;

pub use live::LiveRunner;
pub use sim::SimulationRunner;

use crate::searchspace::SearchSpace;

/// Result of evaluating one kernel configuration.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Mean of the observations (objective value, seconds); `INFINITY` for
    /// configurations that failed to launch.
    pub value: f64,
    /// Raw observations (empty for failed configurations).
    pub observations: Vec<f64>,
    /// Simulated seconds spent compiling this configuration.
    pub compile_time: f64,
    /// Simulated seconds spent executing all observations.
    pub run_time: f64,
    /// Simulated framework overhead.
    pub overhead: f64,
    /// Whether the configuration launched successfully.
    pub valid: bool,
}

impl EvalResult {
    pub fn total_cost(&self) -> f64 {
        self.compile_time + self.run_time + self.overhead
    }
}

/// Serves configuration evaluations for one (kernel, device) search space.
pub trait Runner: Send {
    fn space(&self) -> &SearchSpace;
    /// Evaluate a configuration by index.
    fn evaluate(&mut self, config_idx: usize) -> EvalResult;
    /// A short label for logs ("gemm@A100 live" etc.).
    fn label(&self) -> String;

    /// Allocation-free fast path for the tuning hot loop: returns
    /// `(value, total_cost)`. Defaults to `evaluate`; the simulation
    /// runner overrides it to skip cloning the observation vector (which
    /// the budget/trace accounting never reads).
    fn evaluate_lite(&mut self, config_idx: usize) -> (f64, f64) {
        let r = self.evaluate(config_idx);
        (r.value, r.total_cost())
    }
}

/// One point in a tuning trace.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    pub config: usize,
    /// Objective value (INFINITY for failures).
    pub value: f64,
    /// Simulated clock *after* this evaluation.
    pub clock: f64,
    /// Whether this evaluation was a cache hit (config revisit).
    pub cached: bool,
}

/// The record of one tuning run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
    /// Total simulated seconds consumed.
    pub elapsed: f64,
    /// Number of *unique* configurations evaluated.
    pub unique_evals: usize,
}

impl Trace {
    /// Best (lowest) objective value at or before simulated time `t`,
    /// or None if nothing valid was found by then.
    pub fn best_at(&self, t: f64) -> Option<f64> {
        let mut best = f64::INFINITY;
        for p in &self.points {
            if p.clock > t {
                break;
            }
            if p.value < best {
                best = p.value;
            }
        }
        if best.is_finite() {
            Some(best)
        } else {
            None
        }
    }

    /// Final best value.
    pub fn best(&self) -> Option<f64> {
        let b = self
            .points
            .iter()
            .map(|p| p.value)
            .fold(f64::INFINITY, f64::min);
        if b.is_finite() {
            Some(b)
        } else {
            None
        }
    }
}

/// Budget limits for one tuning run.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    /// Maximum simulated seconds.
    pub max_seconds: f64,
    /// Maximum unique configuration evaluations (usize::MAX = unlimited).
    pub max_unique_evals: usize,
    /// Maximum total proposals including cache hits. Guards against
    /// schedule-heavy optimizers spinning on (nearly free) revisits far
    /// past anything a real tuning run would do.
    pub max_proposals: usize,
}

impl Budget {
    pub fn seconds(s: f64) -> Budget {
        Budget {
            max_seconds: s,
            max_unique_evals: usize::MAX,
            max_proposals: usize::MAX,
        }
    }

    pub fn evals(n: usize) -> Budget {
        Budget {
            max_seconds: f64::INFINITY,
            max_unique_evals: n,
            max_proposals: usize::MAX,
        }
    }

    /// Cap total proposals (unique + cached).
    pub fn with_proposal_cap(mut self, cap: usize) -> Budget {
        self.max_proposals = cap;
        self
    }
}

/// Reusable per-run working memory for [`Tuning`]: the seen-bitset, the
/// directly indexed value cache, and the trace-point vector. A fresh
/// `Tuning` allocates (and zeroes) all three per run — megabytes per
/// (space, repeat) job on the big spaces. Pooling one scratch per
/// executor worker turns that into: re-zero the bitset (64× smaller than
/// the value cache, which needs no zeroing — reads are gated by the
/// bitset) and clear the point vector in place.
#[derive(Default)]
pub struct TuningScratch {
    seen: Vec<u64>,
    cached_values: Vec<f64>,
    points: Vec<TracePoint>,
}

impl TuningScratch {
    pub fn new() -> TuningScratch {
        TuningScratch::default()
    }

    /// Reset for a run over `space_len` configurations: zero the bitset
    /// words, grow (never shrink) the value cache without zeroing, clear
    /// the points keeping their capacity.
    fn reset(&mut self, space_len: usize) {
        self.seen.clear();
        self.seen.resize((space_len + 63) / 64, 0);
        if self.cached_values.len() < space_len {
            self.cached_values.resize(space_len, 0.0);
        }
        self.points.clear();
    }

    /// Run `f` with this thread's pooled scratch. Executor workers are
    /// persistent threads, so this is one scratch per worker slot for the
    /// process lifetime — exactly the reuse `Campaign::run` wants. Falls
    /// back to a fresh scratch on re-entrant use (a nested tuning run on
    /// the same thread), which stays correct, just unpooled.
    pub fn with_pooled<R>(f: impl FnOnce(&mut TuningScratch) -> R) -> R {
        thread_local! {
            static POOLED: std::cell::RefCell<TuningScratch> =
                std::cell::RefCell::new(TuningScratch::new());
        }
        POOLED.with(|cell| match cell.try_borrow_mut() {
            Ok(mut scratch) => f(&mut scratch),
            Err(_) => f(&mut TuningScratch::new()),
        })
    }
}

/// The run's working buffers: owned by this `Tuning` (the standalone
/// constructor) or borrowed from a pooled [`TuningScratch`].
enum Scratch<'a> {
    Owned(TuningScratch),
    Borrowed(&'a mut TuningScratch),
}

impl Scratch<'_> {
    #[inline]
    fn get(&mut self) -> &mut TuningScratch {
        match self {
            Scratch::Owned(s) => s,
            Scratch::Borrowed(s) => s,
        }
    }
}

/// A budget-tracked tuning session over a runner: the interface the
/// optimizers program against.
pub struct Tuning<'a> {
    runner: &'a mut dyn Runner,
    budget: Budget,
    /// Simulated seconds consumed so far.
    elapsed: f64,
    /// Unique configurations evaluated so far.
    unique_evals: usize,
    /// Total proposals including cache hits (== recorded trace points).
    proposals: usize,
    /// Running best value — kept current in `eval`, so `best_value` is
    /// O(1) instead of a full trace scan per optimizer iteration.
    best: f64,
    /// Within-run evaluation cache, directly indexed by config index:
    /// `scratch.cached_values[i]` is meaningful iff bit `i` of
    /// `scratch.seen` is set. No hashing on the revisit path — one bit
    /// test and one array read.
    scratch: Scratch<'a>,
    /// Framework overhead charged on cache hits.
    cached_overhead: f64,
    /// Size of the search space (tuning is done once it is exhausted).
    space_len: usize,
}

impl<'a> Tuning<'a> {
    pub fn new(runner: &'a mut dyn Runner, budget: Budget) -> Tuning<'a> {
        Tuning::build(runner, budget, None)
    }

    /// Like [`Tuning::new`], but running on borrowed scratch buffers —
    /// see [`TuningScratch`]. The scratch is reset here; its contents
    /// after [`finish`](Tuning::finish) are unspecified.
    pub fn with_scratch(
        runner: &'a mut dyn Runner,
        budget: Budget,
        scratch: &'a mut TuningScratch,
    ) -> Tuning<'a> {
        Tuning::build(runner, budget, Some(scratch))
    }

    fn build(
        runner: &'a mut dyn Runner,
        budget: Budget,
        scratch: Option<&'a mut TuningScratch>,
    ) -> Tuning<'a> {
        let space_len = runner.space().len();
        let mut scratch = match scratch {
            Some(s) => Scratch::Borrowed(s),
            None => Scratch::Owned(TuningScratch::new()),
        };
        scratch.get().reset(space_len);
        Tuning {
            runner,
            budget,
            elapsed: 0.0,
            unique_evals: 0,
            proposals: 0,
            best: f64::INFINITY,
            scratch,
            // Kernel Tuner semantics: a cache hit returns instantly and
            // consumes no tuning time. Runaway revisit loops are bounded
            // by Budget::max_proposals and the space-exhaustion check.
            cached_overhead: 0.0,
            space_len,
        }
    }

    pub fn space(&self) -> &SearchSpace {
        self.runner.space()
    }

    /// True once the budget is exhausted; optimizers must stop evaluating.
    /// Also true once every configuration has been evaluated: with free
    /// cache hits there is nothing left to learn (and an eval-count budget
    /// larger than the space could otherwise never be reached).
    pub fn done(&self) -> bool {
        self.elapsed >= self.budget.max_seconds
            || self.unique_evals >= self.budget.max_unique_evals
            || self.proposals >= self.budget.max_proposals
            || self.unique_evals >= self.space_len
    }

    /// Remaining simulated seconds.
    pub fn remaining(&self) -> f64 {
        (self.budget.max_seconds - self.elapsed).max(0.0)
    }

    /// Simulated seconds consumed so far.
    pub fn elapsed(&self) -> f64 {
        self.elapsed
    }

    /// Evaluate a configuration; INFINITY for failed configs. The
    /// simulated clock advances accordingly.
    pub fn eval(&mut self, config_idx: usize) -> f64 {
        let Tuning {
            runner,
            scratch,
            elapsed,
            unique_evals,
            proposals,
            best,
            cached_overhead,
            ..
        } = self;
        let s = scratch.get();
        let (word, bit) = (config_idx >> 6, 1u64 << (config_idx & 63));
        if s.seen[word] & bit != 0 {
            // Revisit: the value already went through the running-best
            // fold when first evaluated.
            let v = s.cached_values[config_idx];
            *elapsed += *cached_overhead;
            *proposals += 1;
            s.points.push(TracePoint {
                config: config_idx,
                value: v,
                clock: *elapsed,
                cached: true,
            });
            return v;
        }
        let (value, cost) = runner.evaluate_lite(config_idx);
        *elapsed += cost;
        *unique_evals += 1;
        *proposals += 1;
        s.seen[word] |= bit;
        s.cached_values[config_idx] = value;
        if value < *best {
            *best = value;
        }
        s.points.push(TracePoint {
            config: config_idx,
            value,
            clock: *elapsed,
            cached: false,
        });
        value
    }

    /// Current best value (INFINITY if nothing valid yet). O(1): the
    /// running best maintained by `eval`.
    pub fn best_value(&self) -> f64 {
        self.best
    }

    /// Finish and return the trace. Owned scratch gives up its point
    /// vector; borrowed (pooled) scratch is copied out exact-size so the
    /// pool keeps its capacity for the next run.
    pub fn finish(self) -> Trace {
        let Tuning {
            scratch,
            elapsed,
            unique_evals,
            ..
        } = self;
        let points = match scratch {
            Scratch::Owned(s) => s.points,
            Scratch::Borrowed(s) => s.points.clone(),
        };
        Trace {
            points,
            elapsed,
            unique_evals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runtime::Engine;
    use std::sync::Arc;

    fn live_runner() -> LiveRunner {
        let kernel = kernels::kernel_by_name("synthetic").unwrap();
        LiveRunner::new(
            kernel,
            &A100,
            Arc::new(Engine::native()),
            NoiseModel::default(),
            42,
        )
    }

    #[test]
    fn budget_stops_tuning() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(5));
        let mut i = 0;
        while !t.done() {
            t.eval(i % 10);
            i += 1;
        }
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 5);
    }

    #[test]
    fn revisits_are_cached() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(100));
        let v1 = t.eval(3);
        let clock1 = t.elapsed();
        let v2 = t.eval(3);
        let clock2 = t.elapsed();
        assert_eq!(v1, v2);
        assert!(clock2 - clock1 < 0.01, "cache hit must be ~free");
        let trace = t.finish();
        assert_eq!(trace.unique_evals, 1);
        assert!(trace.points[1].cached);
    }

    #[test]
    fn best_at_respects_time() {
        let mut r = live_runner();
        let mut t = Tuning::new(&mut r, Budget::evals(10));
        for i in 0..10 {
            t.eval(i);
        }
        let trace = t.finish();
        assert!(trace.best_at(0.0).is_none());
        let best_end = trace.best_at(trace.elapsed).unwrap();
        assert_eq!(Some(best_end), trace.best());
        // best is monotone over time
        let mut prev = f64::INFINITY;
        for k in 1..=10 {
            let t_k = trace.elapsed * k as f64 / 10.0;
            if let Some(b) = trace.best_at(t_k) {
                assert!(b <= prev + 1e-12);
                prev = b;
            }
        }
    }

    #[test]
    fn time_budget_stops() {
        let mut r = live_runner();
        // Tiny time budget: a single eval (compile ~seconds) exceeds it.
        let mut t = Tuning::new(&mut r, Budget::seconds(0.5));
        t.eval(0);
        assert!(t.done());
    }

    /// A synthetic-space sim runner over a hand-built cache with a known
    /// value landscape including invalid (INFINITY) configurations.
    fn sim_runner_with_invalids() -> SimulationRunner {
        let space = crate::kernels::kernel_by_name("synthetic")
            .unwrap()
            .space_arc();
        let records: Vec<crate::dataset::cache::ConfigRecord> = (0..space.len())
            .map(|i| {
                let valid = i % 3 != 1;
                let v = if valid {
                    2.0 + ((i as f64) * 0.61).sin()
                } else {
                    f64::INFINITY
                };
                crate::dataset::cache::ConfigRecord {
                    key: space.key(i),
                    value: v,
                    observations: if valid { vec![v] } else { vec![] },
                    compile_time: 1.0 + (i % 5) as f64 * 0.25,
                    valid,
                }
            })
            .collect();
        let cache = Arc::new(crate::dataset::cache::CacheData::new(
            "synthetic",
            "x",
            "",
            0,
            1,
            0.0,
            vec!["a".into()],
            records,
        ));
        SimulationRunner::new_unchecked(space, cache)
    }

    /// The O(1) running best must track `trace.best()` through
    /// interleaved uncached, cached, and invalid evaluations.
    #[test]
    fn running_best_matches_trace_best() {
        let mut r = sim_runner_with_invalids();
        let n = r.space().len();
        let mut t = Tuning::new(&mut r, Budget::evals(usize::MAX));
        // Mix fresh indices, revisits, and invalid configs (idx % 3 == 1).
        let invalid_slots = ((n - 2) / 3).max(1);
        let seq: Vec<usize> = (0..60)
            .map(|i| match i % 4 {
                0 => (i * 7) % n,                   // fresh-ish walk
                1 => (i * 7) % n,                   // immediate revisit (cached)
                2 => 1 + 3 * (i % invalid_slots),   // guaranteed invalid config
                _ => seq_prev(i, n),                // revisit an earlier index
            })
            .collect();
        let mut expected = f64::INFINITY;
        for &i in &seq {
            let v = t.eval(i);
            if v < expected {
                expected = v;
            }
            assert_eq!(
                t.best_value().to_bits(),
                expected.to_bits(),
                "running best drifted at config {i}"
            );
        }
        let best = t.best_value();
        let trace = t.finish();
        assert_eq!(best, trace.best().unwrap_or(f64::INFINITY));
    }

    fn seq_prev(i: usize, n: usize) -> usize {
        (i.saturating_sub(4) * 7) % n
    }

    /// One pooled scratch reused across runs must replay bit-identically
    /// to fresh per-run allocation, run after run.
    #[test]
    fn pooled_scratch_is_bit_identical_to_fresh_alloc() {
        let mut scratch = TuningScratch::new();
        for seed in 0..4usize {
            let seq: Vec<usize> = (0..40).map(|i| (i * (seed + 3)) % 20).collect();
            let run = |t: &mut Tuning| {
                for &i in &seq {
                    t.eval(i);
                }
            };
            let mut r1 = sim_runner_with_invalids();
            let mut fresh = Tuning::new(&mut r1, Budget::evals(1000));
            run(&mut fresh);
            let fresh = fresh.finish();
            let mut r2 = sim_runner_with_invalids();
            let mut pooled = Tuning::with_scratch(&mut r2, Budget::evals(1000), &mut scratch);
            run(&mut pooled);
            let pooled = pooled.finish();
            assert_eq!(fresh.points.len(), pooled.points.len());
            assert_eq!(fresh.unique_evals, pooled.unique_evals);
            assert_eq!(fresh.elapsed.to_bits(), pooled.elapsed.to_bits());
            for (a, b) in fresh.points.iter().zip(&pooled.points) {
                assert_eq!(a.config, b.config);
                assert_eq!(a.value.to_bits(), b.value.to_bits());
                assert_eq!(a.clock.to_bits(), b.clock.to_bits());
                assert_eq!(a.cached, b.cached);
            }
        }
    }

    /// The thread-local pool hands back the same buffers across calls and
    /// survives (falls back) under re-entrant use.
    #[test]
    fn with_pooled_reuses_and_handles_reentrancy() {
        let cap0 = TuningScratch::with_pooled(|s| {
            s.points.reserve(1024);
            s.points.capacity()
        });
        let cap1 = TuningScratch::with_pooled(|s| s.points.capacity());
        assert!(cap1 >= cap0, "pooled capacity must persist");
        // Nested use on the same thread gets a fresh scratch, not a panic.
        TuningScratch::with_pooled(|outer| {
            outer.points.clear();
            TuningScratch::with_pooled(|inner| {
                assert_eq!(inner.points.capacity(), 0, "nested call is unpooled");
            });
        });
    }
}
