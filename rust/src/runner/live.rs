//! Live runner: evaluations through the (simulated) hardware.
//!
//! Every call runs the device model through the evaluation [`Engine`]
//! (PJRT or native oracle), draws the 32-observation noise vector, and
//! charges the simulated wall-clock for compile + run + overhead — the
//! costs the paper's Fig. 9 compares against simulation mode.

use super::{EvalResult, Runner};
use crate::gpu::DeviceModel;
use crate::kernels::{str_seed, Kernel};
use crate::perfmodel::analytical::Features;
use crate::perfmodel::contract::{INVALID_TIME, NUM_DEVICE};
use crate::perfmodel::noise::{NoiseModel, OBSERVATIONS};
use crate::runtime::Engine;
use crate::searchspace::SearchSpace;
use crate::util::rng::{mix64, Rng};
use crate::util::stats;
use std::sync::Arc;

/// Fixed framework overhead per evaluation (scheduling, codegen prep).
pub const FRAMEWORK_OVERHEAD: f64 = 0.05;

/// The live (hardware-in-the-loop) runner.
pub struct LiveRunner {
    kernel: Kernel,
    device_vec: [f32; NUM_DEVICE],
    device_name: String,
    engine: Arc<Engine>,
    noise: NoiseModel,
    /// Seed tying the noise stream to this (kernel, device) space.
    pub space_seed: u64,
    /// Number of observations per evaluation.
    pub observations: usize,
    /// Pre-extracted features (configs are evaluated repeatedly).
    features: Vec<Features>,
}

impl LiveRunner {
    pub fn new(
        kernel: Kernel,
        device: &DeviceModel,
        engine: Arc<Engine>,
        noise: NoiseModel,
        seed: u64,
    ) -> LiveRunner {
        let features = kernel.all_features();
        let space_seed = mix64(seed, mix64(str_seed(kernel.name), str_seed(device.name)));
        LiveRunner {
            kernel,
            device_vec: device.to_vector(),
            device_name: device.name.to_string(),
            engine,
            noise,
            space_seed,
            observations: OBSERVATIONS,
            features,
        }
    }

    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Deterministic per-config compile time in seconds (1–10 s), the
    /// dominant cost of evaluating a configuration on real hardware.
    pub fn compile_time(&self, config_idx: usize) -> f64 {
        let mut rng = Rng::new(mix64(self.space_seed ^ 0xC0DE, config_idx as u64));
        rng.range_f64(1.0, 10.0)
    }

    /// Evaluate a batch of configurations (used by the brute-forcer to
    /// amortize PJRT dispatch); returns results in order.
    pub fn evaluate_batch(&mut self, config_idxs: &[usize]) -> Vec<EvalResult> {
        let feats: Vec<Features> = config_idxs.iter().map(|&i| self.features[i]).collect();
        let ms = self
            .engine
            .measure(&feats, &self.device_vec)
            // lint: allow(W03, reason = "engine failure is fatal on the live path")
            .expect("engine evaluation failed");
        config_idxs
            .iter()
            .zip(ms)
            .map(|(&idx, m)| {
                let compile_time = self.compile_time(idx);
                if m.time >= INVALID_TIME {
                    return EvalResult {
                        value: f64::INFINITY,
                        observations: Vec::new(),
                        compile_time,
                        run_time: 0.0,
                        overhead: FRAMEWORK_OVERHEAD,
                        valid: false,
                    };
                }
                let obs = self.noise.observations(
                    self.space_seed,
                    idx,
                    m.time as f64,
                    m.t_cold as f64,
                    m.t_hot as f64,
                    self.observations,
                );
                let run_time: f64 = obs.iter().sum();
                EvalResult {
                    value: stats::mean(&obs),
                    observations: obs,
                    compile_time,
                    run_time,
                    overhead: FRAMEWORK_OVERHEAD,
                    valid: true,
                }
            })
            .collect()
    }
}

impl Runner for LiveRunner {
    fn space(&self) -> &SearchSpace {
        self.kernel.space()
    }

    fn evaluate(&mut self, config_idx: usize) -> EvalResult {
        // lint: allow(W03, reason = "a one-element batch yields one result")
        self.evaluate_batch(&[config_idx]).pop().unwrap()
    }

    fn label(&self) -> String {
        format!("{}@{} live", self.kernel.name, self.device_name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::{A100, W6600};
    use crate::kernels;

    fn runner(seed: u64) -> LiveRunner {
        LiveRunner::new(
            kernels::kernel_by_name("synthetic").unwrap(),
            &A100,
            Arc::new(Engine::native()),
            NoiseModel::default(),
            seed,
        )
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = runner(7);
        let mut b = runner(7);
        for i in [0usize, 5, 17] {
            let ra = a.evaluate(i);
            let rb = b.evaluate(i);
            assert_eq!(ra.value, rb.value);
            assert_eq!(ra.observations, rb.observations);
            assert_eq!(ra.compile_time, rb.compile_time);
        }
    }

    #[test]
    fn observation_count_and_mean() {
        let mut r = runner(3);
        let res = r.evaluate(0);
        assert!(res.valid);
        assert_eq!(res.observations.len(), OBSERVATIONS);
        let m = stats::mean(&res.observations);
        assert!((m - res.value).abs() < 1e-12);
        assert!(res.run_time > 0.0);
        assert!(res.compile_time >= 1.0 && res.compile_time <= 10.0);
    }

    #[test]
    fn different_devices_different_values() {
        let k1 = kernels::kernel_by_name("synthetic").unwrap();
        let k2 = kernels::kernel_by_name("synthetic").unwrap();
        let e = Arc::new(Engine::native());
        let mut a = LiveRunner::new(k1, &A100, e.clone(), NoiseModel::default(), 7);
        let mut b = LiveRunner::new(k2, &W6600, e, NoiseModel::default(), 7);
        assert_ne!(a.evaluate(0).value, b.evaluate(0).value);
    }

    #[test]
    fn batch_matches_sequential() {
        let mut a = runner(9);
        let mut b = runner(9);
        let idxs = [0usize, 3, 9, 3];
        let batch = a.evaluate_batch(&idxs);
        for (&i, r) in idxs.iter().zip(&batch) {
            assert_eq!(b.evaluate(i).value, r.value);
        }
    }
}
