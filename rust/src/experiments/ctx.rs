//! Shared experiment context: hub, engine, scale profile, memoized
//! intermediates.

use crate::campaign::{NullObserver, Observer};
use crate::dataset::hub::{Hub, HUB_KERNELS, HUB_SEED};
use crate::error::Result;
use crate::gpu::specs::{TEST_DEVICES, TRAIN_DEVICES};
use crate::hypertuning::{self, exhaustive, meta, sweep};
use crate::kernels;
use crate::methodology::{self, SpaceEval};
use crate::optimizers::{self, HyperParams};
use crate::report::Report;
use crate::runner::{Budget, Tuning};
use crate::runtime::Engine;
use crate::util::hash::FastMap;
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Scale profile: "quick" for minutes-scale regeneration, "paper" for the
/// full-size runs recorded in EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scale {
    /// Repeats during hyperparameter tuning (paper: 25).
    pub tuning_repeats: usize,
    /// Repeats for re-evaluation comparisons (paper: 100).
    pub eval_repeats: usize,
    /// Sampling points per performance curve (paper-style: 50).
    pub points: usize,
    /// Hyperparameter evaluations for extended meta-tuning (stands in for
    /// the paper's 7-day budget).
    pub meta_evals: usize,
}

impl Scale {
    pub fn parse(name: &str) -> Result<Scale> {
        Ok(match name {
            "quick" => Scale {
                tuning_repeats: 5,
                eval_repeats: 20,
                points: 30,
                meta_evals: 40,
            },
            "paper" => Scale {
                tuning_repeats: methodology::TUNING_REPEATS,
                eval_repeats: methodology::EVAL_REPEATS,
                points: methodology::DEFAULT_POINTS,
                meta_evals: 150,
            },
            other => crate::bail!("unknown scale {other:?} (quick|paper)"),
        })
    }
}

/// Shared context for experiment runs.
pub struct Ctx {
    pub hub: Hub,
    pub engine: Arc<Engine>,
    pub results_dir: PathBuf,
    pub scale: Scale,
    pub scale_name: String,
    pub seed: u64,
    /// Campaign progress observer attached to every hypertuning run this
    /// context launches (the CLI installs a progress logger; batch runs
    /// keep the no-op default).
    observer: Arc<dyn Observer>,
    /// Explicit fault plan injected into the sweep/metasweep campaigns
    /// this context launches (the CLI wires `--inject-faults` /
    /// `TUNETUNER_FAULTS` here; batch runs keep `None`).
    faults: Option<Arc<crate::faults::FaultPlan>>,
    spaces: Mutex<FastMap<String, Arc<Vec<SpaceEval>>>>,
    hyper: Mutex<FastMap<String, Arc<exhaustive::HyperTuningResults>>>,
}

impl Ctx {
    pub fn new(
        hub: Hub,
        engine: Arc<Engine>,
        results_dir: PathBuf,
        scale: Scale,
        scale_name: &str,
        seed: u64,
    ) -> Ctx {
        std::fs::create_dir_all(&results_dir).ok();
        Ctx {
            hub,
            engine,
            results_dir,
            scale,
            scale_name: scale_name.to_string(),
            seed,
            observer: Arc::new(NullObserver),
            faults: None,
            spaces: Mutex::new(FastMap::default()),
            hyper: Mutex::new(FastMap::default()),
        }
    }

    /// Attach a campaign observer to the hypertuning runs this context
    /// launches.
    pub fn with_observer(mut self, observer: Arc<dyn Observer>) -> Ctx {
        self.observer = observer;
        self
    }

    /// Inject a deterministic fault plan into the sweep/metasweep
    /// campaigns this context launches (chaos testing).
    pub fn with_faults(mut self, faults: Option<Arc<crate::faults::FaultPlan>>) -> Ctx {
        self.faults = faults;
        self
    }

    pub fn report(&self, id: &str) -> Report {
        Report::new(&self.results_dir, id)
    }

    /// Ensure the full 24-space hub exists (built through the engine).
    pub fn ensure_hub(&self) -> Result<Vec<(String, String, f64)>> {
        self.hub.ensure_all(Arc::clone(&self.engine), HUB_SEED)
    }

    fn spaces_for(&self, devices: &[&str], tag: &str) -> Result<Arc<Vec<SpaceEval>>> {
        if let Some(s) = self.spaces.lock().unwrap().get(tag) {
            return Ok(Arc::clone(s));
        }
        self.ensure_hub()?;
        let mut out = Vec::new();
        for kname in HUB_KERNELS {
            let kernel = kernels::kernel_by_name(kname)?;
            for dev in devices {
                // Memoize per (kernel, device): train/test/all share them.
                // NB: take the Option out and drop the guard before the
                // miss path re-locks (std Mutex is not reentrant).
                let key = format!("one:{kname}@{dev}");
                let hit = self.spaces.lock().unwrap().get(&key).cloned();
                let se = match hit {
                    Some(s) => s[0].clone(),
                    None => {
                        let cache = self.hub.load(kname, dev)?;
                        let se = SpaceEval::new(
                            kernel.space_arc(),
                            cache,
                            methodology::DEFAULT_CUTOFF,
                            self.scale.points,
                        );
                        self.spaces
                            .lock()
                            .unwrap()
                            .insert(key, Arc::new(vec![se.clone()]));
                        se
                    }
                };
                out.push(se);
            }
        }
        let arc = Arc::new(out);
        self.spaces
            .lock()
            .unwrap()
            .insert(tag.to_string(), Arc::clone(&arc));
        Ok(arc)
    }

    /// The 12 training spaces (4 kernels × {MI250X, A100, A4000}).
    pub fn train_spaces(&self) -> Result<Arc<Vec<SpaceEval>>> {
        self.spaces_for(&TRAIN_DEVICES, "train")
    }

    /// The 12 held-out test spaces (4 kernels × {W6600, W7800, A6000}).
    pub fn test_spaces(&self) -> Result<Arc<Vec<SpaceEval>>> {
        self.spaces_for(&TEST_DEVICES, "test")
    }

    /// All 24 spaces (train then test order).
    pub fn all_spaces(&self) -> Result<Arc<Vec<SpaceEval>>> {
        let devices: Vec<&str> = TRAIN_DEVICES
            .iter()
            .chain(TEST_DEVICES.iter())
            .copied()
            .collect();
        self.spaces_for(&devices, "all")
    }

    /// Exhaustive limited hypertuning results for an algorithm at the
    /// scale's tuning repeats, loaded from the results dir when present,
    /// else computed and persisted.
    pub fn limited_results(&self, algo: &str) -> Result<Arc<exhaustive::HyperTuningResults>> {
        self.limited_results_at(algo, self.scale.tuning_repeats)
    }

    /// [`Ctx::limited_results`] at an explicit repeat count (`tunetuner
    /// sweep --repeats`). Off-scale repeat counts persist under a
    /// repeats-tagged filename so they never shadow the scale's own
    /// results.
    pub fn limited_results_at(
        &self,
        algo: &str,
        repeats: usize,
    ) -> Result<Arc<exhaustive::HyperTuningResults>> {
        let key = format!("{algo}-limited-r{repeats}");
        if let Some(r) = self.hyper.lock().unwrap().get(&key) {
            return Ok(Arc::clone(r));
        }
        let path = self.results_dir.join(format!(
            "hypertuning_{algo}_limited_{}{}.json.gz",
            self.scale_name,
            self.repeats_suffix(repeats)
        ));
        let hp_space = hypertuning::limited_space(algo)?;
        let results = if let Some(r) = load_if_current(&path, &hp_space, repeats)? {
            r
        } else {
            let train = self.train_spaces()?;
            crate::log_info!(
                "exhaustive hypertuning {algo}: {} configs x {} spaces x {} repeats",
                hp_space.len(),
                train.len(),
                repeats
            );
            let r = exhaustive::exhaustive_tuning_observed(
                algo,
                &hp_space,
                "limited",
                &train,
                repeats,
                self.seed,
                Arc::clone(&self.observer),
            )?;
            r.save(&path)?;
            r
        };
        let arc = Arc::new(results);
        self.hyper.lock().unwrap().insert(key, Arc::clone(&arc));
        Ok(arc)
    }

    /// Extended hypertuning via a dual-annealing meta-strategy (Table IV),
    /// persisted like the limited campaigns.
    pub fn extended_results(&self, algo: &str) -> Result<Arc<exhaustive::HyperTuningResults>> {
        let key = format!("{algo}-extended");
        if let Some(r) = self.hyper.lock().unwrap().get(&key) {
            return Ok(Arc::clone(r));
        }
        let path = self
            .results_dir
            .join(format!("hypertuning_{algo}_extended_{}.json.gz", self.scale_name));
        let hp_space = Arc::new(hypertuning::extended_space(algo)?);
        let results = if let Some(r) = load_if_current(&path, &hp_space, self.scale.tuning_repeats)?
        {
            r
        } else {
            let train = self.train_spaces()?;
            crate::log_info!(
                "extended meta-tuning {algo}: {} configs, budget {} evaluations",
                hp_space.len(),
                self.scale.meta_evals
            );
            // lint: allow(W01, reason = "elapsed-time telemetry; never feeds tuning decisions")
            let t0 = std::time::Instant::now();
            let mut runner = meta::MetaRunner::new(
                algo,
                Arc::clone(&hp_space),
                train.as_ref().clone(),
                self.scale.tuning_repeats,
                self.seed,
            )
            .with_observer(Arc::clone(&self.observer));
            let mut tuning = Tuning::new(&mut runner, Budget::evals(self.scale.meta_evals));
            let opt = optimizers::create("dual_annealing", &HyperParams::new())?;
            let mut rng = Rng::new(self.seed ^ 0xE0E0);
            opt.run(&mut tuning, &mut rng);
            drop(tuning);
            let results: Vec<exhaustive::HyperResult> = runner
                .history
                .iter()
                .map(|&(idx, score)| exhaustive::HyperResult {
                    config_idx: idx,
                    hp_key: HyperParams::from_space_config(&hp_space, idx).key(),
                    score,
                })
                .collect();
            let train_budget: f64 = train.iter().map(|s| s.budget_seconds).sum();
            let r = exhaustive::HyperTuningResults {
                algo: algo.to_string(),
                space_kind: "extended".into(),
                space_key: exhaustive::space_fingerprint(&hp_space),
                repeats: self.scale.tuning_repeats,
                seed: self.seed,
                simulated_seconds: train_budget
                    * self.scale.tuning_repeats as f64
                    * results.len() as f64,
                results,
                wallclock_seconds: t0.elapsed().as_secs_f64(),
            };
            r.save(&path)?;
            r
        };
        let arc = Arc::new(results);
        self.hyper.lock().unwrap().insert(key, Arc::clone(&arc));
        Ok(arc)
    }

    /// The full-registry hypertuning sweep (`tunetuner sweep`): every
    /// grid-bearing optimizer hypertuned over the training spaces, the
    /// per-optimizer exhaustive results loaded/persisted through
    /// [`Ctx::limited_results`] (so a sweep resumes from whatever
    /// per-algorithm campaigns already ran at this scale). The assembled
    /// envelope is persisted to the results dir as
    /// `sweep_registry_<scale>.json.gz`.
    pub fn registry_sweep(&self) -> Result<sweep::SweepResult> {
        self.registry_sweep_at(None)
    }

    /// [`Ctx::registry_sweep`] at an explicit repeat count (`tunetuner
    /// sweep --repeats`); `None` uses the scale's tuning repeats.
    /// Off-scale repeat counts persist under a repeats-tagged filename.
    pub fn registry_sweep_at(&self, repeats_override: Option<usize>) -> Result<sweep::SweepResult> {
        let repeats = repeats_override.unwrap_or(self.scale.tuning_repeats);
        let train = self.train_spaces()?;
        let path = self.results_dir.join(format!(
            "sweep_registry_{}{}.json.gz",
            self.scale_name,
            self.repeats_suffix(repeats)
        ));
        // Checkpoint the envelope after every leg: a crash costs at most
        // one optimizer's campaigns (and the per-algorithm results are
        // persisted separately by `limited_results_at` anyway).
        let checkpoint = sweep::Checkpoint::new(path.clone(), 1);
        let result = sweep::sweep_registry_checkpointed(
            &train,
            repeats,
            self.seed,
            Arc::clone(&self.observer),
            Some(&checkpoint),
            self.faults.clone(),
            |algo| self.limited_results_at(algo, repeats),
        )?;
        result.save(&path)?;
        Ok(result)
    }

    /// The metasweep (`tunetuner metasweep`): race the configured
    /// meta-strategies against the exhaustive registry sweep at the same
    /// repeats/seed. The reference sweep is loaded/computed through
    /// [`Ctx::registry_sweep_at`] (resuming from persisted per-algorithm
    /// campaigns); the metasweep itself resumes from a previously
    /// persisted `metasweep_registry_<scale>.json.gz` envelope when its
    /// fingerprints and parameters still match.
    pub fn registry_metasweep(
        &self,
        config: &hypertuning::MetaSweepConfig,
        repeats_override: Option<usize>,
    ) -> Result<hypertuning::MetaSweepResult> {
        let repeats = repeats_override.unwrap_or(self.scale.tuning_repeats);
        let reference = self.registry_sweep_at(repeats_override)?;
        let train = self.train_spaces()?;
        let path = self.results_dir.join(format!(
            "metasweep_registry_{}{}.json.gz",
            self.scale_name,
            self.repeats_suffix(repeats)
        ));
        // A stale/corrupt prior is never fatal: load_tolerant warns and
        // starts fresh, and the driver re-verifies every fingerprint and
        // simply re-runs what doesn't match. The prior doubles as the
        // crash-resume path — the incremental checkpoint below rewrites
        // this same file after every completed leg.
        let prior = hypertuning::MetaSweepResult::load_tolerant(&path);
        let checkpoint = sweep::Checkpoint::new(path.clone(), 1);
        let result = hypertuning::metasweep_registry_checkpointed(
            &train,
            repeats,
            self.seed,
            &reference,
            config,
            prior.as_ref(),
            Some(&checkpoint),
            self.faults.clone(),
            Arc::clone(&self.observer),
        )?;
        result.save(&path)?;
        Ok(result)
    }

    fn repeats_suffix(&self, repeats: usize) -> String {
        if repeats == self.scale.tuning_repeats {
            String::new()
        } else {
            format!("_r{repeats}")
        }
    }
}

/// Load persisted hypertuning results only when their space fingerprint
/// matches the current schema-derived space and their repeat count
/// matches the request. A stale (or pre-fingerprint) file triggers
/// recomputation instead of silently misdecoding its `config_idx`
/// values against a changed grid — or comparing scores averaged over a
/// different number of repeats. A corrupt or truncated file (which
/// [`crate::util::fsio::atomic_write`] makes rare, but a foreign file
/// can still produce) is likewise a warning + recompute, never an
/// abort.
fn load_if_current(
    path: &std::path::Path,
    hp_space: &crate::searchspace::SearchSpace,
    repeats: usize,
) -> Result<Option<exhaustive::HyperTuningResults>> {
    if !path.exists() {
        return Ok(None);
    }
    let r = match exhaustive::HyperTuningResults::load(path) {
        Ok(r) => r,
        Err(e) => {
            crate::log_warn!(
                "ignoring unreadable hypertuning results at {}: {e:#}; recomputing",
                path.display()
            );
            return Ok(None);
        }
    };
    if r.space_key == exhaustive::space_fingerprint(hp_space) && r.repeats == repeats {
        Ok(Some(r))
    } else {
        crate::log_warn!(
            "stale hypertuning results at {} (hyperparameter space or repeats changed); \
             recomputing",
            path.display()
        );
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A truncated artifact (half-written by a kill before fsio existed,
    /// or a foreign file) must read as "recompute", not crash the run.
    #[test]
    fn load_if_current_treats_truncated_files_as_missing() {
        let dir = std::env::temp_dir().join(format!("tt_ctxload_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let space = hypertuning::limited_space("pso").unwrap();
        let absent = dir.join("absent.json.gz");
        assert!(load_if_current(&absent, &space, 5).unwrap().is_none());
        let truncated = dir.join("truncated.json");
        let body = b"{\"schema\": \"tunetuner-hypertuning\", \"res";
        crate::util::fsio::atomic_write(&truncated, body).unwrap();
        assert!(load_if_current(&truncated, &space, 5).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
