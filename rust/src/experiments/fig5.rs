//! Fig. 5: aggregate performance over time — each algorithm with its
//! most-average vs optimal hyperparameter configuration, across all 24
//! search spaces. Produces the paper's headline: the average improvement
//! of the optimal over the average configuration (paper: 94.8%, with
//! per-algorithm deltas 0.170 / 0.192 / 0.473 / 0.149).

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space};
use crate::methodology::evaluate_algorithm;
use crate::optimizers::HyperParams;
use crate::util::plot::Series;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let all = ctx.all_spaces()?;
    let reps = ctx.scale.eval_repeats;
    let mut series = Vec::new();
    let mut summary = String::new();
    let mut deltas = Vec::new();
    let mut pct_improvements = Vec::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let space = limited_space(algo)?;
        let mean_hp =
            HyperParams::from_space_config(&space, results.most_average().config_idx);
        let best_hp = HyperParams::from_space_config(&space, results.best().config_idx);
        let mean_r = evaluate_algorithm(algo, &mean_hp, &all, reps, ctx.seed ^ 0x21)?;
        let best_r = evaluate_algorithm(algo, &best_hp, &all, reps, ctx.seed ^ 0x23)?;
        let frac = |i: usize| (i + 1) as f64 / mean_r.aggregate_curve.len() as f64;
        series.push(Series {
            name: format!("{algo} (mean)"),
            points: mean_r
                .aggregate_curve
                .iter()
                .enumerate()
                .map(|(i, &y)| (frac(i), y))
                .collect(),
        });
        series.push(Series {
            name: format!("{algo} (optimal)"),
            points: best_r
                .aggregate_curve
                .iter()
                .enumerate()
                .map(|(i, &y)| (frac(i), y))
                .collect(),
        });
        let delta = best_r.score - mean_r.score;
        let pct = if mean_r.score.abs() > 1e-9 {
            delta / mean_r.score.abs() * 100.0
        } else {
            delta * 100.0
        };
        deltas.push(delta);
        pct_improvements.push(pct);
        summary.push_str(&format!(
            "{algo}: mean-config score {:.3}, optimal {:.3}, improvement {:+.3} ({pct:+.1}%)\n",
            mean_r.score, best_r.score, delta
        ));
    }
    summary.push_str(&format!(
        "average improvement of optimal over mean configuration: {:.1}% (paper: 94.8%); mean delta {:+.3}\n",
        crate::util::stats::mean(&pct_improvements),
        crate::util::stats::mean(&deltas),
    ));
    let report = ctx.report("fig5");
    report.lines(
        "Fig 5: aggregate performance score over relative budget (mean vs optimal hyperparameters)",
        &series,
    )?;
    report.summary(&summary)?;
    Ok(())
}
