//! Fig. 9: tuning-time comparison between live tuning and simulation mode.
//!
//! Live time is calculated as the paper does: the 95% budget of each
//! training space, times the number of hyperparameter configurations,
//! times the repeats. Simulation time is the *measured* wall-clock of the
//! exhaustive campaigns. The paper's totals: 22 323 hours live vs 172
//! hours simulated, a ~130x speedup.

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space};
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let train = ctx.train_spaces()?;
    let budget_sum: f64 = train.iter().map(|s| s.budget_seconds).sum();
    let mut table = Table::new(
        "Fig 9: hyperparameter tuning time, live (estimated) vs simulation mode (measured)",
        &["Algorithm", "HP configs", "Live (hours)", "Simulated (hours)", "Speedup"],
    );
    let mut live_total = 0.0;
    let mut sim_total = 0.0;
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let n_configs = limited_space(algo)?.len();
        let live_seconds = budget_sum * n_configs as f64 * results.repeats as f64;
        let sim_seconds = results.wallclock_seconds;
        live_total += live_seconds;
        sim_total += sim_seconds;
        table.row(vec![
            algo.to_string(),
            n_configs.to_string(),
            format!("{:.1}", live_seconds / 3600.0),
            format!("{:.3}", sim_seconds / 3600.0),
            format!("{:.0}x", live_seconds / sim_seconds.max(1e-9)),
        ]);
    }
    let report = ctx.report("fig9");
    report.table(&table)?;
    report.summary(&format!(
        "total: live {:.0} hours vs simulated {:.2} hours -> {:.0}x speedup (paper: 22323 vs 172 hours, ~130x)\n",
        live_total / 3600.0,
        sim_total / 3600.0,
        live_total / sim_total.max(1e-9),
    ))?;
    Ok(())
}
