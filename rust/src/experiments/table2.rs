//! Table II: brute-force execution times for each search space.
//!
//! The paper reports wall-clock hours per (application, GPU) brute-force;
//! we report the *simulated device-hours* our live runner charged while
//! brute-forcing each space through the PJRT device model, plus the grand
//! total (paper: ~962 hours).

use super::Ctx;
use crate::dataset::hub::HUB_KERNELS;
use crate::gpu::specs::all_devices;
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    ctx.ensure_hub()?;
    let devices: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
    let header: Vec<&str> = std::iter::once("Application")
        .chain(devices.iter().copied())
        .collect();
    let mut table = Table::new(
        "Table II: brute-force execution times in hours for each search space (simulated device time)",
        &header,
    );
    let mut total = 0.0;
    for kernel in HUB_KERNELS {
        let mut row = vec![capitalize(kernel)];
        for dev in &devices {
            let cache = ctx.hub.load(kernel, dev)?;
            let hours = cache.bruteforce_seconds / 3600.0;
            total += hours;
            row.push(format!("{hours:.1}"));
        }
        table.row(row);
    }
    let report = ctx.report("table2");
    report.table(&table)?;
    report.summary(&format!(
        "total simulated brute-force time: {total:.0} hours (paper: 962 hours)\n"
    ))?;
    Ok(())
}

pub(crate) fn capitalize(s: &str) -> String {
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => String::new(),
    }
}
