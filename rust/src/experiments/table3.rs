//! Table III: the limited hyperparameter spaces with the optimal values
//! (bold in the paper; starred here) and the values closest to the mean
//! (italic in the paper; bracketed here), determined by the exhaustive
//! campaign on the twelve training spaces.

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space};
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(
        "Table III: hyperparameter values; *optimal*, [closest to mean]",
        &["Algorithm", "Hyperparameter", "Values"],
    );
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let space = limited_space(algo)?;
        let best = space.named_values(results.best().config_idx);
        let avg = space.named_values(results.most_average().config_idx);
        for (d, param) in space.params.iter().enumerate() {
            let rendered: Vec<String> = param
                .values
                .iter()
                .map(|v| {
                    let s = v.key();
                    let is_best = best[d].1.key() == s;
                    let is_avg = avg[d].1.key() == s;
                    match (is_best, is_avg) {
                        (true, true) => format!("*[{s}]*"),
                        (true, false) => format!("*{s}*"),
                        (false, true) => format!("[{s}]"),
                        (false, false) => s,
                    }
                })
                .collect();
            table.row(vec![
                algo.to_string(),
                param.name.clone(),
                format!("{{{}}}", rendered.join(", ")),
            ]);
        }
    }
    let report = ctx.report("table3");
    report.table(&table)?;

    let mut lines = String::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        lines.push_str(&format!(
            "{algo}: best score {:.3} ({}), worst {:.3}, mean-config {:.3}; campaign {:.1}s wall-clock\n",
            results.best().score,
            results.best().hp_key,
            results.worst().score,
            results.most_average().score,
            results.wallclock_seconds,
        ));
    }
    report.summary(&lines)?;
    Ok(())
}
