//! Fig. 2: violin plots of the performance-score distribution over all
//! hyperparameter configurations, per optimization algorithm.
//!
//! The paper's headline from this figure: an average best-worst score
//! difference of 0.865, and PSO being far more hyperparameter-sensitive
//! than simulated annealing.

use super::Ctx;
use crate::hypertuning::limited_algos;
use crate::util::stats;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let mut dists: Vec<(String, Vec<f64>)> = Vec::new();
    let mut spread_sum = 0.0;
    let mut summary = String::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let scores = results.scores();
        let spread = stats::max(&scores) - stats::min(&scores);
        spread_sum += spread;
        summary.push_str(&format!(
            "{algo}: n={} mean={:.3} std={:.3} min={:.3} max={:.3} spread={:.3}\n",
            scores.len(),
            stats::mean(&scores),
            stats::stddev(&scores),
            stats::min(&scores),
            stats::max(&scores),
            spread,
        ));
        dists.push((algo.to_string(), scores));
    }
    summary.push_str(&format!(
        "average best-worst difference: {:.3} (paper: 0.865)\n",
        spread_sum / limited_algos().len() as f64
    ));
    let report = ctx.report("fig2");
    report.violins(
        "Fig 2: performance-score distribution per hyperparameter configuration ( | = mean )",
        &dists,
    )?;
    report.summary(&summary)?;
    Ok(())
}
