//! Fig. 4: per-search-space impact of hyperparameter tuning — the
//! suboptimal (worst) vs optimal (best) configuration of each algorithm,
//! scored on all 24 spaces (train + test halves), showing the improvement
//! is general rather than over-fitted to a few spaces.

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space};
use crate::methodology::evaluate_algorithm;
use crate::optimizers::HyperParams;
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let all = ctx.all_spaces()?;
    let reps = ctx.scale.eval_repeats;
    let labels: Vec<String> = all.iter().map(|s| s.label.clone()).collect();
    // Build a wide table: per space, worst and best mean score per algo.
    let mut header: Vec<String> = vec!["Space".into(), "Set".into()];
    for algo in limited_algos() {
        header.push(format!("{algo}:worst"));
        header.push(format!("{algo}:best"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Fig 4: per-space mean score, suboptimal (worst) vs optimal (best) configurations",
        &header_refs,
    );

    let mut per_algo: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let space = limited_space(algo)?;
        let worst_hp = HyperParams::from_space_config(&space, results.worst().config_idx);
        let best_hp = HyperParams::from_space_config(&space, results.best().config_idx);
        let worst = evaluate_algorithm(algo, &worst_hp, &all, reps, ctx.seed ^ 0x11)?;
        let best = evaluate_algorithm(algo, &best_hp, &all, reps, ctx.seed ^ 0x13)?;
        per_algo.push((worst.per_space_means(), best.per_space_means()));
    }
    let mut improved = 0usize;
    let mut cells = 0usize;
    for (s, label) in labels.iter().enumerate() {
        // Train spaces come first (12), then test.
        let set = if s < all.len() / 2 { "train" } else { "test" };
        let mut row = vec![label.clone(), set.to_string()];
        for (worst, best) in &per_algo {
            row.push(format!("{:.3}", worst[s]));
            row.push(format!("{:.3}", best[s]));
            cells += 1;
            if best[s] > worst[s] {
                improved += 1;
            }
        }
        table.row(row);
    }
    let report = ctx.report("fig4");
    report.table(&table)?;
    report.summary(&format!(
        "optimal improves on suboptimal in {improved}/{cells} (algorithm, space) cells\n"
    ))?;
    Ok(())
}
