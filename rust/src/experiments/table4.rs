//! Table IV: the extended hyperparameter spaces with the optimal values
//! found by the dual-annealing meta-strategy (the paper's 7-day campaign;
//! here budget-limited by `--scale`).

use super::Ctx;
use crate::hypertuning::{extended_algos, extended_space};
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let mut table = Table::new(
        "Table IV: extended hyperparameter values; *optimal found by meta-strategy*",
        &["Algorithm", "Hyperparameter", "Range", "Optimal"],
    );
    let mut summary = String::new();
    for algo in extended_algos() {
        let results = ctx.extended_results(algo)?;
        let space = extended_space(algo)?;
        let best = space.named_values(results.best().config_idx);
        for (d, param) in space.params.iter().enumerate() {
            // lint: allow(W03, reason = "param value grids are non-empty by construction")
            let first = param.values.first().unwrap().key();
            // lint: allow(W03, reason = "param value grids are non-empty by construction")
            let last = param.values.last().unwrap().key();
            table.row(vec![
                algo.to_string(),
                param.name.clone(),
                format!("{{{first}, ..., {last}}} ({} values)", param.cardinality()),
                format!("*{}*", best[d].1.key()),
            ]);
        }
        summary.push_str(&format!(
            "{algo}: explored {}/{} configs, best score {:.3} ({})\n",
            results.results.len(),
            space.len(),
            results.best().score,
            results.best().hp_key,
        ));
    }
    let report = ctx.report("table4");
    report.table(&table)?;
    report.summary(&summary)?;
    Ok(())
}
