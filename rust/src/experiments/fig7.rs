//! Fig. 7: per-search-space impact of the *extended* tuning — the
//! most-average configuration of the limited campaign vs the optimal
//! configuration found by the extended meta-strategy campaign, on all 24
//! spaces.

use super::Ctx;
use crate::hypertuning::{extended_algos, extended_space, limited_space};
use crate::methodology::evaluate_algorithm;
use crate::optimizers::HyperParams;
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let all = ctx.all_spaces()?;
    let reps = ctx.scale.eval_repeats;
    let mut header: Vec<String> = vec!["Space".into(), "Set".into()];
    for algo in extended_algos() {
        header.push(format!("{algo}:avg-lim"));
        header.push(format!("{algo}:opt-ext"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Fig 7: per-space mean score, average (limited) vs optimal (extended) configurations",
        &header_refs,
    );
    let mut per_algo = Vec::new();
    for algo in extended_algos() {
        let limited = ctx.limited_results(algo)?;
        let extended = ctx.extended_results(algo)?;
        let lim_space = limited_space(algo)?;
        let ext_space = extended_space(algo)?;
        let avg_hp =
            HyperParams::from_space_config(&lim_space, limited.most_average().config_idx);
        let opt_hp =
            HyperParams::from_space_config(&ext_space, extended.best().config_idx);
        let avg_r = evaluate_algorithm(algo, &avg_hp, &all, reps, ctx.seed ^ 0x41)?;
        let opt_r = evaluate_algorithm(algo, &opt_hp, &all, reps, ctx.seed ^ 0x43)?;
        per_algo.push((avg_r.per_space_means(), opt_r.per_space_means()));
    }
    let mut improved = 0usize;
    let mut cells = 0usize;
    for (s, se) in all.iter().enumerate() {
        let set = if s < all.len() / 2 { "train" } else { "test" };
        let mut row = vec![se.label.clone(), set.to_string()];
        for (avg, opt) in &per_algo {
            row.push(format!("{:.3}", avg[s]));
            row.push(format!("{:.3}", opt[s]));
            cells += 1;
            if opt[s] > avg[s] {
                improved += 1;
            }
        }
        table.row(row);
    }
    let report = ctx.report("fig7");
    report.table(&table)?;
    report.summary(&format!(
        "extended-optimal improves on limited-average in {improved}/{cells} (algorithm, space) cells\n"
    ))?;
    Ok(())
}
