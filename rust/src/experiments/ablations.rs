//! Ablation studies over the method's own design choices — extensions
//! beyond the paper's figures, probing the knobs DESIGN.md calls out:
//!
//! * `ablation_cutoff`  — how the budget cutoff percentile (the "typically
//!   somewhere around 95%" of Section III-B) shifts scores and rankings.
//! * `ablation_repeats` — how many repeated runs the tuning campaign needs
//!   before the best-configuration choice stabilizes (the paper uses 25).
//! * `ablation_noise`   — how measurement-noise amplitude distorts the
//!   dataset: optimum identity and tuned scores under increasing sigma.

use super::Ctx;
use crate::dataset::bruteforce;
use crate::gpu::specs::device_by_name;
use crate::hypertuning::{exhaustive_tuning, limited_space};
use crate::kernels;
use crate::methodology::{evaluate_algorithm, SpaceEval};
use crate::optimizers::HyperParams;
use crate::perfmodel::NoiseModel;
use crate::runner::LiveRunner;
use crate::util::stats;
use crate::util::table::Table;
use crate::error::Result;
use std::sync::Arc;

/// Budget-cutoff sensitivity: rescore the tuned-optimal GA under different
/// cutoff percentiles.
pub fn cutoff(ctx: &Ctx) -> Result<()> {
    ctx.ensure_hub()?;
    let results = ctx.limited_results("genetic_algorithm")?;
    let space = limited_space("genetic_algorithm")?;
    let best_hp = HyperParams::from_space_config(&space, results.best().config_idx);
    let mean_hp = HyperParams::from_space_config(&space, results.most_average().config_idx);

    let mut table = Table::new(
        "Ablation: budget cutoff percentile vs scores (genetic algorithm)",
        &["Cutoff", "Budget range (s)", "Optimal score", "Mean-config score", "Delta"],
    );
    for cutoff in [0.80, 0.90, 0.95, 0.99] {
        // Re-prepare the training spaces under this cutoff.
        let mut spaces = Vec::new();
        for kname in crate::dataset::hub::HUB_KERNELS {
            let kernel = kernels::kernel_by_name(kname)?;
            for dev in crate::gpu::specs::TRAIN_DEVICES {
                let cache = ctx.hub.load(kname, dev)?;
                spaces.push(SpaceEval::new(
                    kernel.space_arc(),
                    cache,
                    cutoff,
                    ctx.scale.points,
                ));
            }
        }
        let lo = spaces.iter().map(|s| s.budget_seconds).fold(f64::INFINITY, f64::min);
        let hi = spaces.iter().map(|s| s.budget_seconds).fold(0.0f64, f64::max);
        let best =
            evaluate_algorithm("genetic_algorithm", &best_hp, &spaces, ctx.scale.eval_repeats, 3)?;
        let mean =
            evaluate_algorithm("genetic_algorithm", &mean_hp, &spaces, ctx.scale.eval_repeats, 3)?;
        table.row(vec![
            format!("{cutoff:.2}"),
            format!("{lo:.0}..{hi:.0}"),
            format!("{:.3}", best.score),
            format!("{:.3}", mean.score),
            format!("{:+.3}", best.score - mean.score),
        ]);
    }
    let report = ctx.report("ablation_cutoff");
    report.table(&table)?;
    report.summary(
        "the optimal-vs-mean gap should persist across cutoffs; absolute scores \
         shift because the budget (and thus the baseline) changes\n",
    )?;
    Ok(())
}

/// Repeat-count stability: does the best hyperparameter configuration
/// chosen by the campaign change with fewer repeats?
pub fn repeats(ctx: &Ctx) -> Result<()> {
    let train = ctx.train_spaces()?;
    let hp_space = limited_space("dual_annealing")?;
    let reference = exhaustive_tuning(
        "dual_annealing",
        &hp_space,
        "limited",
        &train,
        ctx.scale.tuning_repeats.max(10),
        ctx.seed,
    )?;
    let ref_scores = reference.scores();

    let mut table = Table::new(
        "Ablation: tuning repeats vs campaign stability (dual annealing, 8 configs)",
        &["Repeats", "Best config", "Same as reference?", "Score corr."],
    );
    for reps in [1usize, 2, 5, 10] {
        let r = exhaustive_tuning("dual_annealing", &hp_space, "limited", &train, reps, ctx.seed)?;
        let corr = stats::pearson(&r.scores(), &ref_scores);
        table.row(vec![
            reps.to_string(),
            r.best().hp_key.clone(),
            (r.best().config_idx == reference.best().config_idx).to_string(),
            format!("{corr:.3}"),
        ]);
    }
    let report = ctx.report("ablation_repeats");
    report.table(&table)?;
    report.summary(
        "score correlation with the high-repeat reference should rise with \
         repeats — the stochasticity argument for the paper's 25 repeats\n",
    )?;
    Ok(())
}

/// Noise-amplitude sensitivity: rebuild one space with different sigma and
/// examine what the dataset looks like.
pub fn noise(ctx: &Ctx) -> Result<()> {
    let Some(device) = device_by_name("A100") else {
        crate::bail!("noise ablation requires the A100 device model");
    };
    let mut table = Table::new(
        "Ablation: measurement-noise amplitude (convolution @ A100)",
        &["Sigma", "Optimum (ms)", "Optimum idx", "Obs spread (p95/p5)", "GA score"],
    );
    let mut base_optimum = None;
    for sigma in [0.0, 0.02, 0.05, 0.10] {
        let noise = NoiseModel {
            sigma,
            ..NoiseModel::default()
        };
        let kernel = kernels::kernel_by_name("convolution")?;
        let mut live = LiveRunner::new(
            kernels::kernel_by_name("convolution")?,
            &device,
            Arc::clone(&ctx.engine),
            noise,
            ctx.seed,
        );
        let cache = Arc::new(bruteforce::bruteforce(&mut live)?);
        // Per-config observation spread, averaged.
        let mut spreads = Vec::new();
        for rec in cache.records.iter().filter(|r| r.valid).step_by(13) {
            let p95 = stats::percentile(&rec.observations, 95.0);
            let p5 = stats::percentile(&rec.observations, 5.0);
            spreads.push(p95 / p5);
        }
        let opt_idx = cache.optimum_index();
        base_optimum.get_or_insert(opt_idx);
        let se = SpaceEval::new(kernel.space_arc(), Arc::clone(&cache), 0.95, ctx.scale.points);
        let ga = evaluate_algorithm(
            "genetic_algorithm",
            &HyperParams::new(),
            &[se],
            ctx.scale.eval_repeats.min(25),
            7,
        )?;
        table.row(vec![
            format!("{sigma:.2}"),
            format!("{:.4}", cache.optimum() * 1e3),
            format!("{opt_idx}"),
            format!("{:.3}", stats::mean(&spreads)),
            format!("{:.3}", ga.score),
        ]);
    }
    let report = ctx.report("ablation_noise");
    report.table(&table)?;
    report.summary(
        "noise shifts the *measured* optimum slightly (mean over 32 obs) but \
         the tuning signal persists; spreads grow with sigma as expected\n",
    )?;
    Ok(())
}
