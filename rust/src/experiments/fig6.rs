//! Fig. 6: meta-strategies for hyperparameter tuning.
//!
//! The exhaustively evaluated hyperparameter spaces become tuning problems
//! themselves (objective = 1 - score, replayed through the ordinary
//! simulation machinery), and the paper's four algorithms — with their
//! tuned-optimal hyperparameters — are run as meta-strategies over them
//! with many repeats. The paper reports all meta-strategies performing
//! well after a startup cost, average score 0.223.

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space, meta};
use crate::methodology::{evaluate_algorithm, SpaceEval};
use crate::optimizers::HyperParams;
use crate::util::plot::Series;
use crate::error::Result;
use std::sync::Arc;

pub fn run(ctx: &Ctx) -> Result<()> {
    // Build the meta-level spaces: one per target algorithm.
    let mut meta_spaces = Vec::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let hp_space = Arc::new(limited_space(algo)?);
        let cache = Arc::new(meta::meta_cache_from_results(&results, &hp_space)?);
        meta_spaces.push(SpaceEval::new(
            hp_space,
            cache,
            crate::methodology::DEFAULT_CUTOFF,
            ctx.scale.points,
        ));
    }

    let mut series = Vec::new();
    let mut summary = String::new();
    let mut scores = Vec::new();
    for meta_algo in limited_algos() {
        // Use the tuned-optimal hyperparameters of the meta-strategy.
        let results = ctx.limited_results(meta_algo)?;
        let space = limited_space(meta_algo)?;
        let hp = HyperParams::from_space_config(&space, results.best().config_idx);
        let r = evaluate_algorithm(
            meta_algo,
            &hp,
            &meta_spaces,
            ctx.scale.eval_repeats,
            ctx.seed ^ 0x31,
        )?;
        let frac = |i: usize| (i + 1) as f64 / r.aggregate_curve.len() as f64;
        series.push(Series {
            name: format!("meta:{meta_algo}"),
            points: r
                .aggregate_curve
                .iter()
                .enumerate()
                .map(|(i, &y)| (frac(i), y))
                .collect(),
        });
        scores.push(r.score);
        summary.push_str(&format!("meta:{meta_algo}: aggregate score {:.3}\n", r.score));
    }
    summary.push_str(&format!(
        "average meta-strategy score: {:.3} (paper: 0.223)\n",
        crate::util::stats::mean(&scores)
    ));
    let report = ctx.report("fig6");
    report.lines(
        "Fig 6: aggregate performance of meta-strategies on the hyperparameter tuning spaces",
        &series,
    )?;
    report.summary(&summary)?;
    Ok(())
}
