//! Fig. 8: aggregate performance over time — mean, optimal-limited, and
//! optimal-extended hyperparameters. Produces the paper's second headline:
//! the average improvement of extended tuning over the average limited
//! configuration (paper: 204.7% overall, 210.8% on the test set).

use super::Ctx;
use crate::hypertuning::{extended_algos, extended_space, limited_space};
use crate::methodology::evaluate_algorithm;
use crate::optimizers::HyperParams;
use crate::util::plot::Series;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let all = ctx.all_spaces()?;
    let test = ctx.test_spaces()?;
    let reps = ctx.scale.eval_repeats;
    let mut series = Vec::new();
    let mut summary = String::new();
    let mut pct_all = Vec::new();
    let mut pct_test = Vec::new();
    let mut deltas = Vec::new();
    for algo in extended_algos() {
        let limited = ctx.limited_results(algo)?;
        let extended = ctx.extended_results(algo)?;
        let lim_space = limited_space(algo)?;
        let ext_space = extended_space(algo)?;
        let mean_hp =
            HyperParams::from_space_config(&lim_space, limited.most_average().config_idx);
        let lim_hp =
            HyperParams::from_space_config(&lim_space, limited.best().config_idx);
        let ext_hp =
            HyperParams::from_space_config(&ext_space, extended.best().config_idx);

        let mean_r = evaluate_algorithm(algo, &mean_hp, &all, reps, ctx.seed ^ 0x51)?;
        let lim_r = evaluate_algorithm(algo, &lim_hp, &all, reps, ctx.seed ^ 0x53)?;
        let ext_r = evaluate_algorithm(algo, &ext_hp, &all, reps, ctx.seed ^ 0x55)?;
        let mean_t = evaluate_algorithm(algo, &mean_hp, &test, reps, ctx.seed ^ 0x57)?;
        let ext_t = evaluate_algorithm(algo, &ext_hp, &test, reps, ctx.seed ^ 0x59)?;

        let frac = |i: usize| (i + 1) as f64 / mean_r.aggregate_curve.len() as f64;
        for (tag, r) in [("mean", &mean_r), ("opt-lim", &lim_r), ("opt-ext", &ext_r)] {
            series.push(Series {
                name: format!("{algo} ({tag})"),
                points: r
                    .aggregate_curve
                    .iter()
                    .enumerate()
                    .map(|(i, &y)| (frac(i), y))
                    .collect(),
            });
        }
        let delta = ext_r.score - mean_r.score;
        deltas.push(delta);
        let pct = |d: f64, base: f64| {
            if base.abs() > 1e-9 {
                d / base.abs() * 100.0
            } else {
                d * 100.0
            }
        };
        pct_all.push(pct(delta, mean_r.score));
        pct_test.push(pct(ext_t.score - mean_t.score, mean_t.score));
        summary.push_str(&format!(
            "{algo}: mean {:.3}, opt-limited {:.3}, opt-extended {:.3}, ext-vs-mean {:+.3}\n",
            mean_r.score, lim_r.score, ext_r.score, delta
        ));
    }
    summary.push_str(&format!(
        "average improvement of extended over mean configuration: {:.1}% overall (paper: 204.7%), {:.1}% on test (paper: 210.8%); mean delta {:+.3}\n",
        crate::util::stats::mean(&pct_all),
        crate::util::stats::mean(&pct_test),
        crate::util::stats::mean(&deltas),
    ));
    let report = ctx.report("fig8");
    report.lines(
        "Fig 8: aggregate performance over relative budget (mean vs optimal limited vs optimal extended)",
        &series,
    )?;
    report.summary(&summary)?;
    Ok(())
}
