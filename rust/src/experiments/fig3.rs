//! Fig. 3: best and worst hyperparameter configurations scored on (a) the
//! tuning campaign itself (25 repeats), (b) the training set re-executed
//! with 100 repeats, and (c) the held-out test set — the stability and
//! generalization check.

use super::Ctx;
use crate::hypertuning::{limited_algos, limited_space};
use crate::methodology::evaluate_algorithm;
use crate::optimizers::HyperParams;
use crate::util::table::Table;
use crate::error::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    let train = ctx.train_spaces()?;
    let test = ctx.test_spaces()?;
    let reps = ctx.scale.eval_repeats;
    let mut table = Table::new(
        "Fig 3: best/worst configuration scores on tuning, training (re-executed), and test",
        &["Algorithm", "Config", "Tuning", "Train (re-exec)", "Test"],
    );
    let mut gaps = Vec::new();
    for algo in limited_algos() {
        let results = ctx.limited_results(algo)?;
        let space = limited_space(algo)?;
        for (label, r) in [("best", results.best()), ("worst", results.worst())] {
            let hp = HyperParams::from_space_config(&space, r.config_idx);
            let on_train = evaluate_algorithm(algo, &hp, &train, reps, ctx.seed ^ 0x3)?;
            let on_test = evaluate_algorithm(algo, &hp, &test, reps, ctx.seed ^ 0x7)?;
            if label == "best" {
                gaps.push(on_train.score - on_test.score);
            }
            table.row(vec![
                algo.to_string(),
                label.to_string(),
                format!("{:.3}", r.score),
                format!("{:.3}", on_train.score),
                format!("{:.3}", on_test.score),
            ]);
        }
    }
    let report = ctx.report("fig3");
    report.table(&table)?;
    report.summary(&format!(
        "mean train->test generalization gap of best configs: {:.3} (small = generalizes)\n",
        crate::util::stats::mean(&gaps)
    ))?;
    Ok(())
}
