//! Experiment regenerators: one module per table/figure of the paper's
//! evaluation (see DESIGN.md §6 for the index).
//!
//! Every experiment runs through [`Ctx`], which owns the hub, the PJRT
//! engine, the results directory, and the scale profile, and memoizes the
//! expensive intermediates (prepared spaces, hypertuning campaigns) so
//! `experiment all` shares work across figures.

pub mod ctx;
pub mod ablations;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;

pub use ctx::{Ctx, Scale};

use crate::bail;
use crate::error::Result;

/// All paper experiment ids in run order.
pub const ALL: [&str; 11] = [
    "table2", "table3", "fig2", "fig3", "fig4", "fig5", "fig6", "table4", "fig7",
    "fig8", "fig9",
];

/// Extension ablations (design-choice studies beyond the paper).
pub const ABLATIONS: [&str; 3] = ["ablation_cutoff", "ablation_repeats", "ablation_noise"];

/// Run one experiment (or "all").
pub fn run(ctx: &Ctx, id: &str) -> Result<()> {
    match id {
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        "table4" => table4::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "ablation_cutoff" => ablations::cutoff(ctx),
        "ablation_repeats" => ablations::repeats(ctx),
        "ablation_noise" => ablations::noise(ctx),
        "all" => {
            for id in ALL {
                crate::log_info!("=== experiment {id} ===");
                run(ctx, id)?;
            }
            Ok(())
        }
        "ablations" => {
            for id in ABLATIONS {
                crate::log_info!("=== experiment {id} ===");
                run(ctx, id)?;
            }
            Ok(())
        }
        other => bail!(
            "unknown experiment {other:?} (known: {ALL:?}, {ABLATIONS:?}, 'all', 'ablations')"
        ),
    }
}
