//! Synthetic brute-force caches for generated search spaces.
//!
//! [`crate::searchspace::spacegen`] manufactures constrained spaces at
//! arbitrary scale; this module gives them a deterministic performance
//! landscape so a full simulated tuning campaign — SimTable build, batch
//! gathers, budget accounting — runs against million-config spaces
//! without ever brute-forcing real kernels. The landscape is a smooth
//! multi-dimensional bowl (so optimizers have gradient structure to
//! exploit) times hash-derived multiplicative ruggedness (so it is not
//! trivially convex), and every record is a pure function of
//! `(seed, rank)` — rebuilding the same spec yields bit-identical caches.

use super::cache::{CacheData, ConfigRecord};
use crate::searchspace::SearchSpace;
use crate::util::rng::mix64;

/// Uniform f64 in [0, 1) from a hash.
#[inline]
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Build a synthetic cache index-aligned with `space`.
///
/// * `seed` — landscape seed; values are functions of `(seed, rank)`.
/// * `observations_per_config` — raw observations per valid record.
/// * `invalid_fraction` — approximate fraction of configs that fail to
///   launch (recorded with `value = INFINITY`, compile time only).
pub fn synth_cache(
    space: &SearchSpace,
    seed: u64,
    observations_per_config: usize,
    invalid_fraction: f64,
) -> CacheData {
    let ndim = space.dims().len();
    // Per-dimension bowl centers, fixed by the seed.
    let centers: Vec<f64> = (0..ndim)
        .map(|d| unit(mix64(seed ^ 0x63656e, d as u64)))
        .collect();
    let mut records = Vec::with_capacity(space.len());
    let mut bruteforce_seconds = 0.0;
    for i in 0..space.len() {
        let rank = space.rank_of(i);
        let h = mix64(seed, rank);
        let compile_time = 0.2 + 2.0 * unit(mix64(h, 2));
        let valid = unit(mix64(h, 1)) >= invalid_fraction;
        let rec = if valid {
            // Smooth bowl over normalized digits + a mild per-config
            // multiplicative ruggedness term.
            let mut bowl = 0.0;
            for (d, &c) in centers.iter().enumerate() {
                let card = space.dims()[d];
                let x = if card > 1 {
                    space.digit(i, d) as f64 / (card - 1) as f64
                } else {
                    0.5
                };
                bowl += (x - c) * (x - c);
            }
            let rugged = 1.0 + 0.3 * (unit(mix64(h, 3)) - 0.5);
            let center = 0.05 * (1.0 + bowl) * rugged;
            let observations: Vec<f64> = (0..observations_per_config)
                .map(|j| center * (0.95 + 0.1 * unit(mix64(h, 100 + j as u64))))
                .collect();
            let value = observations.iter().sum::<f64>() / observations.len().max(1) as f64;
            ConfigRecord {
                key: space.key(i),
                value,
                observations,
                compile_time,
                valid: true,
            }
        } else {
            ConfigRecord {
                key: space.key(i),
                value: f64::INFINITY,
                observations: Vec::new(),
                compile_time,
                valid: false,
            }
        };
        bruteforce_seconds += rec.total_cost(0.0);
        records.push(rec);
    }
    CacheData::new(
        space.name.clone(),
        "synthetic-device",
        "spacegen landscape",
        seed,
        observations_per_config,
        bruteforce_seconds,
        space.params.iter().map(|p| p.name.clone()).collect(),
        records,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runner::{Budget, SimulationRunner, Tuning};
    use crate::searchspace::spacegen::{ConstraintFamily, SpaceGenSpec};
    use std::sync::Arc;

    fn small_space() -> SearchSpace {
        SpaceGenSpec::new(vec![16, 16, 8], 0.2, ConstraintFamily::Mixed, 5)
            .build()
            .unwrap()
    }

    #[test]
    fn deterministic_and_aligned() {
        let space = small_space();
        let a = synth_cache(&space, 9, 3, 0.05);
        let b = synth_cache(&space, 9, 3, 0.05);
        assert_eq!(a.records.len(), space.len());
        a.verify_against(&space).unwrap();
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.value.to_bits(), rb.value.to_bits());
            assert_eq!(ra.observations, rb.observations);
            assert_eq!(ra.valid, rb.valid);
        }
        // A different seed gives a different landscape.
        let c = synth_cache(&space, 10, 3, 0.05);
        assert!(a
            .records
            .iter()
            .zip(&c.records)
            .any(|(x, y)| x.value.to_bits() != y.value.to_bits()));
    }

    #[test]
    fn value_is_mean_of_observations_and_invalids_marked() {
        let space = small_space();
        let cache = synth_cache(&space, 3, 4, 0.25);
        let mut invalid = 0usize;
        for r in &cache.records {
            if r.valid {
                let mean = r.observations.iter().sum::<f64>() / r.observations.len() as f64;
                assert_eq!(r.value.to_bits(), mean.to_bits());
                assert_eq!(r.observations.len(), 4);
            } else {
                invalid += 1;
                assert!(r.value.is_infinite());
                assert!(r.observations.is_empty());
            }
        }
        let frac = invalid as f64 / cache.records.len() as f64;
        assert!((0.1..=0.4).contains(&frac), "invalid fraction {frac}");
    }

    #[test]
    fn campaign_smoke_on_synthetic_cache() {
        let space = Arc::new(small_space());
        let cache = Arc::new(synth_cache(&space, 7, 3, 0.05));
        let mut sim = SimulationRunner::new(Arc::clone(&space), cache).unwrap();
        let mut tuning = Tuning::new(&mut sim, Budget::evals(64));
        for i in 0..64 {
            tuning.eval(i % space.len());
        }
        let trace = tuning.finish();
        assert!(!trace.points.is_empty());
        assert!(trace.points.iter().any(|p| p.value.is_finite()));
    }
}
