//! The brute-force cache file: the unit of the benchmark hub.
//!
//! Schema (T4-flavored, one JSON document per (kernel, device) pair):
//!
//! ```json
//! {
//!   "schema": "tunetuner-T4", "schema_version": 1,
//!   "kernel": "gemm", "device": "A100", "problem": "...",
//!   "space_seed": 1234, "observations_per_config": 32,
//!   "bruteforce_seconds": 160922.5,
//!   "param_names": ["MWG", ...],
//!   "configs": [
//!     {"key": "16,16,...", "avg": 0.0123, "valid": true,
//!      "compile_time": 3.2, "obs": [ ... 32 raw values ... ]},
//!     ...
//!   ]
//! }
//! ```
//!
//! Configs are stored in search-space index order; loading verifies the
//! keys against a freshly built space so that an out-of-date cache fails
//! loudly instead of replaying the wrong values.

use super::simtable::SimTable;
use crate::runner::EvalResult;
use crate::searchspace::SearchSpace;
use crate::util::compress;
use crate::util::json::{self, Json};
use crate::bail;
use crate::error::{Context, Result, TuneError};
use std::path::Path;
use std::sync::{Arc, OnceLock};

/// One configuration's brute-force record.
#[derive(Clone, Debug)]
pub struct ConfigRecord {
    pub key: String,
    /// Mean observation; INFINITY for invalid configs.
    pub value: f64,
    pub observations: Vec<f64>,
    pub compile_time: f64,
    pub valid: bool,
}

impl ConfigRecord {
    pub fn from_eval(key: String, r: &EvalResult) -> ConfigRecord {
        ConfigRecord {
            key,
            value: r.value,
            observations: r.observations.clone(),
            compile_time: r.compile_time,
            valid: r.valid,
        }
    }

    /// Simulated seconds an evaluation of this record costs.
    pub fn total_cost(&self, overhead: f64) -> f64 {
        self.compile_time + self.observations.iter().sum::<f64>() + overhead
    }
}

/// A fully brute-forced search space.
#[derive(Debug)]
pub struct CacheData {
    pub kernel: String,
    pub device: String,
    pub problem: String,
    pub space_seed: u64,
    pub observations_per_config: usize,
    /// Simulated device-seconds the brute-force took (Table II).
    pub bruteforce_seconds: f64,
    pub param_names: Vec<String>,
    /// Index-aligned with the search space.
    pub records: Vec<ConfigRecord>,
    /// Lazily built columnar eval table + memoized statistics (see
    /// [`CacheData::sim_table`]).
    table: OnceLock<Arc<SimTable>>,
}

impl Clone for CacheData {
    /// Clones the records but not the memoized [`SimTable`] — the clone's
    /// `records` are independently mutable, so its table is rebuilt on
    /// first use.
    fn clone(&self) -> CacheData {
        CacheData::new(
            self.kernel.clone(),
            self.device.clone(),
            self.problem.clone(),
            self.space_seed,
            self.observations_per_config,
            self.bruteforce_seconds,
            self.param_names.clone(),
            self.records.clone(),
        )
    }
}

impl CacheData {
    pub fn new(
        kernel: impl Into<String>,
        device: impl Into<String>,
        problem: impl Into<String>,
        space_seed: u64,
        observations_per_config: usize,
        bruteforce_seconds: f64,
        param_names: Vec<String>,
        records: Vec<ConfigRecord>,
    ) -> CacheData {
        CacheData {
            kernel: kernel.into(),
            device: device.into(),
            problem: problem.into(),
            space_seed,
            observations_per_config,
            bruteforce_seconds,
            param_names,
            records,
            table: OnceLock::new(),
        }
    }

    /// The columnar evaluation table and memoized baseline statistics for
    /// this cache, built on first use and `Arc`-shared afterwards (the
    /// simulation runners and the baseline both read it). `records` must
    /// not be mutated after the first call — mutate-then-replay would
    /// read the stale table (cloning resets the memo).
    pub fn sim_table(&self) -> &Arc<SimTable> {
        self.table.get_or_init(|| Arc::new(SimTable::build(self)))
    }

    /// Sorted mean values of the valid configurations (ascending).
    /// Memoized on the [`SimTable`]; this accessor clones — hot callers
    /// should read `sim_table().sorted_valid_values` directly.
    pub fn sorted_valid_values(&self) -> Vec<f64> {
        self.sim_table().sorted_valid_values.clone()
    }

    /// The known optimum (lowest mean).
    pub fn optimum(&self) -> f64 {
        self.sim_table().optimum
    }

    /// Index of the optimal configuration.
    pub fn optimum_index(&self) -> usize {
        self.sim_table().optimum_index
    }

    /// Mean evaluation cost in simulated seconds (used for the baseline
    /// time axis); invalid configs cost compile + overhead only. The
    /// standard-overhead value is memoized as `sim_table().mean_eval_cost`;
    /// this general form still walks the records.
    pub fn mean_eval_cost(&self, overhead: f64) -> f64 {
        let total: f64 = self.records.iter().map(|r| r.total_cost(overhead)).sum();
        total / self.records.len() as f64
    }

    /// Fraction of configurations that launch.
    pub fn valid_fraction(&self) -> f64 {
        self.sim_table().valid_fraction
    }

    // -- JSON (de)serialization -------------------------------------------------

    pub fn to_json(&self) -> Json {
        let configs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("key", r.key.as_str().into())
                    .set("valid", r.valid.into())
                    .set("compile_time", r.compile_time.into());
                if r.valid {
                    o.set("avg", r.value.into()).set(
                        "obs",
                        Json::Arr(r.observations.iter().map(|&x| Json::Num(x)).collect()),
                    );
                } else {
                    // JSON has no INFINITY; invalid configs carry no values.
                    o.set("avg", Json::Null).set("obs", Json::Arr(vec![]));
                }
                o
            })
            .collect();
        let mut j = Json::obj();
        j.set("schema", "tunetuner-T4".into())
            .set("schema_version", 1usize.into())
            .set("kernel", self.kernel.as_str().into())
            .set("device", self.device.as_str().into())
            .set("problem", self.problem.as_str().into())
            .set("space_seed", (self.space_seed as f64).into())
            .set(
                "observations_per_config",
                self.observations_per_config.into(),
            )
            .set("bruteforce_seconds", self.bruteforce_seconds.into())
            .set(
                "param_names",
                Json::Arr(
                    self.param_names
                        .iter()
                        .map(|n| Json::Str(n.clone()))
                        .collect(),
                ),
            )
            .set("configs", Json::Arr(configs));
        j
    }

    pub fn from_json(j: &Json) -> Result<CacheData> {
        let str_field = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(|v| v.as_str())
                .with_context(|| format!("cache missing {k:?}"))?
                .to_string())
        };
        if str_field("schema")? != "tunetuner-T4" {
            bail!("not a tunetuner-T4 cache file");
        }
        let num_field = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("cache missing {k:?}"))
        };
        // Strict decoding: a corrupt cache must fail loudly, not replay
        // wrong values. Param names must all be strings and observations
        // all numeric — the old lenient path defaulted/dropped them,
        // which silently shifted every downstream cost and value.
        let mut param_names = Vec::new();
        for (i, v) in j
            .get("param_names")
            .and_then(|v| v.as_arr())
            .context("missing param_names")?
            .iter()
            .enumerate()
        {
            match v.as_str() {
                Some(s) => param_names.push(s.to_string()),
                None => {
                    return Err(TuneError::Parse(format!(
                        "cache param_names[{i}] is not a string: {v:?}"
                    )))
                }
            }
        }
        let mut records = Vec::new();
        for c in j
            .get("configs")
            .and_then(|v| v.as_arr())
            .context("missing configs")?
        {
            let key = c
                .get("key")
                .and_then(|v| v.as_str())
                .context("config missing key")?
                .to_string();
            let valid = c.get("valid").and_then(|v| v.as_bool()).unwrap_or(false);
            let obs_arr = c.get("obs").and_then(|v| v.as_arr()).unwrap_or(&[]);
            let mut observations = Vec::with_capacity(obs_arr.len());
            for (i, x) in obs_arr.iter().enumerate() {
                match x.as_f64() {
                    Some(f) => observations.push(f),
                    None => {
                        return Err(TuneError::Parse(format!(
                            "cache config {key:?}: obs[{i}] is not a number: {x:?}"
                        )))
                    }
                }
            }
            records.push(ConfigRecord {
                value: if valid {
                    c.get("avg")
                        .and_then(|v| v.as_f64())
                        .with_context(|| format!("valid config {key:?} missing avg"))?
                } else {
                    f64::INFINITY
                },
                key,
                observations,
                compile_time: c
                    .get("compile_time")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.0),
                valid,
            });
        }
        Ok(CacheData::new(
            str_field("kernel")?,
            str_field("device")?,
            str_field("problem")?,
            num_field("space_seed")? as u64,
            num_field("observations_per_config")? as usize,
            num_field("bruteforce_seconds")?,
            param_names,
            records,
        ))
    }

    /// Save (gzip if path ends in .gz).
    pub fn save(&self, path: &Path) -> Result<()> {
        compress::write_string(path, &self.to_json().to_string())
    }

    /// Load and parse.
    pub fn load(path: &Path) -> Result<CacheData> {
        let text = compress::read_string(path)?;
        CacheData::from_json(&json::parse(&text).context("parse cache JSON")?)
    }

    /// Verify this cache is index-aligned with a search space.
    pub fn verify_against(&self, space: &SearchSpace) -> Result<()> {
        if self.records.len() != space.len() {
            return Err(crate::error::TuneError::StaleCache(format!(
                "cache has {} configs but space {} has {}",
                self.records.len(),
                space.name,
                space.len()
            )));
        }
        if space.is_empty() {
            return Ok(());
        }
        // Spot-check keys (full check is O(n) string builds; sample). The
        // packed-rank engine decodes straight from the SoA buffer, so
        // space.key() here is allocation-bound, not lookup-bound.
        let n = space.len();
        for idx in [0, n / 3, n / 2, n - 1] {
            if self.records[idx].key != space.key(idx) {
                return Err(crate::error::TuneError::StaleCache(format!(
                    "cache/space key mismatch at {idx}: {} vs {}",
                    self.records[idx].key,
                    space.key(idx)
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_cache() -> CacheData {
        CacheData::new(
            "synthetic",
            "A100",
            "test",
            99,
            3,
            1234.5,
            vec!["a".into(), "b".into()],
            vec![
                ConfigRecord {
                    key: "1,1".into(),
                    value: 0.5,
                    observations: vec![0.4, 0.5, 0.6],
                    compile_time: 2.0,
                    valid: true,
                },
                ConfigRecord {
                    key: "1,2".into(),
                    value: f64::INFINITY,
                    observations: vec![],
                    compile_time: 3.0,
                    valid: false,
                },
                ConfigRecord {
                    key: "2,1".into(),
                    value: 0.25,
                    observations: vec![0.2, 0.25, 0.3],
                    compile_time: 1.5,
                    valid: true,
                },
            ],
        )
    }

    #[test]
    fn json_roundtrip() {
        let c = sample_cache();
        let j = c.to_json();
        let back = CacheData::from_json(&j).unwrap();
        assert_eq!(back.kernel, "synthetic");
        assert_eq!(back.records.len(), 3);
        assert_eq!(back.records[0].observations, vec![0.4, 0.5, 0.6]);
        assert!(!back.records[1].valid);
        assert!(back.records[1].value.is_infinite());
        assert_eq!(back.bruteforce_seconds, 1234.5);
        assert_eq!(back.space_seed, 99);
    }

    #[test]
    fn file_roundtrip_gz() {
        let dir = std::env::temp_dir().join(format!("tt_cache_{}", std::process::id()));
        let path = dir.join("x.json.gz");
        let c = sample_cache();
        c.save(&path).unwrap();
        let back = CacheData::load(&path).unwrap();
        assert_eq!(back.records[2].value, 0.25);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stats_helpers() {
        let c = sample_cache();
        assert_eq!(c.optimum(), 0.25);
        assert_eq!(c.optimum_index(), 2);
        assert_eq!(c.sorted_valid_values(), vec![0.25, 0.5]);
        assert!((c.valid_fraction() - 2.0 / 3.0).abs() < 1e-12);
        // mean cost: (2+1.5) + (3) compile + obs sums (1.5 + 0.75) + 3*oh
        let cost = c.mean_eval_cost(0.1);
        assert!((cost - (2.0 + 1.5 + 3.0 + 1.5 + 0.75 + 0.3) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_wrong_schema() {
        let j = json::parse(r#"{"schema": "other"}"#).unwrap();
        assert!(CacheData::from_json(&j).is_err());
    }

    #[test]
    fn strict_decoding_rejects_non_numeric_observation() {
        // The old lenient decoder filter_map'd non-numeric observations
        // away, silently shortening the run_time of the config — the
        // replayed clock would drift from what live tuning measured.
        let mut j = sample_cache().to_json();
        if let Some(Json::Arr(configs)) = j.get("configs").cloned() {
            let mut cfgs = configs;
            cfgs[0].set("obs", Json::Arr(vec![Json::Num(0.4), Json::Str("oops".into())]));
            j.set("configs", Json::Arr(cfgs));
        }
        let err = CacheData::from_json(&j).unwrap_err();
        assert!(matches!(err, TuneError::Parse(_)), "{err:#}");
        let msg = format!("{err:#}");
        assert!(msg.contains("1,1"), "names the offending config: {msg}");
        assert!(msg.contains("obs[1]"), "{msg}");
    }

    #[test]
    fn strict_decoding_rejects_non_string_param_name() {
        // The old decoder unwrap_or_default'd these to "", breaking the
        // T1 interop metadata without any signal.
        let mut j = sample_cache().to_json();
        j.set(
            "param_names",
            Json::Arr(vec![Json::Str("a".into()), Json::Num(7.0)]),
        );
        let err = CacheData::from_json(&j).unwrap_err();
        assert!(matches!(err, TuneError::Parse(_)), "{err:#}");
        assert!(format!("{err:#}").contains("param_names[1]"), "{err:#}");
    }
}
