//! The brute-force driver: exhaustively evaluate a search space.
//!
//! This is the one-off cost that enables simulation mode. Evaluations are
//! batched through the live runner (one PJRT execution per 16k configs)
//! and the *simulated* device time — what the search would have cost on
//! real hardware — is accumulated for Table II.

use super::cache::{CacheData, ConfigRecord};
use crate::runner::live::LiveRunner;
use crate::runner::Runner;
use crate::error::Result;

/// Brute-force a full (kernel, device) search space through a live runner.
pub fn bruteforce(runner: &mut LiveRunner) -> Result<CacheData> {
    let n = runner.space().len();
    let idxs: Vec<usize> = (0..n).collect();
    let mut records = Vec::with_capacity(n);
    let mut device_seconds = 0.0;
    // Chunked to bound memory; the engine re-chunks to artifact batch sizes.
    for chunk in idxs.chunks(16384) {
        let results = runner.evaluate_batch(chunk);
        for (&idx, r) in chunk.iter().zip(&results) {
            device_seconds += r.total_cost();
            records.push(ConfigRecord::from_eval(runner.space().key(idx), r));
        }
    }
    let kernel = runner.kernel();
    Ok(CacheData::new(
        kernel.name.to_string(),
        runner
            .label()
            .split('@')
            .nth(1)
            .unwrap_or("?")
            .trim_end_matches(" live")
            .to_string(),
        kernel.problem.clone(),
        runner.space_seed,
        runner.observations,
        device_seconds,
        kernel.space().params.iter().map(|p| p.name.clone()).collect(),
        records,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::specs::A100;
    use crate::kernels;
    use crate::perfmodel::NoiseModel;
    use crate::runtime::Engine;
    use std::sync::Arc;

    #[test]
    fn covers_whole_space_deterministically() {
        let mk = || {
            LiveRunner::new(
                kernels::kernel_by_name("synthetic").unwrap(),
                &A100,
                Arc::new(Engine::native()),
                NoiseModel::default(),
                42,
            )
        };
        let c1 = bruteforce(&mut mk()).unwrap();
        let c2 = bruteforce(&mut mk()).unwrap();
        assert_eq!(c1.records.len(), mk().space().len());
        assert!(c1.bruteforce_seconds > 0.0);
        assert_eq!(c1.device, "A100");
        for (a, b) in c1.records.iter().zip(&c2.records) {
            assert_eq!(a.value, b.value);
            assert_eq!(a.observations, b.observations);
        }
        // Some spread in values and a strictly best optimum.
        let vals = c1.sorted_valid_values();
        assert!(vals.len() > 10);
        assert!(vals[vals.len() - 1] / vals[0] > 1.2);
    }
}
