//! The on-disk benchmark hub.
//!
//! Layout (mirroring the paper's community hub):
//!
//! ```text
//! hub/
//!   index.json                 # dataset metadata + per-space summary
//!   <kernel>/
//!     t1.json                  # T1-style input description
//!     <DEVICE>.json.gz         # T4-style brute-force cache (compressed)
//! ```
//!
//! `Hub::ensure` builds missing caches (in parallel across spaces) and
//! `Hub::load` serves them with an in-memory memo so experiments touching
//! the same space repeatedly don't re-read or re-parse.

use super::bruteforce;
use super::cache::CacheData;
use super::t1;
use crate::gpu::specs::{all_devices, device_by_name, DeviceModel};
use crate::kernels::{self, Kernel};
use crate::perfmodel::NoiseModel;
use crate::runner::LiveRunner;
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::error::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Default seed for the published dataset.
pub const HUB_SEED: u64 = 0xFA1B;

/// The four paper kernels in hub order.
pub const HUB_KERNELS: [&str; 4] = ["dedispersion", "convolution", "hotspot", "gemm"];

/// A handle to a hub directory.
pub struct Hub {
    root: PathBuf,
    memo: Mutex<HashMap<(String, String), Arc<CacheData>>>,
}

impl Hub {
    pub fn new<P: Into<PathBuf>>(root: P) -> Hub {
        Hub {
            root: root.into(),
            memo: Mutex::new(HashMap::new()),
        }
    }

    /// Default hub directory: `$TUNETUNER_HUB` or `./hub`.
    pub fn default_root() -> PathBuf {
        std::env::var("TUNETUNER_HUB")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("hub"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn cache_path(&self, kernel: &str, device: &str) -> PathBuf {
        self.root.join(kernel).join(format!("{device}.json.gz"))
    }

    pub fn exists(&self, kernel: &str, device: &str) -> bool {
        self.cache_path(kernel, device).exists()
    }

    /// Load a cache (memoized); verifies alignment with the kernel space.
    pub fn load(&self, kernel: &str, device: &str) -> Result<Arc<CacheData>> {
        let key = (kernel.to_string(), device.to_string());
        if let Some(c) = self.memo.lock().unwrap().get(&key) {
            return Ok(Arc::clone(c));
        }
        let path = self.cache_path(kernel, device);
        let data = Arc::new(CacheData::load(&path).with_context(|| {
            format!(
                "load hub cache {} (build it with `tunetuner bruteforce`)",
                path.display()
            )
        })?);
        self.memo.lock().unwrap().insert(key, Arc::clone(&data));
        Ok(data)
    }

    /// Brute-force one (kernel, device) space and store it.
    pub fn build_one(
        &self,
        kernel: &Kernel,
        device: &DeviceModel,
        engine: Arc<Engine>,
        seed: u64,
    ) -> Result<Arc<CacheData>> {
        let mut runner = LiveRunner::new(
            kernels::kernel_by_name(kernel.name)?,
            device,
            engine,
            NoiseModel::default(),
            seed,
        );
        let cache = Arc::new(bruteforce::bruteforce(&mut runner)?);
        cache.save(&self.cache_path(kernel.name, device.name))?;
        t1::write_t1(kernel, &self.root.join(kernel.name).join("t1.json"))?;
        self.memo.lock().unwrap().insert(
            (kernel.name.to_string(), device.name.to_string()),
            Arc::clone(&cache),
        );
        Ok(cache)
    }

    /// Ensure every (kernel × device) cache exists, building missing ones
    /// in parallel. Returns (kernel, device, bruteforce_seconds) for all.
    pub fn ensure(
        &self,
        kernels_list: &[&str],
        devices_list: &[&str],
        engine: Arc<Engine>,
        seed: u64,
    ) -> Result<Vec<(String, String, f64)>> {
        let mut missing = Vec::new();
        for k in kernels_list {
            for d in devices_list {
                if !self.exists(k, d) {
                    missing.push((k.to_string(), d.to_string()));
                }
            }
        }
        if !missing.is_empty() {
            crate::log_info!("hub: building {} missing spaces", missing.len());
            let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for (k, d) in &missing {
                    let engine = Arc::clone(&engine);
                    let errors = &errors;
                    let this = &self;
                    scope.spawn(move || {
                        let go = || -> Result<()> {
                            let kernel = kernels::kernel_by_name(k)?;
                            let device = device_by_name(d).ok_or_else(|| {
                                crate::error::TuneError::UnknownDevice(d.clone())
                            })?;
                            let c = this.build_one(&kernel, &device, engine, seed)?;
                            crate::log_info!(
                                "hub: {k}@{d}: {} configs, {:.1} simulated hours",
                                c.records.len(),
                                c.bruteforce_seconds / 3600.0
                            );
                            Ok(())
                        };
                        if let Err(e) = go() {
                            errors.lock().unwrap().push(format!("{k}@{d}: {e:#}"));
                        }
                    });
                }
            });
            let errs = errors.into_inner().unwrap();
            if !errs.is_empty() {
                crate::bail!("hub build failures: {}", errs.join("; "));
            }
        }
        let mut out = Vec::new();
        for k in kernels_list {
            for d in devices_list {
                let c = self.load(k, d)?;
                out.push((k.to_string(), d.to_string(), c.bruteforce_seconds));
            }
        }
        self.write_index(&out)?;
        Ok(out)
    }

    /// Ensure the full 24-space paper dataset.
    pub fn ensure_all(&self, engine: Arc<Engine>, seed: u64) -> Result<Vec<(String, String, f64)>> {
        let devices: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
        self.ensure(&HUB_KERNELS, &devices, engine, seed)
    }

    fn write_index(&self, entries: &[(String, String, f64)]) -> Result<()> {
        let mut spaces = Vec::new();
        for (k, d, secs) in entries {
            let c = self.load(k, d)?;
            let mut o = Json::obj();
            o.set("kernel", k.as_str().into())
                .set("device", d.as_str().into())
                .set("configs", c.records.len().into())
                .set("valid_fraction", c.valid_fraction().into())
                .set("optimum", c.optimum().into())
                .set("bruteforce_seconds", (*secs).into())
                .set("path", format!("{k}/{d}.json.gz").into());
            spaces.push(o);
        }
        let mut j = Json::obj();
        j.set("schema", "tunetuner-hub-index".into())
            .set("version", 1usize.into())
            .set("observations_per_config", 32usize.into())
            .set("spaces", Json::Arr(spaces));
        crate::util::compress::write_string(&self.root.join("index.json"), &j.to_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tt_hub_{}", std::process::id()));
        let hub = Hub::new(&dir);
        let engine = Arc::new(Engine::native());
        let entries = hub
            .ensure(&["synthetic"], &["A100", "W6600"], engine, 7)
            .unwrap();
        assert_eq!(entries.len(), 2);
        assert!(hub.exists("synthetic", "A100"));
        assert!(dir.join("synthetic/t1.json").exists());
        assert!(dir.join("index.json").exists());

        // Reload from disk through a fresh hub handle.
        let hub2 = Hub::new(&dir);
        let c = hub2.load("synthetic", "A100").unwrap();
        assert!(c.records.len() > 50);
        // memoized second load returns the same Arc
        let c2 = hub2.load("synthetic", "A100").unwrap();
        assert!(Arc::ptr_eq(&c, &c2));

        // Landscapes differ across devices.
        let w = hub2.load("synthetic", "W6600").unwrap();
        assert_ne!(c.optimum_index(), w.optimum_index());

        std::fs::remove_dir_all(&dir).ok();
    }
}
