//! The on-disk benchmark hub.
//!
//! Layout (mirroring the paper's community hub):
//!
//! ```text
//! hub/
//!   index.json                 # dataset metadata + per-space summary
//!   <kernel>/
//!     t1.json                  # T1-style input description
//!     <DEVICE>.json.gz         # T4-style brute-force cache (compressed)
//! ```
//!
//! `Hub::ensure` builds missing caches (in parallel across spaces) and
//! `Hub::load` serves them with an in-memory memo so experiments touching
//! the same space repeatedly don't re-read or re-parse.

use super::bruteforce;
use super::cache::CacheData;
use super::t1;
use super::t4b;
use crate::gpu::specs::{all_devices, device_by_name, DeviceModel};
use crate::kernels::{self, Kernel};
use crate::perfmodel::NoiseModel;
use crate::runner::LiveRunner;
use crate::runtime::Engine;
use crate::util::json::Json;
use crate::error::{Context, Result};
use crate::util::hash::FastMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Default seed for the published dataset.
pub const HUB_SEED: u64 = 0xFA1B;

/// The four paper kernels in hub order.
pub const HUB_KERNELS: [&str; 4] = ["dedispersion", "convolution", "hotspot", "gemm"];

/// A handle to a hub directory.
pub struct Hub {
    root: PathBuf,
    memo: Mutex<FastMap<(String, String), Arc<CacheData>>>,
    /// Per-kernel space fingerprints (None = unregistered kernel).
    /// Computing one builds the kernel's whole search space, so it is
    /// memoized per hub instead of per (kernel, device) load — a full
    /// hub scan would otherwise re-enumerate each kernel's space once
    /// per device on the exact startup path T4B exists to make cheap.
    fp_memo: Mutex<FastMap<String, Option<String>>>,
}

impl Hub {
    pub fn new<P: Into<PathBuf>>(root: P) -> Hub {
        Hub {
            root: root.into(),
            memo: Mutex::new(FastMap::default()),
            fp_memo: Mutex::new(FastMap::default()),
        }
    }

    /// Default hub directory: `$TUNETUNER_HUB` or `./hub`.
    pub fn default_root() -> PathBuf {
        std::env::var("TUNETUNER_HUB")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("hub"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn cache_path(&self, kernel: &str, device: &str) -> PathBuf {
        self.root.join(kernel).join(format!("{device}.json.gz"))
    }

    /// Path of the binary T4B sidecar next to the JSON cache.
    pub fn sidecar_path(&self, kernel: &str, device: &str) -> PathBuf {
        t4b::sidecar_path(&self.cache_path(kernel, device))
    }

    pub fn exists(&self, kernel: &str, device: &str) -> bool {
        self.cache_path(kernel, device).exists()
    }

    /// Load a cache (memoized). When a T4B sidecar is present, its
    /// fingerprint matches the kernel's current search space, and the
    /// JSON has not been modified since the sidecar was written, it is
    /// served directly — the JSON is never read, let alone parsed. A
    /// missing, stale, outdated (JSON newer) or unreadable sidecar falls
    /// back to the JSON and (re)writes the sidecar so the next load is
    /// binary; a JSON that is newer but unreadable falls back to a
    /// fingerprint-fresh sidecar instead of failing the load.
    pub fn load(&self, kernel: &str, device: &str) -> Result<Arc<CacheData>> {
        let key = (kernel.to_string(), device.to_string());
        if let Some(c) = self.memo.lock().unwrap().get(&key) {
            return Ok(Arc::clone(c));
        }
        let data = Arc::new(self.load_from_disk(kernel, device)?);
        self.memo.lock().unwrap().insert(key, Arc::clone(&data));
        Ok(data)
    }

    /// Fingerprint of the space a kernel's caches must index, memoized
    /// per hub (computing it enumerates the kernel's search space). Hub
    /// caches are always for registered kernels; anything else returns
    /// None and skips the sidecar machinery, parsing JSON as before.
    fn space_fingerprint(&self, kernel: &str) -> Option<String> {
        if let Some(fp) = self.fp_memo.lock().unwrap().get(kernel) {
            return fp.clone();
        }
        // Compute outside the lock: building a kernel enumerates its
        // whole space, and holding the mutex for that would serialize
        // unrelated kernels' loads. A racing thread computes the same
        // deterministic value; first insert wins.
        let fp = kernels::kernel_by_name(kernel)
            .ok()
            .map(|k| k.space().fingerprint());
        self.fp_memo
            .lock()
            .unwrap()
            .entry(kernel.to_string())
            .or_insert(fp)
            .clone()
    }

    /// Decode the sidecar if it matches the expected space fingerprint;
    /// stale/unreadable sidecars warn and return None.
    fn read_fresh_sidecar(
        &self,
        sidecar: &Path,
        fingerprint: Option<&str>,
    ) -> Option<(CacheData, t4b::SrcStamp)> {
        let fp = fingerprint?;
        if !sidecar.exists() {
            return None;
        }
        match t4b::read(sidecar) {
            Ok((cache, got, src)) if got == fp => Some((cache, src)),
            Ok((_, got, _)) => {
                crate::log_warn!(
                    "hub: stale T4B sidecar {} (fingerprint {got} != {fp}), re-parsing JSON",
                    sidecar.display()
                );
                None
            }
            Err(e) => {
                crate::log_warn!(
                    "hub: unreadable T4B sidecar {}: {e:#}; re-parsing JSON",
                    sidecar.display()
                );
                None
            }
        }
    }

    /// Best-effort sidecar write, stamped with the JSON it mirrors — a
    /// failure only costs the next load a JSON parse.
    fn write_sidecar(&self, cache: &CacheData, fp: &str, json: &Path, sidecar: &Path) {
        if let Err(e) = t4b::write(cache, fp, t4b::SrcStamp::of(json), sidecar) {
            crate::log_warn!(
                "hub: failed to write T4B sidecar {}: {e:#}",
                sidecar.display()
            );
        }
    }

    fn load_from_disk(&self, kernel: &str, device: &str) -> Result<CacheData> {
        let path = self.cache_path(kernel, device);
        let fingerprint = self.space_fingerprint(kernel);
        let sidecar = t4b::sidecar_path(&path);
        if let Some((cache, src)) = self.read_fresh_sidecar(&sidecar, fingerprint.as_deref()) {
            if sidecar_mirrors_json(&src, &path, &sidecar) {
                // The warm path: the sidecar still mirrors the JSON next
                // to it, which is never read, let alone parsed.
                return Ok(cache);
            }
            // The JSON changed since the sidecar was written (a dropped-in
            // re-measured cache keeps the same space fingerprint): the
            // JSON wins — but if it turns out unreadable, the decoded
            // sidecar (the last good parse) must not take the hub down.
            match CacheData::load(&path) {
                Ok(fresh) => {
                    if let Some(fp) = &fingerprint {
                        self.write_sidecar(&fresh, fp, &path, &sidecar);
                    }
                    return Ok(fresh);
                }
                Err(e) => {
                    crate::log_warn!(
                        "hub: cache {} unreadable ({e:#}); serving the T4B sidecar instead",
                        path.display()
                    );
                    return Ok(cache);
                }
            }
        }
        let cache = CacheData::load(&path).with_context(|| {
            format!(
                "load hub cache {} (build it with `tunetuner bruteforce`)",
                path.display()
            )
        })?;
        if let Some(fp) = &fingerprint {
            self.write_sidecar(&cache, fp, &path, &sidecar);
        }
        Ok(cache)
    }

    /// Brute-force one (kernel, device) space and store it.
    pub fn build_one(
        &self,
        kernel: &Kernel,
        device: &DeviceModel,
        engine: Arc<Engine>,
        seed: u64,
    ) -> Result<Arc<CacheData>> {
        let mut runner = LiveRunner::new(
            kernels::kernel_by_name(kernel.name)?,
            device,
            engine,
            NoiseModel::default(),
            seed,
        );
        let cache = Arc::new(bruteforce::bruteforce(&mut runner)?);
        let path = self.cache_path(kernel.name, device.name);
        cache.save(&path)?;
        // Emit both formats up front: a fresh hub never pays the one-time
        // JSON→T4B conversion on its first load. Best-effort, like the
        // load path — the JSON already landed, so a failed sidecar write
        // only costs the next load a parse.
        let sidecar = t4b::sidecar_path(&path);
        self.write_sidecar(&cache, &kernel.space().fingerprint(), &path, &sidecar);
        t1::write_t1(kernel, &self.root.join(kernel.name).join("t1.json"))?;
        self.memo.lock().unwrap().insert(
            (kernel.name.to_string(), device.name.to_string()),
            Arc::clone(&cache),
        );
        Ok(cache)
    }

    /// Ensure every (kernel × device) cache exists, building missing ones
    /// in parallel. Returns (kernel, device, bruteforce_seconds) for all.
    pub fn ensure(
        &self,
        kernels_list: &[&str],
        devices_list: &[&str],
        engine: Arc<Engine>,
        seed: u64,
    ) -> Result<Vec<(String, String, f64)>> {
        let mut missing = Vec::new();
        for k in kernels_list {
            for d in devices_list {
                if !self.exists(k, d) {
                    missing.push((k.to_string(), d.to_string()));
                }
            }
        }
        if !missing.is_empty() {
            crate::log_info!("hub: building {} missing spaces", missing.len());
            let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
            std::thread::scope(|scope| {
                for (k, d) in &missing {
                    let engine = Arc::clone(&engine);
                    let errors = &errors;
                    let this = &self;
                    scope.spawn(move || {
                        let go = || -> Result<()> {
                            let kernel = kernels::kernel_by_name(k)?;
                            let device = device_by_name(d).ok_or_else(|| {
                                crate::error::TuneError::UnknownDevice(d.clone())
                            })?;
                            let c = this.build_one(&kernel, &device, engine, seed)?;
                            crate::log_info!(
                                "hub: {k}@{d}: {} configs, {:.1} simulated hours",
                                c.records.len(),
                                c.bruteforce_seconds / 3600.0
                            );
                            Ok(())
                        };
                        if let Err(e) = go() {
                            errors.lock().unwrap().push(format!("{k}@{d}: {e:#}"));
                        }
                    });
                }
            });
            let errs = errors.into_inner().unwrap();
            if !errs.is_empty() {
                crate::bail!("hub build failures: {}", errs.join("; "));
            }
        }
        let mut out = Vec::new();
        for k in kernels_list {
            for d in devices_list {
                let c = self.load(k, d)?;
                out.push((k.to_string(), d.to_string(), c.bruteforce_seconds));
            }
        }
        self.write_index(&out)?;
        Ok(out)
    }

    /// Ensure the full 24-space paper dataset.
    pub fn ensure_all(&self, engine: Arc<Engine>, seed: u64) -> Result<Vec<(String, String, f64)>> {
        let devices: Vec<&str> = all_devices().iter().map(|d| d.name).collect();
        self.ensure(&HUB_KERNELS, &devices, engine, seed)
    }

    fn write_index(&self, entries: &[(String, String, f64)]) -> Result<()> {
        let mut spaces = Vec::new();
        for (k, d, secs) in entries {
            let c = self.load(k, d)?;
            let mut o = Json::obj();
            o.set("kernel", k.as_str().into())
                .set("device", d.as_str().into())
                .set("configs", c.records.len().into())
                .set("valid_fraction", c.valid_fraction().into())
                .set("optimum", c.optimum().into())
                .set("bruteforce_seconds", (*secs).into())
                .set("path", format!("{k}/{d}.json.gz").into());
            spaces.push(o);
        }
        let mut j = Json::obj();
        j.set("schema", "tunetuner-hub-index".into())
            .set("version", 1usize.into())
            .set("observations_per_config", 32usize.into())
            .set("spaces", Json::Arr(spaces));
        crate::util::compress::write_string(&self.root.join("index.json"), &j.to_pretty())
    }
}

/// True when the sidecar still mirrors the JSON next to it. The sidecar
/// records the `(size, mtime)` identity of the JSON it was converted
/// from (exact equality, immune to timestamp-granularity ties); a
/// stamp-less sidecar falls back to an mtime comparison. A missing JSON
/// counts as mirrored — the sidecar is all there is.
fn sidecar_mirrors_json(src: &t4b::SrcStamp, json: &Path, sidecar: &Path) -> bool {
    if !json.exists() {
        return true;
    }
    if src.is_known() {
        return t4b::SrcStamp::of(json) == *src;
    }
    let mtime = |p: &Path| std::fs::metadata(p).and_then(|m| m.modified()).ok();
    match (mtime(json), mtime(sidecar)) {
        (Some(j), Some(s)) => j <= s,
        _ => true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_load_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tt_hub_{}", std::process::id()));
        let hub = Hub::new(&dir);
        let engine = Arc::new(Engine::native());
        let entries = hub
            .ensure(&["synthetic"], &["A100", "W6600"], engine, 7)
            .unwrap();
        assert_eq!(entries.len(), 2);
        assert!(hub.exists("synthetic", "A100"));
        assert!(dir.join("synthetic/t1.json").exists());
        assert!(dir.join("index.json").exists());

        // Reload from disk through a fresh hub handle.
        let hub2 = Hub::new(&dir);
        let c = hub2.load("synthetic", "A100").unwrap();
        assert!(c.records.len() > 50);
        // memoized second load returns the same Arc
        let c2 = hub2.load("synthetic", "A100").unwrap();
        assert!(Arc::ptr_eq(&c, &c2));

        // Landscapes differ across devices.
        let w = hub2.load("synthetic", "W6600").unwrap();
        assert_ne!(c.optimum_index(), w.optimum_index());

        std::fs::remove_dir_all(&dir).ok();
    }

    fn build_synthetic_hub(tag: &str) -> (std::path::PathBuf, Hub) {
        let dir = std::env::temp_dir().join(format!("tt_hub_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let hub = Hub::new(&dir);
        hub.ensure(&["synthetic"], &["A100"], Arc::new(Engine::native()), 7)
            .unwrap();
        (dir, hub)
    }

    /// The acceptance property for the binary sidecar: a hub with a
    /// fingerprint-fresh sidecar keeps loading even when the `.json.gz`
    /// is corrupted — the warm path (untouched files) never reads the
    /// JSON at all, and a JSON that is newer but unreadable falls back
    /// to the sidecar instead of taking the hub down.
    #[test]
    fn fresh_sidecar_is_served_without_touching_json() {
        let (dir, hub) = build_synthetic_hub("t4b_serve");
        let sidecar = hub.sidecar_path("synthetic", "A100");
        assert!(sidecar.exists(), "bruteforce must emit both formats");
        let want = hub.load("synthetic", "A100").unwrap();

        // Corrupt the JSON. A fresh hub handle (no memo) must still load,
        // byte-identically, from the sidecar alone.
        crate::util::fsio::atomic_write(&hub.cache_path("synthetic", "A100"), b"not gzip, not json")
            .unwrap();
        let hub2 = Hub::new(&dir);
        let got = hub2.load("synthetic", "A100").unwrap();
        assert_eq!(got.records.len(), want.records.len());
        for (a, b) in got.records.iter().zip(&want.records) {
            assert_eq!(a.key, b.key);
            assert_eq!(a.value.to_bits(), b.value.to_bits());
            assert_eq!(a.observations, b.observations);
            assert_eq!(a.compile_time.to_bits(), b.compile_time.to_bits());
            assert_eq!(a.valid, b.valid);
        }
        assert_eq!(got.bruteforce_seconds.to_bits(), want.bruteforce_seconds.to_bits());

        // The warm path proper: with the JSON *gone* the load can only
        // succeed by serving the sidecar without ever touching the JSON.
        std::fs::remove_file(hub.cache_path("synthetic", "A100")).unwrap();
        let hub3 = Hub::new(&dir);
        let warm = hub3.load("synthetic", "A100").unwrap();
        assert_eq!(warm.records.len(), want.records.len());
        assert_eq!(warm.optimum().to_bits(), want.optimum().to_bits());

        std::fs::remove_dir_all(&dir).ok();
    }

    /// A sidecar with a stale fingerprint is rejected: the hub falls back
    /// to the JSON and rewrites a fresh sidecar.
    #[test]
    fn stale_sidecar_falls_back_to_json_and_is_rewritten() {
        let (dir, hub) = build_synthetic_hub("t4b_stale");
        let want = hub.load("synthetic", "A100").unwrap();
        let sidecar = hub.sidecar_path("synthetic", "A100");

        // Overwrite the sidecar under a wrong fingerprint.
        super::t4b::write(&want, "stale-fingerprint", super::t4b::SrcStamp::NONE, &sidecar)
            .unwrap();
        let hub2 = Hub::new(&dir);
        let got = hub2.load("synthetic", "A100").unwrap();
        assert_eq!(got.records.len(), want.records.len());
        // The fallback parse rewrote the sidecar with the live fingerprint.
        let fp = crate::kernels::kernel_by_name("synthetic")
            .unwrap()
            .space()
            .fingerprint();
        let (_, written_fp, _) = super::t4b::read(&sidecar).unwrap();
        assert_eq!(written_fp, fp);

        std::fs::remove_dir_all(&dir).ok();
    }

    /// A re-measured JSON dropped next to an older sidecar (same space,
    /// same fingerprint — only the recorded source stamp distinguishes
    /// it) must win: the hub re-parses the JSON and refreshes the
    /// sidecar.
    #[test]
    fn updated_json_wins_over_older_sidecar() {
        let (dir, hub) = build_synthetic_hub("t4b_mtime");
        let original = hub.load("synthetic", "A100").unwrap();
        let sidecar = hub.sidecar_path("synthetic", "A100");
        let json_path = hub.cache_path("synthetic", "A100");
        let (_, _, recorded) = super::t4b::read(&sidecar).unwrap();
        assert!(recorded.is_known(), "hub sidecars carry a source stamp");

        // "Re-measure": same space, perturbed values.
        let mut updated = (*original).clone();
        for r in &mut updated.records {
            if r.valid {
                r.value *= 2.0;
            }
        }
        // Save until the JSON's identity differs from the recorded stamp
        // (guards against coarse filesystem timestamp granularity in the
        // astronomically unlikely same-size case).
        for _ in 0..200 {
            updated.save(&json_path).unwrap();
            if super::t4b::SrcStamp::of(&json_path) != recorded {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        assert_ne!(
            super::t4b::SrcStamp::of(&json_path),
            recorded,
            "stamp setup failed"
        );

        let hub2 = Hub::new(&dir);
        let got = hub2.load("synthetic", "A100").unwrap();
        assert_eq!(
            got.optimum().to_bits(),
            (original.optimum() * 2.0).to_bits(),
            "updated JSON must be served over the stale sidecar"
        );
        // And the sidecar was refreshed from the new JSON.
        let (from_sidecar, _, _) = super::t4b::read(&sidecar).unwrap();
        assert_eq!(from_sidecar.records.len(), got.records.len());
        assert_eq!(
            from_sidecar.optimum().to_bits(),
            got.optimum().to_bits()
        );

        std::fs::remove_dir_all(&dir).ok();
    }

    /// A hub populated before the sidecar format existed (JSON only)
    /// grows a sidecar on first load.
    #[test]
    fn json_only_hub_gains_sidecar_on_first_load() {
        let (dir, hub) = build_synthetic_hub("t4b_gain");
        let sidecar = hub.sidecar_path("synthetic", "A100");
        std::fs::remove_file(&sidecar).unwrap();

        let hub2 = Hub::new(&dir);
        hub2.load("synthetic", "A100").unwrap();
        assert!(sidecar.exists(), "JSON parse must write the sidecar");

        std::fs::remove_dir_all(&dir).ok();
    }
}
