//! T1-style input descriptions.
//!
//! The hub stores, next to each brute-force output, a JSON document
//! describing the tuning problem (kernel name, problem size, tunable
//! parameters and their values, constraint expressions) in the spirit of
//! the T1 format of "FAIR sharing of data in autotuning research", so
//! other tuners can reconstruct the search space.

use crate::kernels::Kernel;
use crate::searchspace::{SearchSpace, TunableParam, Value};
use crate::util::json::{self, Json};
use crate::error::{Context, Result};

/// Serialize a kernel's tuning problem to a T1-style JSON document.
pub fn to_t1(kernel: &Kernel) -> Json {
    let space = kernel.space();
    let mut params = Json::obj();
    for p in &space.params {
        let vals: Vec<Json> = p
            .values
            .iter()
            .map(|v| match v {
                Value::Int(i) => Json::Num(*i as f64),
                Value::Float(x) => Json::Num(*x),
                Value::Bool(b) => Json::Bool(*b),
                Value::Str(s) => Json::Str(s.clone()),
            })
            .collect();
        params.set(&p.name, Json::Arr(vals));
    }
    let constraints: Vec<Json> = space
        .constraints
        .iter()
        .map(|c| Json::Str(c.source.clone()))
        .collect();
    let mut j = Json::obj();
    j.set("schema", "tunetuner-T1".into())
        .set("schema_version", 1usize.into())
        .set("kernel_name", kernel.name.into())
        .set("problem", kernel.problem.as_str().into())
        .set("configuration_space", params)
        .set("constraints", Json::Arr(constraints))
        .set("objective", "time".into())
        .set("minimize", true.into());
    j
}

/// Rebuild a search space from a T1 document (values become Int when
/// integral, Float otherwise; strings and bools pass through).
pub fn space_from_t1(doc: &Json) -> Result<SearchSpace> {
    let name = doc
        .get("kernel_name")
        .and_then(|v| v.as_str())
        .context("T1 missing kernel_name")?;
    let cfg = doc
        .get("configuration_space")
        .and_then(|v| v.as_obj())
        .context("T1 missing configuration_space")?;
    let mut params = Vec::new();
    for (pname, vals) in cfg {
        let arr = vals.as_arr().context("parameter values must be an array")?;
        let values: Vec<Value> = arr
            .iter()
            .map(|v| match v {
                Json::Num(x) if x.fract() == 0.0 => Value::Int(*x as i64),
                Json::Num(x) => Value::Float(*x),
                Json::Bool(b) => Value::Bool(*b),
                Json::Str(s) => Value::Str(s.clone()),
                _ => Value::Int(0),
            })
            .collect();
        params.push(TunableParam {
            name: pname.clone(),
            values,
        });
    }
    let mut constraints = Vec::new();
    if let Some(arr) = doc.get("constraints").and_then(|v| v.as_arr()) {
        for c in arr {
            constraints.push(crate::searchspace::Constraint::parse(
                c.as_str().context("constraint must be a string")?,
            )?);
        }
    }
    SearchSpace::build(name, params, constraints)
}

/// Round-trip helper used by the hub.
pub fn write_t1(kernel: &Kernel, path: &std::path::Path) -> Result<()> {
    crate::util::compress::write_string(path, &to_t1(kernel).to_pretty())
}

pub fn read_t1(path: &std::path::Path) -> Result<SearchSpace> {
    let text = crate::util::compress::read_string(path)?;
    space_from_t1(&json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    #[test]
    fn t1_roundtrip_preserves_space() {
        for name in ["synthetic", "gemm"] {
            let k = kernels::kernel_by_name(name).unwrap();
            let doc = to_t1(&k);
            let rebuilt = space_from_t1(&doc).unwrap();
            // BTreeMap reorders parameters, so compare sizes and per-config
            // membership rather than index order.
            assert_eq!(rebuilt.len(), k.space().len(), "{name}");
            assert_eq!(rebuilt.cartesian_size(), k.space().cartesian_size());
        }
    }

    #[test]
    fn t1_has_constraints() {
        let k = kernels::kernel_by_name("gemm").unwrap();
        let doc = to_t1(&k);
        let cs = doc.get("constraints").unwrap().as_arr().unwrap();
        assert!(!cs.is_empty());
    }
}
