//! `SimTable`: the columnar, precomputed evaluation table behind
//! simulation mode.
//!
//! The simulator's throughput is the denominator of everything this repo
//! does: every meta-strategy sweep and every Table III/IV cell is millions
//! of `evaluate_lite` calls. Replaying those through the AoS
//! [`ConfigRecord`](super::cache::ConfigRecord)s means a pointer chase
//! into each record plus a 32-element observation-vector re-sum *per
//! lookup* to recompute the total cost. The `SimTable` hoists all of that
//! into one build pass per [`CacheData`](super::cache::CacheData):
//!
//! * **Interleaved `(value, total_cost)` pairs** in one contiguous buffer
//!   — `SimulationRunner::evaluate_lite` becomes a single indexed load
//!   (16 bytes, one cache line shared by adjacent configs), with cost
//!   precomputed as `compile + Σobs + overhead` in exactly the summation
//!   order the per-call path used, so replayed clocks are bit-identical.
//! * **A validity bitset** (one bit per config).
//! * **Memoized baseline statistics** — `sorted_valid_values`, `optimum`,
//!   `optimum_index`, `mean_eval_cost`, `valid_fraction` — which were
//!   previously recomputed O(n log n) per `Baseline::new` and O(n) per
//!   hub-index write or test-quality call.
//!
//! The table is built lazily on first use and `Arc`-shared (the same
//! pattern as the CSR neighbor graphs on `SearchSpace`): campaigns build
//! it once on the preparing thread, and the spaces×repeats executor jobs
//! share it read-only.

use super::cache::CacheData;
use crate::runner::live::FRAMEWORK_OVERHEAD;

/// Columnar evaluation table derived from one brute-force cache.
#[derive(Debug)]
pub struct SimTable {
    /// Interleaved `(mean value, total simulated cost)` per config, in
    /// search-space index order. Cost includes [`FRAMEWORK_OVERHEAD`].
    vc: Vec<(f64, f64)>,
    /// Validity bitset: bit `i` of word `i / 64` is set iff config `i`
    /// launched successfully.
    valid: Vec<u64>,
    /// Number of valid configurations.
    pub n_valid: usize,
    /// Mean values of the valid configurations, ascending.
    pub sorted_valid_values: Vec<f64>,
    /// Lowest valid mean value (INFINITY if nothing is valid).
    pub optimum: f64,
    /// Index of the optimal configuration (0 if nothing is valid).
    pub optimum_index: usize,
    /// Mean simulated cost of one evaluation at [`FRAMEWORK_OVERHEAD`].
    pub mean_eval_cost: f64,
    /// Fraction of configurations that launch.
    pub valid_fraction: f64,
}

impl SimTable {
    /// One build pass over the records. Every statistic is computed with
    /// the same fold order as the former per-call `CacheData` methods, so
    /// everything downstream (baseline budgets, replayed clocks) is
    /// bit-identical to the pre-table code.
    pub fn build(cache: &CacheData) -> SimTable {
        let n = cache.records.len();
        let mut vc = Vec::with_capacity(n);
        let mut valid = vec![0u64; (n + 63) / 64];
        let mut n_valid = 0usize;
        let mut optimum_index = 0usize;
        let mut optimum = f64::INFINITY;
        for (i, r) in cache.records.iter().enumerate() {
            vc.push((r.value, r.total_cost(FRAMEWORK_OVERHEAD)));
            if r.valid {
                valid[i >> 6] |= 1u64 << (i & 63);
                n_valid += 1;
                if r.value < optimum {
                    optimum = r.value;
                    optimum_index = i;
                }
            }
        }
        let mut sorted_valid_values: Vec<f64> = cache
            .records
            .iter()
            .filter(|r| r.valid)
            .map(|r| r.value)
            .collect();
        sorted_valid_values.sort_by(f64::total_cmp);
        let mean_eval_cost = vc.iter().map(|&(_, c)| c).sum::<f64>() / n as f64;
        let valid_fraction = n_valid as f64 / n as f64;
        SimTable {
            vc,
            valid,
            n_valid,
            sorted_valid_values,
            optimum,
            optimum_index,
            mean_eval_cost,
            valid_fraction,
        }
    }

    /// Number of configurations.
    #[inline]
    pub fn len(&self) -> usize {
        self.vc.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.vc.is_empty()
    }

    /// The simulation hot path: `(value, total_cost)` as one indexed load
    /// from the interleaved buffer — no record pointer chase, no
    /// observation traversal, no allocation.
    #[inline]
    pub fn lookup(&self, idx: usize) -> (f64, f64) {
        self.vc[idx]
    }

    /// Mean value of a configuration (INFINITY for invalid configs).
    #[inline]
    pub fn value(&self, idx: usize) -> f64 {
        self.vc[idx].0
    }

    /// Total simulated cost of evaluating a configuration.
    #[inline]
    pub fn cost(&self, idx: usize) -> f64 {
        self.vc[idx].1
    }

    /// Whether a configuration launched successfully.
    #[inline]
    pub fn is_valid(&self, idx: usize) -> bool {
        self.valid[idx >> 6] & (1u64 << (idx & 63)) != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::cache::ConfigRecord;

    fn sample() -> CacheData {
        CacheData::new(
            "t",
            "d",
            "p",
            1,
            3,
            0.0,
            vec!["a".into()],
            vec![
                ConfigRecord {
                    key: "1".into(),
                    value: 0.5,
                    observations: vec![0.4, 0.5, 0.6],
                    compile_time: 2.0,
                    valid: true,
                },
                ConfigRecord {
                    key: "2".into(),
                    value: f64::INFINITY,
                    observations: vec![],
                    compile_time: 3.0,
                    valid: false,
                },
                ConfigRecord {
                    key: "3".into(),
                    value: 0.25,
                    observations: vec![0.2, 0.25, 0.3],
                    compile_time: 1.5,
                    valid: true,
                },
            ],
        )
    }

    #[test]
    fn table_matches_record_walk() {
        let cache = sample();
        let t = SimTable::build(&cache);
        assert_eq!(t.len(), 3);
        for (i, r) in cache.records.iter().enumerate() {
            assert_eq!(t.value(i).to_bits(), r.value.to_bits());
            assert_eq!(
                t.cost(i).to_bits(),
                r.total_cost(FRAMEWORK_OVERHEAD).to_bits()
            );
            assert_eq!(t.is_valid(i), r.valid);
            assert_eq!(t.lookup(i), (t.value(i), t.cost(i)));
        }
        assert_eq!(t.n_valid, 2);
        assert_eq!(t.optimum, 0.25);
        assert_eq!(t.optimum_index, 2);
        assert_eq!(t.sorted_valid_values, vec![0.25, 0.5]);
        assert!((t.valid_fraction - 2.0 / 3.0).abs() < 1e-12);
        // Mean cost folds in the same order the per-record walk did.
        let want = cache
            .records
            .iter()
            .map(|r| r.total_cost(FRAMEWORK_OVERHEAD))
            .sum::<f64>()
            / 3.0;
        assert_eq!(t.mean_eval_cost.to_bits(), want.to_bits());
    }

    #[test]
    fn arc_shared_and_lazy_on_cache() {
        let cache = sample();
        let a = std::sync::Arc::clone(cache.sim_table());
        let b = std::sync::Arc::clone(cache.sim_table());
        assert!(std::sync::Arc::ptr_eq(&a, &b), "built once, shared");
        // A clone starts with a fresh (unbuilt) memo.
        let cloned = cache.clone();
        let c = std::sync::Arc::clone(cloned.sim_table());
        assert!(!std::sync::Arc::ptr_eq(&a, &c));
        assert_eq!(c.optimum, a.optimum);
    }
}
