//! T4B: the binary columnar sidecar of the T4 JSON cache.
//!
//! Parsing a multi-MB gzipped JSON document per (kernel, device) space is
//! the dominant cost of campaign startup on a warm hub. The T4B sidecar
//! stores the same `CacheData` — field for field, including infinities
//! and empty observation vectors — as flat little-endian sections that
//! decode with `memcpy`-shaped loops, plus the structural fingerprint of
//! the search space it indexes, so a stale sidecar is detected without
//! touching the JSON. The header also records the `(size, mtime)`
//! identity of the source JSON, so a dropped-in re-measured cache (same
//! space fingerprint, different bytes) is detected exactly. The hub
//! loads `<DEVICE>.t4b` when it is present, fingerprint-fresh, and still
//! mirrors the JSON next to it (never parsing the JSON at all on that
//! path) and writes one after any JSON parse; `tunetuner bruteforce`
//! emits both formats up front.
//!
//! # Layout (version 1, all integers/floats little-endian)
//!
//! Strings are `u32` byte length followed by UTF-8 bytes. With `n` the
//! record count and `w = ceil(n / 64)`:
//!
//! | offset        | size          | field                                   |
//! |---------------|---------------|-----------------------------------------|
//! | 0             | 8             | magic `"TUNET4B\0"`                     |
//! | 8             | 4             | format version (`u32`, = 1)             |
//! | 12            | …             | space fingerprint (string)              |
//! | …             | 8             | source JSON byte size (`u64`, 0=unknown)|
//! | …             | 8             | source JSON mtime, ns since epoch (`u64`, 0=unknown) |
//! | …             | …             | kernel, device, problem (3 strings)     |
//! | …             | 8             | `space_seed` (`u64`)                    |
//! | …             | 8             | `observations_per_config` (`u64`)       |
//! | …             | 8             | `bruteforce_seconds` (`f64`)            |
//! | …             | 4 + …         | param count (`u32`) + names (strings)   |
//! | …             | 8             | `n` — record count (`u64`)              |
//! | …             | 8·n           | values (`f64`; INFINITY when invalid)   |
//! | …             | 8·n           | compile times (`f64`)                   |
//! | …             | 8·w           | validity bitset (`u64` words)           |
//! | …             | 8·(n+1)       | observation offsets (`u64`, monotone)   |
//! | …             | 8·offs[n]     | flattened observations (`f64`)          |
//! | …             | 8·(n+1)       | key byte offsets (`u64`, monotone)      |
//! | …             | koffs[n]      | key blob (UTF-8 bytes)                  |
//!
//! The file ends exactly at the key blob — trailing bytes are a decode
//! error, as is any section that would read past the end, so a torn or
//! foreign file can never half-decode. Writers stage through a temp file
//! and `rename` so a crashed write never shadows the JSON.

use super::cache::{CacheData, ConfigRecord};
use crate::error::{Result, TuneError};
use std::path::{Path, PathBuf};

/// File magic, first 8 bytes.
pub const MAGIC: [u8; 8] = *b"TUNET4B\0";

/// Format version written by [`encode`].
pub const VERSION: u32 = 1;

/// Identity stamp of the source JSON a sidecar was converted from. The
/// sidecar only mirrors that JSON: a replaced or re-measured JSON keeps
/// the same space fingerprint, so `(size, mtime)` is what distinguishes
/// it — exact equality, immune to filesystem timestamp granularity (an
/// mtime *comparison* can tie on coarse-granularity filesystems).
/// `NONE` (both zero) means "unknown"; readers then fall back to
/// whatever freshness policy suits them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SrcStamp {
    /// Source file size in bytes.
    pub size: u64,
    /// Source file mtime in nanoseconds since the epoch (truncated to
    /// u64 — equality-compared only, and both sides truncate alike).
    pub mtime_ns: u64,
}

impl SrcStamp {
    /// No stamp recorded (standalone writes, unreadable metadata).
    pub const NONE: SrcStamp = SrcStamp {
        size: 0,
        mtime_ns: 0,
    };

    /// Best-effort stamp of a file on disk; `NONE` if unreadable.
    pub fn of(path: &Path) -> SrcStamp {
        let stamp = || -> Option<SrcStamp> {
            let meta = std::fs::metadata(path).ok()?;
            let mtime = meta.modified().ok()?;
            let ns = mtime
                .duration_since(std::time::UNIX_EPOCH)
                .ok()?
                .as_nanos() as u64;
            Some(SrcStamp {
                size: meta.len(),
                mtime_ns: ns,
            })
        };
        stamp().unwrap_or(SrcStamp::NONE)
    }

    pub fn is_known(&self) -> bool {
        *self != SrcStamp::NONE
    }
}

/// Sidecar path next to a JSON cache file: `<stem>.t4b` with the
/// `.json` / `.json.gz` suffix stripped (`A100.json.gz` → `A100.t4b`).
pub fn sidecar_path(cache_path: &Path) -> PathBuf {
    let name = cache_path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("cache");
    let stem = name
        .strip_suffix(".json.gz")
        .or_else(|| name.strip_suffix(".json"))
        .unwrap_or(name);
    cache_path.with_file_name(format!("{stem}.t4b"))
}

/// Serialize a cache (with the fingerprint of the space it indexes and
/// the identity stamp of the JSON it mirrors) to the T4B byte layout
/// documented in the module docs.
pub fn encode(cache: &CacheData, fingerprint: &str, src: SrcStamp) -> Vec<u8> {
    let n = cache.records.len();
    let obs_total: usize = cache.records.iter().map(|r| r.observations.len()).sum();
    let key_total: usize = cache.records.iter().map(|r| r.key.len()).sum();
    let mut buf = Vec::with_capacity(80 + 8 * (4 * n + obs_total) + key_total);
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    put_str(&mut buf, fingerprint);
    buf.extend_from_slice(&src.size.to_le_bytes());
    buf.extend_from_slice(&src.mtime_ns.to_le_bytes());
    put_str(&mut buf, &cache.kernel);
    put_str(&mut buf, &cache.device);
    put_str(&mut buf, &cache.problem);
    buf.extend_from_slice(&cache.space_seed.to_le_bytes());
    buf.extend_from_slice(&(cache.observations_per_config as u64).to_le_bytes());
    buf.extend_from_slice(&cache.bruteforce_seconds.to_le_bytes());
    buf.extend_from_slice(&(cache.param_names.len() as u32).to_le_bytes());
    for p in &cache.param_names {
        put_str(&mut buf, p);
    }
    buf.extend_from_slice(&(n as u64).to_le_bytes());
    for r in &cache.records {
        buf.extend_from_slice(&r.value.to_le_bytes());
    }
    for r in &cache.records {
        buf.extend_from_slice(&r.compile_time.to_le_bytes());
    }
    let mut words = vec![0u64; (n + 63) / 64];
    for (i, r) in cache.records.iter().enumerate() {
        if r.valid {
            words[i >> 6] |= 1u64 << (i & 63);
        }
    }
    for w in words {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    let mut off = 0u64;
    buf.extend_from_slice(&off.to_le_bytes());
    for r in &cache.records {
        off += r.observations.len() as u64;
        buf.extend_from_slice(&off.to_le_bytes());
    }
    for r in &cache.records {
        for &x in &r.observations {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    let mut koff = 0u64;
    buf.extend_from_slice(&koff.to_le_bytes());
    for r in &cache.records {
        koff += r.key.len() as u64;
        buf.extend_from_slice(&koff.to_le_bytes());
    }
    for r in &cache.records {
        buf.extend_from_slice(r.key.as_bytes());
    }
    buf
}

/// Decode a T4B buffer into the cache, the fingerprint it was written
/// under, and the source-JSON stamp. Strict: bad magic/version, truncated
/// sections, non-monotone offsets, invalid UTF-8 and trailing bytes are
/// all [`TuneError::Parse`].
pub fn decode(buf: &[u8]) -> Result<(CacheData, String, SrcStamp)> {
    let mut c = Cursor { buf, pos: 0 };
    if c.bytes(8)? != MAGIC {
        return Err(TuneError::Parse("not a T4B file (bad magic)".into()));
    }
    let version = c.u32()?;
    if version != VERSION {
        return Err(TuneError::Parse(format!(
            "unsupported T4B version {version} (expected {VERSION})"
        )));
    }
    let fingerprint = c.string()?;
    let src = SrcStamp {
        size: c.u64()?,
        mtime_ns: c.u64()?,
    };
    let kernel = c.string()?;
    let device = c.string()?;
    let problem = c.string()?;
    let space_seed = c.u64()?;
    let observations_per_config = c.u64()? as usize;
    let bruteforce_seconds = c.f64()?;
    let n_params = c.u32()? as usize;
    let mut param_names = Vec::with_capacity(n_params.min(1 << 16));
    for _ in 0..n_params {
        param_names.push(c.string()?);
    }
    let n = c.u64()? as usize;
    // Sanity-bound n by what the remaining bytes could possibly hold
    // (values alone are 8n) so a corrupt count can't drive a huge alloc.
    if n > c.remaining() / 8 {
        return Err(TuneError::Parse(format!(
            "T4B record count {n} exceeds file size"
        )));
    }
    let values = c.f64s(n)?;
    let compile_times = c.f64s(n)?;
    let words = c.u64s((n + 63) / 64)?;
    let obs_offsets = c.u64s(n + 1)?;
    let obs_total = monotone_last(&obs_offsets, "observation")?;
    // Bound like `n` above: an unchecked total would overflow the `8 * n`
    // multiply inside the reader before its own range check fires.
    if obs_total > c.remaining() / 8 {
        return Err(TuneError::Parse(format!(
            "T4B observation total {obs_total} exceeds file size"
        )));
    }
    let obs = c.f64s(obs_total)?;
    let key_offsets = c.u64s(n + 1)?;
    let key_total = monotone_last(&key_offsets, "key")?;
    let key_blob = c.bytes(key_total)?;
    if c.pos != buf.len() {
        return Err(TuneError::Parse(format!(
            "trailing bytes in T4B file ({} past the key blob)",
            buf.len() - c.pos
        )));
    }
    let mut records = Vec::with_capacity(n);
    for i in 0..n {
        let raw_key = &key_blob[key_offsets[i] as usize..key_offsets[i + 1] as usize];
        let key = std::str::from_utf8(raw_key)
            .map_err(|e| TuneError::Parse(format!("T4B record {i}: key is not UTF-8: {e}")))?
            .to_string();
        records.push(ConfigRecord {
            key,
            value: values[i],
            observations: obs[obs_offsets[i] as usize..obs_offsets[i + 1] as usize].to_vec(),
            compile_time: compile_times[i],
            valid: words[i >> 6] & (1u64 << (i & 63)) != 0,
        });
    }
    Ok((
        CacheData::new(
            kernel,
            device,
            problem,
            space_seed,
            observations_per_config,
            bruteforce_seconds,
            param_names,
            records,
        ),
        fingerprint,
        src,
    ))
}

/// Write a sidecar atomically via [`crate::util::fsio::atomic_write`]
/// (unique pid+counter temp file + rename, the pattern this writer
/// originated): concurrent writers of the same sidecar never interleave
/// into one staging file — each rename installs some writer's complete
/// bytes.
pub fn write(cache: &CacheData, fingerprint: &str, src: SrcStamp, path: &Path) -> Result<()> {
    crate::util::fsio::atomic_write(path, &encode(cache, fingerprint, src))
}

/// Read and decode a sidecar; returns `(cache, fingerprint, src_stamp)`.
pub fn read(path: &Path) -> Result<(CacheData, String, SrcStamp)> {
    let buf = std::fs::read(path)?;
    decode(&buf).map_err(|e| e.wrap(format!("decode T4B sidecar {}", path.display())))
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Validate an offsets section (monotone non-decreasing, starts at 0)
/// and return its final value as a usize.
fn monotone_last(offsets: &[u64], what: &str) -> Result<usize> {
    if offsets.first() != Some(&0) || offsets.windows(2).any(|w| w[0] > w[1]) {
        return Err(TuneError::Parse(format!(
            "T4B {what} offsets are not monotone from 0"
        )));
    }
    Ok(offsets[offsets.len() - 1] as usize)
}

/// Bounds-checked little-endian reader over the raw file bytes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(TuneError::Parse(format!(
                "truncated T4B file: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32> {
        // lint: allow(W03, reason = "bytes(4) yields exactly 4 bytes")
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        // lint: allow(W03, reason = "bytes(8) yields exactly 8 bytes")
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|e| TuneError::Parse(format!("T4B string is not UTF-8: {e}")))
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let raw = self.bytes(8 * n)?;
        Ok(raw
            .chunks_exact(8)
            // lint: allow(W03, reason = "chunks_exact(8) yields 8-byte slices")
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        Ok(self.u64s(n)?.into_iter().map(f64::from_bits).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CacheData {
        CacheData::new(
            "synthetic",
            "A100",
            "test problem",
            0xFA1B,
            3,
            1234.5,
            vec!["a".into(), "b".into()],
            vec![
                ConfigRecord {
                    key: "1,1".into(),
                    value: 0.5,
                    observations: vec![0.4, 0.5, 0.6],
                    compile_time: 2.0,
                    valid: true,
                },
                ConfigRecord {
                    key: "1,2".into(),
                    value: f64::INFINITY,
                    observations: vec![],
                    compile_time: 3.0,
                    valid: false,
                },
                ConfigRecord {
                    key: "2,1".into(),
                    value: 0.25,
                    observations: vec![0.2, 0.25, 0.3],
                    compile_time: 1.5,
                    valid: true,
                },
            ],
        )
    }

    fn assert_cache_eq(a: &CacheData, b: &CacheData) {
        assert_eq!(a.kernel, b.kernel);
        assert_eq!(a.device, b.device);
        assert_eq!(a.problem, b.problem);
        assert_eq!(a.space_seed, b.space_seed);
        assert_eq!(a.observations_per_config, b.observations_per_config);
        assert_eq!(a.bruteforce_seconds.to_bits(), b.bruteforce_seconds.to_bits());
        assert_eq!(a.param_names, b.param_names);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.key, y.key);
            assert_eq!(x.value.to_bits(), y.value.to_bits());
            assert_eq!(x.observations, y.observations);
            assert_eq!(x.compile_time.to_bits(), y.compile_time.to_bits());
            assert_eq!(x.valid, y.valid);
        }
    }

    #[test]
    fn encode_decode_roundtrip_exact() {
        let c = sample();
        let stamp = SrcStamp {
            size: 1234,
            mtime_ns: 987_654_321,
        };
        let (back, fp, src) = decode(&encode(&c, "cafe-42", stamp)).unwrap();
        assert_eq!(fp, "cafe-42");
        assert_eq!(src, stamp);
        assert!(src.is_known());
        assert_cache_eq(&c, &back);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tt_t4b_{}", std::process::id()));
        let path = dir.join("A100.t4b");
        let c = sample();
        write(&c, "fp-1", SrcStamp::NONE, &path).unwrap();
        let (back, fp, src) = read(&path).unwrap();
        assert_eq!(fp, "fp-1");
        assert!(!src.is_known());
        assert_cache_eq(&c, &back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sidecar_path_strips_json_suffixes() {
        let p = sidecar_path(Path::new("hub/gemm/A100.json.gz"));
        assert_eq!(p, Path::new("hub/gemm/A100.t4b"));
        let p = sidecar_path(Path::new("hub/gemm/A100.json"));
        assert_eq!(p, Path::new("hub/gemm/A100.t4b"));
    }

    #[test]
    fn rejects_corruption() {
        let c = sample();
        let good = encode(&c, "fp", SrcStamp::NONE);
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(decode(&bad), Err(TuneError::Parse(_))));
        // Bad version.
        let mut bad = good.clone();
        bad[8] = 99;
        assert!(matches!(decode(&bad), Err(TuneError::Parse(_))));
        // Truncation anywhere must error, never panic or half-decode.
        for cut in [10, 20, good.len() / 2, good.len() - 1] {
            assert!(decode(&good[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage.
        let mut bad = good.clone();
        bad.push(0);
        assert!(matches!(decode(&bad), Err(TuneError::Parse(_))));
    }

    /// A corrupt final observation offset (huge but monotone) must be a
    /// Parse error, never an overflowing-multiply panic or a wild slice.
    #[test]
    fn rejects_huge_observation_offset() {
        // One record, one-byte key "k", 3 observations: the file layout
        // ends key_blob(1) | key_offsets(16) | obs(24) with obs_offsets(16)
        // right before the obs section, so obs_offsets[1] sits at a fixed
        // distance from the end.
        let c = CacheData::new(
            "s",
            "d",
            "p",
            1,
            3,
            0.0,
            vec!["a".into()],
            vec![ConfigRecord {
                key: "k".into(),
                value: 0.5,
                observations: vec![0.4, 0.5, 0.6],
                compile_time: 2.0,
                valid: true,
            }],
        );
        let mut bad = encode(&c, "fp", SrcStamp::NONE);
        assert_eq!(decode(&bad).unwrap().0.records[0].observations.len(), 3);
        let pos = bad.len() - 1 - 16 - 24 - 8;
        bad[pos..pos + 8].copy_from_slice(&((1u64 << 61) + 5).to_le_bytes());
        assert!(matches!(decode(&bad), Err(TuneError::Parse(_))));
    }
}
