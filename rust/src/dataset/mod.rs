//! The FAIR benchmark hub: brute-forced search-space datasets.
//!
//! * [`cache`] — the per-(kernel, device) cache file: every configuration's
//!   32 raw observations, mean, compile time and validity, in a T4-style
//!   JSON schema, gzip-compressed on disk.
//! * [`bruteforce`] — exhaustively evaluates a search space through the
//!   live runner (batched through the PJRT engine) and records the
//!   simulated device-hours (Table II).
//! * [`t1`] — the T1-style input description (kernel, parameters,
//!   constraints) written next to each cache for interoperability.
//! * [`hub`] — the on-disk hub layout: build, save, load, and index the
//!   24 (kernel × device) search spaces.

pub mod cache;
pub mod bruteforce;
pub mod t1;
pub mod hub;

pub use cache::{CacheData, ConfigRecord};
pub use hub::Hub;
