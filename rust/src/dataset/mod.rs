//! The FAIR benchmark hub: brute-forced search-space datasets.
//!
//! * [`cache`] — the per-(kernel, device) cache file: every configuration's
//!   32 raw observations, mean, compile time and validity, in a T4-style
//!   JSON schema, gzip-compressed on disk.
//! * [`bruteforce`] — exhaustively evaluates a search space through the
//!   live runner (batched through the PJRT engine) and records the
//!   simulated device-hours (Table II).
//! * [`simtable`] — the columnar, precomputed evaluation table behind
//!   simulation mode: interleaved `(value, total_cost)` pairs, a validity
//!   bitset, and memoized baseline statistics, built lazily once per
//!   cache and `Arc`-shared across runs.
//! * [`t4b`] — the binary columnar sidecar of the JSON cache (layout
//!   documented byte-by-byte in the module docs): fingerprint-stamped,
//!   loaded by the hub instead of re-parsing JSON on every startup.
//! * [`t1`] — the T1-style input description (kernel, parameters,
//!   constraints) written next to each cache for interoperability.
//! * [`hub`] — the on-disk hub layout: build, save, load, and index the
//!   24 (kernel × device) search spaces. Serves the `.t4b` sidecar when
//!   it is fingerprint-fresh and writes one after any JSON parse.
//! * [`synth`] — deterministic synthetic caches for generated
//!   ([`crate::searchspace::spacegen`]) spaces, so simulated campaigns run
//!   at million-config scale without brute-forcing real kernels.

pub mod cache;
pub mod simtable;
pub mod t4b;
pub mod bruteforce;
pub mod t1;
pub mod hub;
pub mod synth;

pub use cache::{CacheData, ConfigRecord};
pub use hub::Hub;
pub use simtable::SimTable;
pub use synth::synth_cache;
