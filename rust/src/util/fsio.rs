//! Crash-safe persistence: every artifact the tuner writes (envelope
//! JSON, `.json.gz` caches, T4B sidecars) goes through [`atomic_write`] —
//! the staged-temp-plus-rename pattern generalized from the T4B sidecar
//! writer. The temp name carries pid + a process-wide counter so
//! concurrent writers of the same path never interleave into one staging
//! file; each rename installs some writer's *complete* bytes, and a
//! crash (or an injected [`crate::faults`] truncation) mid-stage leaves
//! the previously installed file untouched.

use crate::error::Result;
use crate::faults::FaultPlan;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Unique staging path next to `path`: `<stem>.tmp.<pid>.<seq>`.
fn staging_path(path: &Path) -> PathBuf {
    path.with_extension(format!(
        "tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// Write `bytes` to `path` atomically: stage into a unique temp file in
/// the same directory, then rename over the target. Readers only ever
/// see the old complete file or the new complete file — never a
/// truncated mix. Consults the process-global [`crate::faults`] plan for
/// injected save faults (chaos testing); library callers that hold an
/// explicit plan use [`atomic_write_with`].
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    atomic_write_with(path, bytes, crate::faults::global().as_deref())
}

/// [`atomic_write`] with an explicit fault plan (None = no injection).
/// An injected `truncate-save` fault simulates a crash mid-stage: a
/// truncated temp file is left behind (harmless debris, never renamed)
/// and the write reports an `Io` error — the previous file at `path`
/// stays intact, which is exactly the property the resume path depends
/// on.
// The one place raw writes are allowed: everything else goes through here
// (clippy's disallowed_methods and the lint engine's W02 both point at it).
#[allow(clippy::disallowed_methods)]
pub fn atomic_write_with(path: &Path, bytes: &[u8], faults: Option<&FaultPlan>) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let tmp = staging_path(path);
    if let Some(plan) = faults {
        if plan.save_fault() {
            let cut = bytes.len() / 2;
            std::fs::write(&tmp, &bytes[..cut]).ok();
            return Err(std::io::Error::other(format!(
                "injected fault: truncated write of {} ({} of {} bytes staged)",
                path.display(),
                cut,
                bytes.len()
            ))
            .into());
        }
    }
    let staged = std::fs::write(&tmp, bytes).and_then(|_| std::fs::rename(&tmp, path));
    if staged.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    staged?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tunetuner_fsio_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn atomic_write_creates_and_replaces() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("artifact.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer contents").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer contents");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn atomic_write_creates_missing_parent_dirs() {
        let dir = tmp_dir("parents");
        let path = dir.join("a/b/c.bin");
        atomic_write(&path, &[1, 2, 3]).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), vec![1, 2, 3]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The satellite regression: a truncated-write fault mid-save must
    /// leave the previously installed file intact (the old non-atomic
    /// `File::create` path would have destroyed it first).
    #[test]
    fn truncated_save_fault_leaves_previous_file_intact() {
        let dir = tmp_dir("truncate");
        let path = dir.join("envelope.json");
        atomic_write_with(&path, b"the good envelope", None).unwrap();

        let plan = FaultPlan::parse("truncate-save@*").unwrap();
        let err = atomic_write_with(&path, b"the replacement that crashes", Some(&plan))
            .expect_err("injected truncation must report an error");
        assert!(
            err.to_string().contains("injected fault"),
            "unexpected error: {err}"
        );
        assert_eq!(
            std::fs::read(&path).unwrap(),
            b"the good envelope",
            "previous file must survive a truncated save"
        );

        // The fault spec fires once; the retry goes through cleanly.
        atomic_write_with(&path, b"the replacement", Some(&plan)).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"the replacement");
        std::fs::remove_dir_all(&dir).ok();
    }
}
