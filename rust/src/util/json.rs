//! Minimal JSON: value model, recursive-descent parser, writer.
//!
//! Backs the T1/T4 interchange formats, the benchmark hub metadata, and
//! the artifact contract check. Serde is unavailable offline, so this is
//! a small, strict implementation: UTF-8 input, `\uXXXX` escapes (with
//! surrogate pairs), no trailing commas, no comments.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value);
            }
            // lint: allow(W03, reason = "documented contract: set requires an object")
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["results", "0", "time"])` walks objects and arrays.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with two-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&format_number(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

/// Numbers: integers print without a fraction; NaN/inf become null
/// (JSON has no representation for them).
fn format_number(x: f64) -> String {
    if !x.is_finite() {
        return "null".to_string();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        // Shortest round-trip representation.
        let s = format!("{x}");
        debug_assert!(s.parse::<f64>().map(|y| y == x).unwrap_or(false));
        s
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1; // past 'u'
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if !self.bytes[self.pos..].starts_with(b"\\u") {
                                    return Err(self.err("unpaired surrogate"));
                                }
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code)
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                            continue; // hex4 consumed everything
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1; // past the single-char escape
                }
                Some(b) if b < 0x80 => {
                    // Fast path: batch-copy a run of plain ASCII.
                    let start = self.pos;
                    while let Some(&c) = self.bytes.get(self.pos) {
                        if c == b'"' || c == b'\\' || c >= 0x80 {
                            break;
                        }
                        self.pos += 1;
                    }
                    // Input arrived as &str, so this slice is valid UTF-8.
                    out.push_str(unsafe {
                        std::str::from_utf8_unchecked(&self.bytes[start..self.pos])
                    });
                }
                Some(lead) => {
                    // Multi-byte scalar: width from the leading byte.
                    let width = match lead {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + width).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[self.pos..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        // lint: allow(W03, reason = "digit bytes are ASCII, always valid UTF-8")
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e-3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "src={src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.75e2}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.at(&["a", "2", "b"]).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-275.0));
        let back = parse(&v.to_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        let v = parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
        // surrogate pair for 😀 (U+1F600)
        let v = parse("\"\\ud83d\\ude00!\"").unwrap();
        assert_eq!(v.as_str(), Some("😀!"));
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "[1] x", "{'a':1}"] {
            assert!(parse(src).is_err(), "should reject {src:?}");
        }
    }

    #[test]
    fn integers_stay_integers() {
        let v = Json::Num(12345.0);
        assert_eq!(v.to_string(), "12345");
        let v = Json::Num(0.5);
        assert_eq!(v.to_string(), "0.5");
    }

    #[test]
    fn nonfinite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn object_builder() {
        let mut o = Json::obj();
        o.set("x", 1.0.into()).set("y", "z".into());
        assert_eq!(o.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn stable_key_order() {
        let v = parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }
}
