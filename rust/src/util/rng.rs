//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded through splitmix64. Every stochastic component in
//! the framework (optimizers, the noise model, the brute-forcer) derives
//! its stream from explicit seeds so that whole tuning runs — and the
//! published dataset — are bit-reproducible.

/// splitmix64 step; used for seeding and for stateless hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of two values; used for per-(space, config, rep)
/// noise seeds and the per-config landscape hashes.
#[inline]
pub fn mix64(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.rotate_left(23) ^ 0x9E37_79B9_7F4A_7C15;
    let mut z = splitmix64(&mut s);
    z ^= splitmix64(&mut s);
    z
}

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(mix64(self.next_u64(), tag))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Log-normal with multiplicative sigma around 1.0:
    /// `exp(N(0, sigma) - sigma^2/2)` so the mean stays ~1.
    pub fn lognormal_unit(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - sigma * sigma / 2.0).exp()
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// k distinct indices sampled from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // For small k relative to n use rejection; otherwise shuffle.
        if k * 4 <= n {
            // Membership-only use (iteration order never observed), but
            // FastSet keeps the whole module std-HashSet-free (W01).
            let mut seen =
                crate::util::hash::FastSet::with_capacity_and_hasher(k, Default::default());
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        } else {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_no_bias_smoke() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 800, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn lognormal_mean_near_one() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += rng.lognormal_unit(0.1);
        }
        assert!((s / n as f64 - 1.0).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        for &(n, k) in &[(100, 5), (10, 10), (1000, 400)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn mix64_spreads() {
        assert_ne!(mix64(0, 0), mix64(0, 1));
        assert_ne!(mix64(1, 0), mix64(0, 1));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(21);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
