//! Gzip-compressed file I/O for the benchmark hub.
//!
//! The paper's hub compresses the brute-force output files ("to optimize
//! storage and portability, output files are compressed and decompressed
//! automatically"); we do the same with flate2. Paths ending in `.gz` are
//! compressed transparently by [`write_string`] / [`read_string`].
//!
//! All writes are staged through [`super::fsio::atomic_write`]: the
//! bytes (compressed or not) are fully assembled in memory, written to a
//! unique temp file, and renamed over the target — a crash mid-save can
//! never leave a truncated envelope or cache behind.

use crate::error::{Context, Result};
use flate2::read::GzDecoder;
use flate2::write::GzEncoder;
use flate2::Compression;
use std::io::{Read, Write};
use std::path::Path;

/// Write a string atomically; gzip if the extension is `.gz`.
pub fn write_string(path: &Path, contents: &str) -> Result<()> {
    if path.extension().map(|e| e == "gz").unwrap_or(false) {
        let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(contents.as_bytes())?;
        let bytes = enc.finish()?;
        super::fsio::atomic_write(path, &bytes)
            .with_context(|| format!("write {}", path.display()))
    } else {
        super::fsio::atomic_write(path, contents.as_bytes())
            .with_context(|| format!("write {}", path.display()))
    }
}

/// Read a string; gunzip if the extension is `.gz`.
pub fn read_string(path: &Path) -> Result<String> {
    let raw = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    if path.extension().map(|e| e == "gz").unwrap_or(false) {
        let mut dec = GzDecoder::new(&raw[..]);
        let mut out = String::new();
        dec.read_to_string(&mut out)
            .with_context(|| format!("gunzip {}", path.display()))?;
        Ok(out)
    } else {
        String::from_utf8(raw).context("invalid utf-8")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain_and_gz() {
        let dir = std::env::temp_dir().join(format!("tt_compress_{}", std::process::id()));
        let payload = "hello world ".repeat(1000);

        let plain = dir.join("x.json");
        write_string(&plain, &payload).unwrap();
        assert_eq!(read_string(&plain).unwrap(), payload);

        let gz = dir.join("x.json.gz");
        write_string(&gz, &payload).unwrap();
        assert_eq!(read_string(&gz).unwrap(), payload);

        // compression actually happened
        let plain_len = std::fs::metadata(&plain).unwrap().len();
        let gz_len = std::fs::metadata(&gz).unwrap().len();
        assert!(gz_len < plain_len / 5, "gz={gz_len} plain={plain_len}");

        std::fs::remove_dir_all(&dir).ok();
    }
}
