//! ASCII table rendering for the experiment regenerators.
//!
//! Prints the same row/column structure as the paper's tables so the
//! output can be compared side-by-side, plus CSV export for plotting.

/// A simple column-aligned table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = |l: char, m: char, r: char| {
            let mut s = String::new();
            s.push(l);
            for (i, w) in widths.iter().enumerate() {
                s.push_str(&"─".repeat(w + 2));
                s.push(if i + 1 == ncol { r } else { m });
            }
            s.push('\n');
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("│");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} │", c, width = widths[i]));
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep('┌', '┬', '┐'));
        out.push_str(&fmt_row(&self.header));
        out.push_str(&sep('├', '┼', '┤'));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out.push_str(&sep('└', '┴', '┘'));
        out
    }

    /// CSV export (RFC-4180-ish quoting).
    pub fn to_csv(&self) -> String {
        let quote = |s: &str| {
            if s.contains([',', '"', '\n']) {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| quote(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format seconds as "Xh Ym" / "Ym Zs" / "Z.Zs" for the time tables.
pub fn fmt_duration(seconds: f64) -> String {
    if seconds >= 3600.0 {
        format!("{:.1}h", seconds / 3600.0)
    } else if seconds >= 60.0 {
        format!("{:.1}m", seconds / 60.0)
    } else {
        format!("{seconds:.1}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("long-name"));
        // All data lines equal width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('│')).collect();
        assert!(lines.windows(2).all(|w| w[0].chars().count() == w[1].chars().count()));
    }

    #[test]
    fn csv_quotes() {
        let mut t = Table::new("", &["a,b", "c"]);
        t.row(vec!["x\"y".into(), "z".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"x\"\"y\",z"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(5.0), "5.0s");
        assert_eq!(fmt_duration(90.0), "1.5m");
        assert_eq!(fmt_duration(7200.0), "2.0h");
    }
}
